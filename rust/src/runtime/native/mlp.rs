//! The MLP compute core: dense forward pass, softmax-cross-entropy
//! backward pass, Glorot init — the pure-Rust twin of
//! `python/compile/model.py` (ReLU hidden layers, linear output,
//! mean sparse-categorical-cross-entropy, accuracy).
//!
//! Everything operates on flat row-major `f32` buffers (`rows × dim`),
//! the same layout [`crate::runtime::ModelParams`] stores and the same
//! `&[f32]` views the zero-copy record decoders hand the coordinator —
//! no tensor type, no reshapes, no copies beyond the activations
//! themselves.
//!
//! # Kernel scheme
//!
//! The hot loops are cache-blocked, 4-wide-unrolled f32 micro-kernels
//! over a caller-owned scratch arena ([`MlpScratch`]):
//!
//! * **forward** (`z = a·W + b`, fused bias + ReLU epilogue) — the
//!   output dimension is tiled ([`J_TILE`] floats ≈ 1 KiB) so one
//!   `z`-row tile stays register/L1-hot while the reduction streams;
//!   the reduction is unrolled 4-wide, so four weight rows share each
//!   `z[j]` load;
//! * **backward `dW += aᵀ·dz`** — same tiling, four `dW` rows updated
//!   per load of the `dz` tile;
//! * **backward `da = dz·Wᵀ`** — runs over a transposed-weight tile
//!   (`wt`, rebuilt per layer in scratch) so every `dz[j]` scales one
//!   *contiguous* `wt` row instead of striding through `W`, unrolled
//!   4-wide over `j`;
//! * **zero steady-state allocation** — all intermediates (activations,
//!   `dz`/`da`, `wt`, gradients) live in the arena and are reused
//!   across steps; a debug assertion fires if a warm step ever grows a
//!   buffer.
//!
//! Bit-stability contract: per-element accumulation order depends only
//! on the layer dimensions — never on the batch size or tile split — so
//! batched and single-row runs agree bit-for-bit and repeated runs are
//! deterministic (the pins in `tests/native_engine.rs`).

use crate::runtime::meta::ArtifactMeta;
use crate::runtime::params::{ModelParams, ParamTensor};
use crate::util::Rng;
use anyhow::{bail, Result};

/// Output-dimension tile width (floats) for the blocked kernels: 256
/// f32 = 1 KiB per weight-row strip, so a `z` tile plus four weight
/// strips sit comfortably in L1.
const J_TILE: usize = 256;

/// Reusable buffers for the forward/backward hot path. One arena per
/// training/eval loop (the native backend owns one behind a lock);
/// buffers grow to the high-water mark of the shapes seen, then every
/// later step runs with zero heap allocation.
#[derive(Debug, Default)]
pub struct MlpScratch {
    /// Post-activations `[a_0 = x, a_1, …, logits]` — `L+1` buffers.
    acts: Vec<Vec<f32>>,
    /// Upstream gradient of the layer currently being walked.
    dz: Vec<f32>,
    /// Downstream gradient under construction (swapped into `dz`).
    da: Vec<f32>,
    /// Transposed-weight tile (`fan_out × fan_in`) for the `dz·Wᵀ` pass.
    wt: Vec<f32>,
    /// Parameter gradients in artifact order `[dw1, db1, dw2, db2, …]`.
    grads: Vec<Vec<f32>>,
    /// Did the most recent kernel call grow any buffer?
    grew: bool,
    /// Batch size the forward-only buffers are warmed for.
    fwd_rows: Option<usize>,
    /// Batch size the full backward path is warmed for.
    bwd_rows: Option<usize>,
}

impl MlpScratch {
    pub fn new() -> MlpScratch {
        MlpScratch::default()
    }

    /// True when the most recent kernel call had to grow a buffer —
    /// steady-state steps must keep this `false` (asserted in debug
    /// builds, observable here for tests).
    pub fn grew(&self) -> bool {
        self.grew
    }

    /// Gradients produced by the last [`NativeMlp::loss_grad_with`]
    /// call, in artifact order, shapes matching the model's tensors.
    pub fn grads(&self) -> &[Vec<f32>] {
        &self.grads
    }

    fn note_fwd(&mut self, rows: usize, warm: bool, grew: bool) {
        self.grew = grew;
        debug_assert!(
            !(warm && grew),
            "native forward kernel allocated on a warm scratch (rows={rows})"
        );
        self.fwd_rows = Some(rows);
    }

    fn note_bwd(&mut self, rows: usize, warm: bool, grew: bool) {
        self.grew = grew;
        debug_assert!(
            !(warm && grew),
            "native backward kernel allocated on a warm scratch (rows={rows})"
        );
        self.bwd_rows = Some(rows);
        self.fwd_rows = Some(rows);
    }
}

/// Resize `v` to exactly `len`, recording whether that forced an
/// allocation. Callers fully overwrite (or zero) the buffer afterwards.
fn ensure_len(v: &mut Vec<f32>, len: usize, grew: &mut bool) {
    if v.capacity() < len {
        *grew = true;
    }
    v.resize(len, 0.0);
}

/// Guarantee capacity for `cap` elements without touching the length,
/// recording whether that forced an allocation. Used to pre-size the
/// `dz`/`da` pair: the two trade buffers via `swap` every layer, so
/// sizing them individually would leave the pair asymmetric after a
/// cold call and the *second* call would still have to allocate.
fn ensure_cap(v: &mut Vec<f32>, cap: usize, grew: &mut bool) {
    if v.capacity() < cap {
        *grew = true;
        v.reserve_exact(cap - v.len());
    }
}

/// Architecture view the math runs over: `(fan_in, fan_out)` per layer,
/// hidden layers ReLU, output layer linear.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeMlp {
    pub input_dim: usize,
    pub classes: usize,
    pub layers: Vec<(usize, usize)>,
    pub seed: u64,
}

impl NativeMlp {
    /// Derive the layer chain from the meta spec and cross-check it
    /// against the declared parameter list (the artifact contract).
    pub fn from_meta(meta: &ArtifactMeta) -> Result<NativeMlp> {
        if meta.input_dim == 0 || meta.classes == 0 {
            bail!("native MLP needs input_dim > 0 and classes > 0");
        }
        let dims: Vec<usize> = std::iter::once(meta.input_dim)
            .chain(meta.hidden.iter().copied())
            .chain(std::iter::once(meta.classes))
            .collect();
        let layers: Vec<(usize, usize)> = dims.windows(2).map(|w| (w[0], w[1])).collect();
        let mlp = NativeMlp {
            input_dim: meta.input_dim,
            classes: meta.classes,
            layers,
            seed: meta.seed,
        };
        if meta.params.len() != 2 * mlp.layers.len() {
            bail!(
                "meta declares {} param tensors, architecture {:?} needs {}",
                meta.params.len(),
                dims,
                2 * mlp.layers.len()
            );
        }
        for (i, &(fan_in, fan_out)) in mlp.layers.iter().enumerate() {
            let (w, b) = (&meta.params[2 * i], &meta.params[2 * i + 1]);
            if w.shape != [fan_in, fan_out] || b.shape != [fan_out] {
                bail!(
                    "layer {} shape mismatch: meta has {}{:?}/{}{:?}, architecture wants [{fan_in},{fan_out}]/[{fan_out}]",
                    i + 1,
                    w.name,
                    w.shape,
                    b.name,
                    b.shape
                );
            }
        }
        Ok(mlp)
    }

    /// Glorot-uniform weights + zero biases, deterministic per seed —
    /// the native `init` artifact (same scheme as `model.py`'s
    /// `init_params`, seeded via [`crate::util::Rng`]).
    pub fn init(&self) -> ModelParams {
        let mut rng = Rng::new(self.seed);
        let mut tensors = Vec::with_capacity(2 * self.layers.len());
        for (i, &(fan_in, fan_out)) in self.layers.iter().enumerate() {
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let w = (0..fan_in * fan_out)
                .map(|_| rng.range_f64(-limit, limit) as f32)
                .collect();
            tensors.push(ParamTensor {
                name: format!("w{}", i + 1),
                shape: vec![fan_in, fan_out],
                data: w,
            });
            tensors.push(ParamTensor {
                name: format!("b{}", i + 1),
                shape: vec![fan_out],
                data: vec![0.0; fan_out],
            });
        }
        ModelParams { tensors }
    }

    /// Forward pass into the scratch arena, keeping every
    /// post-activation (needed by backward): fills `acts` with
    /// `[a_0 = x, a_1, …, a_{L-1}, logits]` — `L+1` buffers.
    fn forward_into(
        &self,
        params: &ModelParams,
        x: &[f32],
        rows: usize,
        acts: &mut Vec<Vec<f32>>,
        grew: &mut bool,
    ) {
        let n_layers = self.layers.len();
        if acts.len() != n_layers + 1 {
            *grew = true;
            acts.clear();
            acts.resize_with(n_layers + 1, Vec::new);
        }
        ensure_len(&mut acts[0], x.len(), grew);
        acts[0].copy_from_slice(x);
        for (li, &(fan_in, fan_out)) in self.layers.iter().enumerate() {
            let w = &params.tensors[2 * li].data;
            let b = &params.tensors[2 * li + 1].data;
            let (head, tail) = acts.split_at_mut(li + 1);
            let a = head[li].as_slice();
            let z = &mut tail[0];
            ensure_len(z, rows * fan_out, grew);
            let relu = li < n_layers - 1;
            dense_forward(a, w, b, z, rows, fan_in, fan_out, relu);
        }
    }

    /// Logits for `rows` samples (`rows × classes`, row-major).
    pub fn logits(&self, params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
        let mut s = MlpScratch::default();
        self.forward_into(params, x, rows, &mut s.acts, &mut s.grew);
        s.acts.pop().unwrap()
    }

    /// Class probabilities (numerically stable row-wise softmax).
    pub fn probs(&self, params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
        let mut logits = self.logits(params, x, rows);
        for row in logits.chunks_mut(self.classes) {
            softmax_row(row);
        }
        logits
    }

    /// [`NativeMlp::probs`] over caller-owned scratch: only the
    /// returned vector is allocated once the scratch is warm.
    pub fn probs_with(
        &self,
        params: &ModelParams,
        x: &[f32],
        rows: usize,
        s: &mut MlpScratch,
    ) -> Vec<f32> {
        let warm = s.fwd_rows == Some(rows);
        let mut grew = false;
        self.forward_into(params, x, rows, &mut s.acts, &mut grew);
        s.note_fwd(rows, warm, grew);
        let mut out = s.acts[self.layers.len()].clone();
        for row in out.chunks_mut(self.classes) {
            softmax_row(row);
        }
        out
    }

    /// Mean NLL + accuracy over one batch of `rows` labeled samples.
    pub fn loss_acc(&self, params: &ModelParams, x: &[f32], y: &[i32], rows: usize) -> (f32, f32) {
        let mut s = MlpScratch::default();
        self.loss_acc_with(params, x, y, rows, &mut s)
    }

    /// [`NativeMlp::loss_acc`] over caller-owned scratch (zero heap
    /// allocation once warm).
    pub fn loss_acc_with(
        &self,
        params: &ModelParams,
        x: &[f32],
        y: &[i32],
        rows: usize,
        s: &mut MlpScratch,
    ) -> (f32, f32) {
        let warm = s.fwd_rows == Some(rows);
        let mut grew = false;
        self.forward_into(params, x, rows, &mut s.acts, &mut grew);
        s.note_fwd(rows, warm, grew);
        loss_acc_of_logits(&s.acts[self.layers.len()], y, rows, self.classes)
    }

    /// Loss, accuracy and the full parameter gradient (softmax-CE
    /// backward pass). Gradients come back flat, in artifact order
    /// `[dw1, db1, dw2, db2, …]`, shapes matching `params`.
    ///
    /// Convenience wrapper allocating its own scratch; loops should use
    /// [`NativeMlp::loss_grad_with`] and read `scratch.grads()` instead.
    pub fn loss_grad(
        &self,
        params: &ModelParams,
        x: &[f32],
        y: &[i32],
        rows: usize,
    ) -> (f32, f32, Vec<Vec<f32>>) {
        let mut s = MlpScratch::default();
        let (loss, acc) = self.loss_grad_with(params, x, y, rows, &mut s);
        (loss, acc, std::mem::take(&mut s.grads))
    }

    /// The backward hot path over caller-owned scratch: loss/accuracy
    /// return by value, gradients land in `scratch.grads()`. Zero heap
    /// allocation once the scratch is warm for this batch shape (debug
    /// builds assert it).
    pub fn loss_grad_with(
        &self,
        params: &ModelParams,
        x: &[f32],
        y: &[i32],
        rows: usize,
        s: &mut MlpScratch,
    ) -> (f32, f32) {
        let warm = s.bwd_rows == Some(rows);
        let mut grew = false;
        let n_layers = self.layers.len();
        self.forward_into(params, x, rows, &mut s.acts, &mut grew);
        let (loss, acc) = loss_acc_of_logits(&s.acts[n_layers], y, rows, self.classes);

        // dz and da trade buffers via swap at every layer boundary, so
        // give BOTH capacity for the widest interface now — sizing them
        // lazily would leave the pair asymmetric after the cold call and
        // the second call would still allocate for the swapped-in side.
        let max_dim = self.layers.iter().map(|&(i, o)| i.max(o)).max().unwrap_or(0);
        ensure_cap(&mut s.dz, rows * max_dim, &mut grew);
        ensure_cap(&mut s.da, rows * max_dim, &mut grew);

        // dz for the output layer: (softmax(logits) − onehot(y)) / rows.
        ensure_len(&mut s.dz, rows * self.classes, &mut grew);
        s.dz.copy_from_slice(&s.acts[n_layers]);
        for (r, row) in s.dz.chunks_mut(self.classes).enumerate() {
            softmax_row(row);
            row[y[r] as usize] -= 1.0;
            for v in row.iter_mut() {
                *v /= rows as f32;
            }
        }

        if s.grads.len() != params.tensors.len() {
            grew = true;
            s.grads.clear();
            s.grads.resize_with(params.tensors.len(), Vec::new);
        }
        for li in (0..n_layers).rev() {
            let (fan_in, fan_out) = self.layers[li];
            // dW += aᵀ·dz (a = acts[li], the input to this layer).
            ensure_len(&mut s.grads[2 * li], fan_in * fan_out, &mut grew);
            s.grads[2 * li].fill(0.0);
            accumulate_dw(&s.acts[li], &s.dz, &mut s.grads[2 * li], rows, fan_in, fan_out);
            // db = column sums of dz.
            ensure_len(&mut s.grads[2 * li + 1], fan_out, &mut grew);
            s.grads[2 * li + 1].fill(0.0);
            {
                let db = &mut s.grads[2 * li + 1];
                for r in 0..rows {
                    let dzr = &s.dz[r * fan_out..(r + 1) * fan_out];
                    for (dbv, &dzv) in db.iter_mut().zip(dzr) {
                        *dbv += dzv;
                    }
                }
            }
            if li > 0 {
                // da_{li-1} = dz · Wᵀ over a transposed-weight tile so
                // every dz element scales a contiguous wt row, then the
                // ReLU gate (a_{li-1} > 0 ⟺ z_{li-1} > 0).
                let w = &params.tensors[2 * li].data;
                ensure_len(&mut s.wt, fan_in * fan_out, &mut grew);
                transpose_into(w, &mut s.wt, fan_in, fan_out);
                ensure_len(&mut s.da, rows * fan_in, &mut grew);
                s.da.fill(0.0);
                backward_da(&s.dz, &s.wt, &mut s.da, rows, fan_in, fan_out);
                for (dav, &av) in s.da.iter_mut().zip(s.acts[li].iter()) {
                    if av <= 0.0 {
                        *dav = 0.0;
                    }
                }
                std::mem::swap(&mut s.dz, &mut s.da);
            }
        }
        s.note_bwd(rows, warm, grew);
        (loss, acc)
    }
}

/// One dense layer `z = a·W + b` (row-major), ReLU epilogue fused when
/// `relu` is set.
///
/// Blocked over the output dimension ([`J_TILE`]) and unrolled 4-wide
/// over the reduction: four weight rows stream through one register-
/// resident `z` tile per pass, and an all-zero activation quad (common
/// behind ReLU) skips its four rows entirely. Per-element accumulation
/// order depends only on `fan_in` and the element's own tile — never on
/// `rows` — preserving batched == single-row bit-identity.
#[allow(clippy::too_many_arguments)]
fn dense_forward(
    a: &[f32],
    w: &[f32],
    b: &[f32],
    z: &mut [f32],
    rows: usize,
    fan_in: usize,
    fan_out: usize,
    relu: bool,
) {
    for j0 in (0..fan_out).step_by(J_TILE) {
        let jw = (fan_out - j0).min(J_TILE);
        let bt = &b[j0..j0 + jw];
        for r in 0..rows {
            let ar = &a[r * fan_in..(r + 1) * fan_in];
            let zr = &mut z[r * fan_out + j0..r * fan_out + j0 + jw];
            zr.copy_from_slice(bt);
            let mut k = 0;
            while k + 4 <= fan_in {
                let (a0, a1, a2, a3) = (ar[k], ar[k + 1], ar[k + 2], ar[k + 3]);
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let w0 = &w[k * fan_out + j0..][..jw];
                    let w1 = &w[(k + 1) * fan_out + j0..][..jw];
                    let w2 = &w[(k + 2) * fan_out + j0..][..jw];
                    let w3 = &w[(k + 3) * fan_out + j0..][..jw];
                    for (j, zv) in zr.iter_mut().enumerate() {
                        *zv += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                    }
                }
                k += 4;
            }
            while k < fan_in {
                let ak = ar[k];
                if ak != 0.0 {
                    let wk = &w[k * fan_out + j0..][..jw];
                    for (j, zv) in zr.iter_mut().enumerate() {
                        *zv += ak * wk[j];
                    }
                }
                k += 1;
            }
            if relu {
                for zv in zr.iter_mut() {
                    if *zv < 0.0 {
                        *zv = 0.0;
                    }
                }
            }
        }
    }
}

/// Weight-gradient accumulation `dW += aᵀ·dz` (`dw` pre-zeroed).
/// Mirrors the forward blocking: the output dimension is tiled and the
/// reduction walked in quads — four `dW` rows updated per load of the
/// `dz` tile, all-zero activation quads skipped.
fn accumulate_dw(
    a: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    rows: usize,
    fan_in: usize,
    fan_out: usize,
) {
    for j0 in (0..fan_out).step_by(J_TILE) {
        let jw = (fan_out - j0).min(J_TILE);
        for r in 0..rows {
            let ar = &a[r * fan_in..(r + 1) * fan_in];
            let dzr = &dz[r * fan_out + j0..][..jw];
            for (q, dw4) in dw.chunks_mut(4 * fan_out).enumerate() {
                let k = 4 * q;
                if k + 4 <= fan_in {
                    let (a0, a1, a2, a3) = (ar[k], ar[k + 1], ar[k + 2], ar[k + 3]);
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let (d0, rest) = dw4.split_at_mut(fan_out);
                    let (d1, rest) = rest.split_at_mut(fan_out);
                    let (d2, d3) = rest.split_at_mut(fan_out);
                    let t0 = &mut d0[j0..j0 + jw];
                    let t1 = &mut d1[j0..j0 + jw];
                    let t2 = &mut d2[j0..j0 + jw];
                    let t3 = &mut d3[j0..j0 + jw];
                    for (j, &dzv) in dzr.iter().enumerate() {
                        t0[j] += a0 * dzv;
                        t1[j] += a1 * dzv;
                        t2[j] += a2 * dzv;
                        t3[j] += a3 * dzv;
                    }
                } else {
                    // Remainder rows (fan_in % 4).
                    for (i, dwk) in dw4.chunks_mut(fan_out).enumerate() {
                        let ak = ar[k + i];
                        if ak != 0.0 {
                            let t = &mut dwk[j0..j0 + jw];
                            for (j, tv) in t.iter_mut().enumerate() {
                                *tv += ak * dzr[j];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Activation gradient `da += dz·Wᵀ` (`da` pre-zeroed), over the
/// transposed-weight tile `wt` (`fan_out × fan_in`, row-major): each
/// `dz[j]` scales one contiguous `wt` row, unrolled 4-wide over `j` so
/// four scaled rows accumulate per pass over the `da` row.
fn backward_da(
    dz: &[f32],
    wt: &[f32],
    da: &mut [f32],
    rows: usize,
    fan_in: usize,
    fan_out: usize,
) {
    for r in 0..rows {
        let dzr = &dz[r * fan_out..(r + 1) * fan_out];
        let dar = &mut da[r * fan_in..(r + 1) * fan_in];
        let mut j = 0;
        while j + 4 <= fan_out {
            let (d0, d1, d2, d3) = (dzr[j], dzr[j + 1], dzr[j + 2], dzr[j + 3]);
            let w0 = &wt[j * fan_in..][..fan_in];
            let w1 = &wt[(j + 1) * fan_in..][..fan_in];
            let w2 = &wt[(j + 2) * fan_in..][..fan_in];
            let w3 = &wt[(j + 3) * fan_in..][..fan_in];
            for (k, dav) in dar.iter_mut().enumerate() {
                *dav += d0 * w0[k] + d1 * w1[k] + d2 * w2[k] + d3 * w3[k];
            }
            j += 4;
        }
        while j < fan_out {
            let dj = dzr[j];
            if dj != 0.0 {
                let wj = &wt[j * fan_in..][..fan_in];
                for (k, dav) in dar.iter_mut().enumerate() {
                    *dav += dj * wj[k];
                }
            }
            j += 1;
        }
    }
}

/// `wt[j·fan_in + k] = w[k·fan_out + j]` — the backward pass's
/// transposed-weight tile.
fn transpose_into(w: &[f32], wt: &mut [f32], fan_in: usize, fan_out: usize) {
    for k in 0..fan_in {
        let wk = &w[k * fan_out..(k + 1) * fan_out];
        for (j, &wv) in wk.iter().enumerate() {
            wt[j * fan_in + k] = wv;
        }
    }
}

/// In-place stable softmax over one row.
fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Mean sparse-categorical cross-entropy + accuracy from raw logits.
/// Loss accumulates in f64 (the finite-difference gradient check in
/// `rust/tests/native_engine.rs` leans on that headroom).
fn loss_acc_of_logits(logits: &[f32], y: &[i32], rows: usize, classes: usize) -> (f32, f32) {
    let mut nll_sum = 0f64;
    let mut correct = 0usize;
    for (r, row) in logits.chunks(classes).enumerate() {
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = mx as f64
            + row
                .iter()
                .map(|&v| ((v - mx) as f64).exp())
                .sum::<f64>()
                .ln();
        let label = y[r] as usize;
        nll_sum += lse - row[label] as f64;
        // First-max argmax, like jnp.argmax.
        let mut arg = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = c;
            }
        }
        if arg == label {
            correct += 1;
        }
    }
    ((nll_sum / rows as f64) as f32, correct as f32 / rows as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny() -> (NativeMlp, ModelParams) {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 3, &[4], 2, 4, 0.01, 9);
        let mlp = NativeMlp::from_meta(&meta).unwrap();
        let params = mlp.init();
        (mlp, params)
    }

    #[test]
    fn from_meta_checks_param_contract() {
        let mut meta = ArtifactMeta::synthesize(PathBuf::new(), 3, &[4], 2, 4, 0.01, 9);
        assert!(NativeMlp::from_meta(&meta).is_ok());
        meta.params[0].shape = vec![3, 5]; // contradicts hidden=[4]
        assert!(NativeMlp::from_meta(&meta).is_err());
        meta.params.pop();
        assert!(NativeMlp::from_meta(&meta).is_err());
    }

    #[test]
    fn init_is_deterministic_glorot() {
        let (mlp, p1) = tiny();
        let p2 = mlp.init();
        assert_eq!(p1, p2);
        let limit = (6.0f64 / (3 + 4) as f64).sqrt() as f32;
        assert!(p1.tensors[0].data.iter().all(|v| v.abs() <= limit));
        assert!(p1.tensors[0].data.iter().any(|&v| v != 0.0));
        assert!(p1.tensors[1].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn probs_are_a_distribution_and_match_single_row() {
        let (mlp, params) = tiny();
        let x: Vec<f32> = (0..4 * 3).map(|i| (i as f32 * 0.37).sin()).collect();
        let probs = mlp.probs(&params, &x, 4);
        assert_eq!(probs.len(), 4 * 2);
        for row in probs.chunks(2) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Row-wise compute ⟹ batched == single, bit for bit.
        for r in 0..4 {
            let single = mlp.probs(&params, &x[r * 3..(r + 1) * 3], 1);
            assert_eq!(&probs[r * 2..(r + 1) * 2], &single[..]);
        }
    }

    #[test]
    fn loss_of_uniform_logits_is_ln_classes() {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 2, &[], 4, 2, 0.01, 1);
        let mlp = NativeMlp::from_meta(&meta).unwrap();
        // Zero weights + zero biases → uniform logits → loss = ln(4).
        let mut params = mlp.init();
        for t in &mut params.tensors {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        let (loss, _) = mlp.loss_acc(&params, &[1.0, 2.0, -1.0, 0.5], &[0, 3], 2);
        assert!((loss - (4f32).ln()).abs() < 1e-6, "loss {loss}");
    }

    #[test]
    fn grads_match_shapes_and_bias_grad_sums_dz() {
        let (mlp, params) = tiny();
        let x: Vec<f32> = (0..4 * 3).map(|i| (i as f32 * 0.11).cos()).collect();
        let y = [0i32, 1, 1, 0];
        let (loss, acc, grads) = mlp.loss_grad(&params, &x, &y, 4);
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(grads.len(), params.tensors.len());
        for (g, t) in grads.iter().zip(&params.tensors) {
            assert_eq!(g.len(), t.numel());
        }
        // Output-layer dz rows sum to 0 (softmax − onehot), so the
        // output bias gradient must sum to ~0 as well.
        let db_out: f32 = grads[3].iter().sum();
        assert!(db_out.abs() < 1e-5, "db_out {db_out}");
    }
}
