//! The TCP wire protocol: the broker as a real network service.
//!
//! Four pieces, all plain `std::net` plus a thin vendored FFI shim
//! (the build is hermetic — no tokio, no serde, no mio):
//!
//! * [`codec`] — the binary frame format. Every request and response is
//!   one length-prefixed, CRC-32-checksummed frame (the same framing
//!   discipline as the on-disk segment format,
//!   `broker/log/format.rs`), and records travel *as* segment-format
//!   record frames, so both sides decode them zero-copy into
//!   [`crate::util::Bytes`] slice views of the received buffer. Fetch
//!   responses can also be *encoded* zero-copy, as gather-write chunk
//!   lists whose record payloads alias the broker log
//!   ([`codec::encode_fetch_response_chunks`]). Every request body
//!   leads with a **correlation id** — the pipelining handle: requests
//!   stream down a connection back to back, responses return in
//!   *completion* order, and both ends match them up by id
//!   ([`codec::peek_corr`]).
//! * [`reactor`] — the event-loop substrate: a readiness [`Poller`]
//!   (epoll on Linux, portable `poll(2)` elsewhere), an eventfd/pipe
//!   [`WakeFd`] for cross-thread wakeups, and vectored
//!   [`writev`](reactor::writev) — all over the vendored `libc` shim.
//!   Each reactor shard owns one `Poller` + `WakeFd` pair.
//! * [`server`] — [`BrokerServer`]: **N reactor shards** (`serve
//!   --reactors N`, default [`server::default_reactors`]) sharing one
//!   request worker pool, serving a [`crate::broker::Cluster`]. Shard 0
//!   owns the listener and deals accepted connections round-robin;
//!   after that a connection lives and dies on its shard — its own
//!   poller, timer heap and read staging, no cross-shard locks on the
//!   hot path. Connections are **pipelined**: a readability wake
//!   parses every complete frame in the buffer (bounded by
//!   [`server::MAX_INFLIGHT_PER_CONN`]), ordinary requests execute
//!   strictly serially per connection (the ordering guarantee producer
//!   retries depend on), and blocking long-polls (`FetchWait`) skip
//!   the serial lane and park **server-side** as registrations on the
//!   broker's [`crate::broker::notify`] wait-sets, bridged to the
//!   owning shard through a wake hook — so a parked remote consumer
//!   reacts to a produce in one socket round trip, with zero polling
//!   on the wire and zero threads per parked connection. Shutdown
//!   rides the crate's cancel primitives and unblocks every connection
//!   deterministically.
//! * [`client`] — [`RemoteBroker`]: the socket client implementing
//!   [`crate::broker::BrokerTransport`] over a **multiplexed
//!   connection**: N concurrent callers share one socket, a reader
//!   thread demultiplexes responses by correlation id, and long-polls
//!   get a dedicated lane so a parked `FetchWait` never queues behind
//!   (or ahead of) request traffic. Transparent reconnect plus
//!   client-side idle expiry ([`client::CLIENT_IDLE_EXPIRY`]) keep the
//!   pool fresh, so `Producer`/`Consumer`/coordinator jobs run against
//!   a broker in another OS process exactly as they run in-process.
//!
//! On this path the *real* network replaces the simulated
//! [`crate::broker::NetProfile`] delay — the server dispatches every
//! operation with [`crate::broker::ClientLocality::Remote`], whose
//! traversal is always free.

pub mod client;
pub mod codec;
pub mod reactor;
pub mod server;

pub use client::RemoteBroker;
pub use reactor::{Poller, WakeFd};
pub use server::BrokerServer;
