//! Bench harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + timed iterations with mean/σ/p50/p99, table
//! rendering that mirrors the layout of the paper's Tables I/II so
//! `cargo bench` output can be compared line-by-line with the paper,
//! and a machine-readable [`Report`] writer (`BENCH_*.json`) so later
//! PRs have a perf trajectory to compare against.

use crate::json::Json;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        let q = |f: f64| samples[((n - 1) as f64 * f).round() as usize];
        Stats {
            iters: n,
            mean,
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: samples[0],
            p50: q(0.5),
            p99: q(0.99),
            max: samples[n - 1],
        }
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark runner: `warmup` untimed runs, then `iters` timed runs.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` (each call is one sample).
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let samples = (0..self.iters.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect();
        Stats::from_samples(samples)
    }

    /// Time `f` with per-iteration setup excluded from the measurement.
    pub fn run_with_setup<S, T, F: FnMut(T)>(
        &self,
        mut setup: S,
        mut f: F,
    ) -> Stats
    where
        S: FnMut() -> T,
    {
        for _ in 0..self.warmup {
            let input = setup();
            f(input);
        }
        let samples = (0..self.iters.max(1))
            .map(|_| {
                let input = setup();
                let t0 = Instant::now();
                f(input);
                t0.elapsed()
            })
            .collect();
        Stats::from_samples(samples)
    }
}

/// Simple fixed-width results table (paper-style).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Machine-readable benchmark results. Each entry is one measured
/// configuration (`group` + parameter map + metrics); [`Report::save`]
/// writes the whole run as pretty JSON (e.g. `BENCH_broker_throughput.json`)
/// so successive PRs can diff perf numbers mechanically.
pub struct Report {
    name: String,
    entries: Vec<Json>,
}

impl Report {
    pub fn new(name: &str) -> Report {
        Report { name: name.to_string(), entries: Vec::new() }
    }

    /// Record one measured configuration. `params` describe the swept
    /// knobs (batch size, payload bytes, …), `metrics` the results
    /// (records/s, wall seconds, …).
    pub fn entry(&mut self, group: &str, params: &[(&str, f64)], metrics: &[(&str, f64)]) {
        let mut fields = vec![("group", Json::str(group))];
        fields.push((
            "params",
            Json::obj(params.iter().map(|(k, v)| (*k, Json::num(*v))).collect()),
        ));
        fields.push((
            "metrics",
            Json::obj(metrics.iter().map(|(k, v)| (*k, Json::num(*v))).collect()),
        ));
        self.entries.push(Json::obj(fields));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str(&self.name)),
            ("entries", Json::Arr(self.entries.clone())),
        ])
    }

    /// Write the report as pretty JSON to `path`.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, crate::json::to_string_pretty(&self.to_json()))
    }
}

/// Format seconds like the paper's tables (two decimals).
pub fn secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

pub fn millis(d: Duration) -> String {
    format!("{:.2}", d.as_secs_f64() * 1e3)
}

// ---- process-level measurements (Linux) ------------------------------------
//
// Resource-footprint benches (memory per idle connection, thread-count
// ceilings) and the wire soak tests read them from /proc. Off-Linux they
// return None and callers report/assert nothing.

/// A numeric field from `/proc/self/status` (value's first token).
#[cfg(target_os = "linux")]
fn proc_status_field(field: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with(field))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Resident set size of this process in KiB (`VmRSS`).
pub fn proc_rss_kb() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        proc_status_field("VmRSS:")
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Current thread count of this process (`Threads`).
pub fn proc_threads() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        proc_status_field("Threads:")
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Number of open file descriptors of this process.
pub fn proc_open_fds() -> Option<usize> {
    #[cfg(target_os = "linux")]
    {
        Some(std::fs::read_dir("/proc/self/fd").ok()?.count())
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples(vec![Duration::from_millis(10); 5]);
        assert_eq!(s.mean, Duration::from_millis(10));
        assert_eq!(s.std_dev, Duration::ZERO);
        assert_eq!(s.p50, Duration::from_millis(10));
    }

    #[test]
    fn bench_runs_expected_iterations() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let calls = AtomicU32::new(0);
        let b = Bench::new(3, 7);
        let s = b.run(|| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 10);
        assert_eq!(s.iters, 7);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("Demo", &["mode", "latency (s)"]);
        t.row(&["normal".into(), "27.37".into()]);
        t.row(&["data streams".into(), "29.61".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("27.37"));
        assert!(r.contains("data streams"));
    }

    #[test]
    fn report_serializes_entries() {
        let mut r = Report::new("demo");
        r.entry("batching", &[("batch", 64.0)], &[("records_per_s", 123.5)]);
        r.entry("batching", &[("batch", 256.0)], &[("records_per_s", 987.0)]);
        assert_eq!(r.len(), 2);
        let s = crate::json::to_string(&r.to_json());
        assert!(s.contains("\"bench\":\"demo\""), "{s}");
        assert!(s.contains("\"batch\":64"), "{s}");
        assert!(s.contains("records_per_s"), "{s}");
        // And it parses back as JSON.
        let parsed = crate::json::parse(&s).unwrap();
        assert_eq!(parsed.get("entries").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn setup_excluded_from_timing() {
        let b = Bench::new(0, 3);
        let s = b.run_with_setup(
            || std::thread::sleep(Duration::from_millis(20)),
            |_| {},
        );
        // Measured body is empty; must be far below the 20ms setup.
        assert!(s.mean < Duration::from_millis(5), "{:?}", s.mean);
    }
}
