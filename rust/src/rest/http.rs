//! HTTP/1.1 message types + wire parsing/serialization.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Get,
    Post,
    Put,
    Delete,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "GET" => Method::Get,
            "POST" => Method::Post,
            "PUT" => Method::Put,
            "DELETE" => Method::Delete,
            other => bail!("unsupported method {other}"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Method::Get => "GET",
            Method::Post => "POST",
            Method::Put => "PUT",
            Method::Delete => "DELETE",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    Created,
    NoContent,
    BadRequest,
    NotFound,
    Conflict,
    ServerError,
}

impl Status {
    pub fn code(self) -> u16 {
        match self {
            Status::Ok => 200,
            Status::Created => 201,
            Status::NoContent => 204,
            Status::BadRequest => 400,
            Status::NotFound => 404,
            Status::Conflict => 409,
            Status::ServerError => 500,
        }
    }

    pub fn reason(self) -> &'static str {
        match self {
            Status::Ok => "OK",
            Status::Created => "Created",
            Status::NoContent => "No Content",
            Status::BadRequest => "Bad Request",
            Status::NotFound => "Not Found",
            Status::Conflict => "Conflict",
            Status::ServerError => "Internal Server Error",
        }
    }

    pub fn from_code(code: u16) -> Status {
        match code {
            200 => Status::Ok,
            201 => Status::Created,
            204 => Status::NoContent,
            400 => Status::BadRequest,
            404 => Status::NotFound,
            409 => Status::Conflict,
            _ => Status::ServerError,
        }
    }

    pub fn is_success(self) -> bool {
        self.code() < 300
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub method: Method,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// Filled by the router from `:param` segments.
    pub params: BTreeMap<String, String>,
}

impl Request {
    pub fn new(method: Method, path: &str) -> Request {
        Request {
            method,
            path: path.to_string(),
            headers: BTreeMap::new(),
            body: Vec::new(),
            params: BTreeMap::new(),
        }
    }

    pub fn with_body(mut self, body: Vec<u8>, content_type: &str) -> Request {
        self.headers
            .insert("content-type".to_string(), content_type.to_string());
        self.body = body;
        self
    }

    pub fn param(&self, name: &str) -> Result<&str> {
        self.params
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing path param :{name}"))
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|e| anyhow!("body not utf-8: {e}"))
    }

    /// Read one request from a stream.
    pub fn read_from(stream: &mut impl Read) -> Result<Request> {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let mut parts = line.trim_end().split(' ');
        let method = Method::parse(parts.next().unwrap_or(""))?;
        let path = parts
            .next()
            .ok_or_else(|| anyhow!("malformed request line"))?
            .to_string();
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        let len: usize = headers
            .get("content-length")
            .map(|v| v.parse())
            .transpose()
            .map_err(|e| anyhow!("bad content-length: {e}"))?
            .unwrap_or(0);
        if len > 256 * 1024 * 1024 {
            bail!("body too large: {len}");
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        Ok(Request { method, path, headers, body, params: BTreeMap::new() })
    }

    pub fn write_to(&self, stream: &mut impl Write) -> Result<()> {
        write!(stream, "{} {} HTTP/1.1\r\n", self.method.as_str(), self.path)?;
        for (k, v) in &self.headers {
            write!(stream, "{k}: {v}\r\n")?;
        }
        write!(stream, "content-length: {}\r\n", self.body.len())?;
        write!(stream, "connection: close\r\n\r\n")?;
        stream.write_all(&self.body)?;
        stream.flush()?;
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: Status,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn status(status: Status) -> Response {
        Response { status, headers: BTreeMap::new(), body: Vec::new() }
    }

    pub fn json(status: Status, j: &crate::json::Json) -> Response {
        let mut r = Response::status(status);
        r.headers
            .insert("content-type".to_string(), "application/json".to_string());
        r.body = crate::json::to_string(j).into_bytes();
        r
    }

    pub fn binary(status: Status, body: Vec<u8>) -> Response {
        let mut r = Response::status(status);
        r.headers.insert(
            "content-type".to_string(),
            "application/octet-stream".to_string(),
        );
        r.body = body;
        r
    }

    pub fn error(status: Status, msg: &str) -> Response {
        Response::json(status, &crate::json::Json::obj(vec![("error", msg.into())]))
    }

    pub fn body_json(&self) -> Result<crate::json::Json> {
        let s = std::str::from_utf8(&self.body)?;
        crate::json::parse(s).map_err(|e| anyhow!("response json: {e}"))
    }

    pub fn read_from(stream: &mut impl Read) -> Result<Response> {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let code: u16 = line
            .split(' ')
            .nth(1)
            .ok_or_else(|| anyhow!("malformed status line: {line:?}"))?
            .parse()?;
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        let len: usize = headers
            .get("content-length")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(0);
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        Ok(Response { status: Status::from_code(code), headers, body })
    }

    pub fn write_to(&self, stream: &mut impl Write) -> Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\n",
            self.status.code(),
            self.status.reason()
        )?;
        for (k, v) in &self.headers {
            write!(stream, "{k}: {v}\r\n")?;
        }
        write!(stream, "content-length: {}\r\n", self.body.len())?;
        write!(stream, "connection: close\r\n\r\n")?;
        stream.write_all(&self.body)?;
        stream.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_wire_roundtrip() {
        let req = Request::new(Method::Post, "/models")
            .with_body(b"{\"a\":1}".to_vec(), "application/json");
        let mut wire = Vec::new();
        req.write_to(&mut wire).unwrap();
        let back = Request::read_from(&mut wire.as_slice()).unwrap();
        assert_eq!(back.method, Method::Post);
        assert_eq!(back.path, "/models");
        assert_eq!(back.body, req.body);
        assert_eq!(back.headers.get("content-type").unwrap(), "application/json");
    }

    #[test]
    fn response_wire_roundtrip() {
        let resp = Response::binary(Status::Created, vec![1, 2, 3, 255]);
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let back = Response::read_from(&mut wire.as_slice()).unwrap();
        assert_eq!(back.status, Status::Created);
        assert_eq!(back.body, vec![1, 2, 3, 255]);
    }

    #[test]
    fn empty_body_ok() {
        let mut wire = Vec::new();
        Request::new(Method::Get, "/x").write_to(&mut wire).unwrap();
        let back = Request::read_from(&mut wire.as_slice()).unwrap();
        assert!(back.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::read_from(&mut &b"NOT HTTP\r\n\r\n"[..]).is_err());
    }

    #[test]
    fn status_codes() {
        assert_eq!(Status::Ok.code(), 200);
        assert_eq!(Status::from_code(404), Status::NotFound);
        assert!(Status::Created.is_success());
        assert!(!Status::ServerError.is_success());
    }
}
