//! Shared condvar discipline for every timed wait in the codebase.
//!
//! [`wait_deadline`] is the one place the `Condvar::wait_timeout`
//! remaining-time arithmetic lives. [`crate::broker::notify`]'s waiters
//! and [`crate::exec`]'s channels (`recv_deadline`/`recv_timeout`) both
//! build on it; callers loop on their own predicate (a spurious wakeup
//! hands back `timed_out == false` with the predicate unchanged).

use std::sync::{Condvar, MutexGuard};
use std::time::Instant;

/// Wait on `cv` until notified or `deadline` passes. Returns the
/// re-acquired guard and whether the deadline elapsed. An
/// already-passed deadline returns immediately without parking.
pub fn wait_deadline<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    deadline: Instant,
) -> (MutexGuard<'a, T>, bool) {
    let now = Instant::now();
    if now >= deadline {
        return (guard, true);
    }
    let (guard, res) = cv
        .wait_timeout(guard, deadline - now)
        .expect("waiter mutex poisoned");
    (guard, res.timed_out())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::Duration;

    #[test]
    fn elapsed_deadline_returns_immediately() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let (_g, timed_out) = wait_deadline(&cv, g, Instant::now());
        assert!(timed_out);
    }

    #[test]
    fn notify_ends_wait_before_deadline() {
        use std::sync::Arc;
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = pair.clone();
        let h = std::thread::spawn(move || {
            *p2.0.lock().unwrap() = true;
            p2.1.notify_all();
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut g = pair.0.lock().unwrap();
        let mut timed_out = false;
        while !*g && !timed_out {
            (g, timed_out) = wait_deadline(&pair.1, g, deadline);
        }
        assert!(*g);
        h.join().unwrap();
    }
}
