//! Broker ablations (§II's dispatch-rate claims): message-set batching
//! and partition-parallel consumption.
//!
//! * batching — §II credits Kafka's rate to "message set abstractions:
//!   messages are grouped together amortizing the overhead of the
//!   network round trip". Sweep producer batch size with a calibrated
//!   in-cluster link and watch records/s.
//! * partitions — multi-consumer parallel fetch across 1/2/4 partitions.

use kafka_ml::benchkit::{Bench, Table};
use kafka_ml::broker::{
    BrokerConfig, ClientLocality, Cluster, Consumer, NetProfile, Producer, ProducerConfig,
    Record,
};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let records = 20_000usize;
    let payload = vec![7u8; 64];

    // ---- producer batching sweep -----------------------------------------
    let mut t = Table::new(
        "Producer message-set batching (20k x 64B records, in-cluster 250µs/leg)",
        &["batch size", "wall (s)", "records/s", "network round-trips"],
    );
    for batch in [1usize, 8, 64, 256] {
        let c = Cluster::new(BrokerConfig {
            net: NetProfile::calibrated(),
            ..Default::default()
        });
        c.create_topic("bt", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig {
                batch_size: batch,
                locality: ClientLocality::InCluster,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        for _ in 0..records {
            p.send_to("bt", 0, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let wall = t0.elapsed();
        t.row(&[
            batch.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{:.0}", records as f64 / wall.as_secs_f64()),
            c.metrics.counter("broker.produce.batches").get().to_string(),
        ]);
    }
    t.print();

    // ---- consumer parallelism across partitions ------------------------------
    let mut t = Table::new(
        "Partition-parallel consumption (80k x 64B records, no simulated net)",
        &["partitions/consumers", "wall (s)", "records/s"],
    );
    let total = 80_000usize;
    for parts in [1u32, 2, 4] {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("pt", parts);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 512, ..Default::default() },
        );
        for i in 0..total {
            p.send_to("pt", i as u32 % parts, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..parts)
            .map(|pi| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let mut cons = Consumer::new(c, ClientLocality::InCluster);
                    cons.assign(vec![("pt".to_string(), pi)]);
                    let mut got = 0usize;
                    loop {
                        let n = cons.poll(2048).unwrap().len();
                        if n == 0 {
                            break;
                        }
                        got += n;
                    }
                    got
                })
            })
            .collect();
        let got: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(got, total);
        let wall = t0.elapsed();
        t.row(&[
            parts.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{:.0}", total as f64 / wall.as_secs_f64()),
        ]);
    }
    t.print();

    // ---- fetch size sweep (zero-copy-ish batch reads) -------------------------
    let mut t = Table::new(
        "Fetch size sweep (80k records, single consumer)",
        &["max poll", "wall (s)", "records/s"],
    );
    let c = Cluster::new(BrokerConfig::default());
    c.create_topic("ft", 1);
    let mut p = Producer::new(
        c.clone(),
        ProducerConfig { batch_size: 512, ..Default::default() },
    );
    for _ in 0..total {
        p.send_to("ft", 0, Record::new(payload.clone()))?;
    }
    p.flush()?;
    let bench = Bench::new(1, 3);
    for max_poll in [16usize, 256, 4096] {
        let stats = bench.run(|| {
            let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
            cons.assign(vec![("ft".to_string(), 0)]);
            let mut got = 0usize;
            while got < total {
                got += cons.poll(max_poll).unwrap().len();
            }
        });
        t.row(&[
            max_poll.to_string(),
            format!("{:.3}", stats.mean_secs()),
            format!("{:.0}", total as f64 / stats.mean_secs()),
        ]);
    }
    t.print();
    Ok(())
}
