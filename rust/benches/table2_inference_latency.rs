//! **Table II** — Inference latency response (s).
//!
//! Paper (single record round-trip): normal 0.079 / data streams 0.374 /
//! data streams & containerization 0.335.
//!
//! The paper's inversion — containerized inference is *faster* than
//! plain streams — is a network-topology effect ("Kafka is deployed in
//! Kubernetes and thereby the network delay is smaller"): the
//! containerized replica reaches the broker over the in-cluster network,
//! while the plain-script replica pays the external link on both of its
//! legs. The calibrated NetProfile reproduces exactly that.
//!
//! Modes:
//!   * **normal** — direct `Engine::predict` per record (no broker);
//!   * **data streams** — replica runs as a plain thread with EXTERNAL
//!     broker locality; client external;
//!   * **streams & containerization** — replica runs as an orchestrator
//!     pod with IN-CLUSTER locality; client external. (Startup cost is
//!     not part of per-request latency, matching the paper.)

use kafka_ml::benchkit::{Bench, Table};
use kafka_ml::broker::{BrokerConfig, ClientLocality, NetProfile};
use kafka_ml::coordinator::inference::{run_inference_replica, InferenceReplicaConfig};
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::exec::CancelToken;
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;
use kafka_ml::orchestrator::OrchestratorCosts;
use kafka_ml::runtime::Engine;
use std::time::Duration;

fn raw() -> Json {
    Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ])
}

fn main() -> anyhow::Result<()> {
    let net = NetProfile::calibrated();
    println!("Table II reproduction — single-record inference round trips");
    println!(
        "calibration: external {}µs / in-cluster {}µs per leg",
        net.external_one_way.as_micros(),
        net.in_cluster_one_way.as_micros()
    );
    let requests = 100usize;
    let test = hcopd_dataset(requests, 8, 50);

    // Shared platform: train one model to serve.
    let kml = KafkaMl::start(KafkaMlConfig {
        broker: BrokerConfig { net, ..Default::default() },
        costs: OrchestratorCosts::calibrated(),
        ..Default::default()
    })?;
    let model = kml.create_model("t2")?;
    let conf = kml.create_configuration("t2", &[model])?;
    let dep = kml.deploy_training(conf, &TrainParams { epochs: 3, ..Default::default() })?;
    let train = hcopd_dataset(200, 8, 4);
    kml.send_stream(
        dep.id, &train.samples, "t2-data", "RAW", &raw(), 0.0,
        ClientLocality::External,
    )?;
    let results = kml.wait_training(&dep, Duration::from_secs(600))?;
    let result_id = results[0].id;

    // ---- mode 1: normal (direct engine) ---------------------------------
    let engine = Engine::load("artifacts")?;
    let params_host = kml.backend().download_model(result_id)?;
    let params = engine.inference_params(&params_host)?;
    let bench = Bench::new(10, requests);
    let mut i = 0usize;
    let normal = bench.run(|| {
        let s = &test.samples[i % requests];
        let _ = engine.predict(&params, &s.features, 1).unwrap();
        i += 1;
    });

    // ---- mode 2: data streams (replica as plain external process) --------
    let replica_cfg = InferenceReplicaConfig {
        inference_id: 9001,
        result_id,
        artifact_dir: "artifacts".into(),
        backend_url: kml.backend_url().to_string(),
        input_topic: "t2-in-plain".into(),
        output_topic: "t2-out-plain".into(),
        input_format: "RAW".into(),
        input_config: raw(),
        locality: ClientLocality::External, // plain script outside the cluster
        max_poll: 32,
        backend: kafka_ml::runtime::BackendSelect::Auto,
        api_key: None,
    };
    let cancel = CancelToken::new();
    let cluster: kafka_ml::broker::BrokerHandle = kml.cluster.clone();
    let cfg2 = replica_cfg.clone();
    let c2 = cancel.clone();
    let handle = std::thread::spawn(move || {
        run_inference_replica(&cluster, &cfg2, "plain-replica", &c2).ok();
    });
    let mut client = kml
        .inference_client(
            &kafka_ml::registry::InferenceDeployment {
                id: 9001,
                result_id,
                replicas: 1,
                input_topic: "t2-in-plain".into(),
                output_topic: "t2-out-plain".into(),
                input_format: "RAW".into(),
                input_config: raw(),
                tenant: kafka_ml::registry::DEFAULT_TENANT.into(),
            },
            ClientLocality::External,
        )?;
    let mut i = 0usize;
    let streams = bench.run(|| {
        let s = &test.samples[i % requests];
        client.request(&s.features, Duration::from_secs(10)).unwrap();
        i += 1;
    });
    cancel.cancel();
    handle.join().ok();

    // ---- mode 3: streams & containerization ------------------------------
    let inf = kml.deploy_inference(result_id, 1, "t2-in-pod", "t2-out-pod")?;
    let mut client = kml.inference_client(&inf, ClientLocality::External)?;
    let mut i = 0usize;
    let containers = bench.run(|| {
        let s = &test.samples[i % requests];
        client.request(&s.features, Duration::from_secs(10)).unwrap();
        i += 1;
    });
    kml.stop_inference(inf.id)?;

    let mut t = Table::new(
        "TABLE II — Inference latency response (s)",
        &["", "Normal", "Data streams", "Data streams & containerization"],
    );
    t.row(&[
        "measured".into(),
        format!("{:.5}", normal.mean_secs()),
        format!("{:.5}", streams.mean_secs()),
        format!("{:.5}", containers.mean_secs()),
    ]);
    t.row(&[
        "paper".into(),
        "0.079".into(),
        "0.374".into(),
        "0.335".into(),
    ]);
    t.print();
    println!(
        "\nshape check: streams/normal = {:.2}x (paper 4.73x), \
         containers/streams = {:.2}x (paper 0.90x — the in-cluster inversion)",
        streams.mean_secs() / normal.mean_secs(),
        containers.mean_secs() / streams.mean_secs(),
    );
    assert!(streams.mean > normal.mean);
    assert!(
        containers.mean < streams.mean,
        "containerized inference must be FASTER than plain streams (in-cluster net)"
    );
    kml.shutdown();
    Ok(())
}
