//! Small shared substrates: deterministic PRNG, clocks, byte helpers.
//!
//! Nothing here depends on the rest of the crate; everything else may
//! depend on this.

pub mod bytes;
pub mod clock;
pub mod logging;
pub mod rng;
pub mod sync;

pub use bytes::Bytes;
pub use clock::{Clock, ManualClock, SystemClock};
pub use rng::Rng;

/// Round `x` up to the next multiple of `mult` (mult > 0).
pub fn round_up(x: usize, mult: usize) -> usize {
    debug_assert!(mult > 0);
    x.div_ceil(mult) * mult
}

/// Human-readable byte size (`1.5 KiB`, `3.2 MiB`, …).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Human-readable duration with µs/ms/s autoscaling.
pub fn human_duration(d: std::time::Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(17, 5), 20);
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn human_duration_scales() {
        use std::time::Duration;
        assert_eq!(human_duration(Duration::from_micros(500)), "500.0µs");
        assert_eq!(human_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(human_duration(Duration::from_secs(2)), "2.000s");
    }
}
