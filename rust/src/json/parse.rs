//! Recursive-descent JSON parser (RFC 8259), with line/column errors.

use super::Json;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let mut line = 1;
        let mut col = 1;
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { msg: msg.to_string(), line, col }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, out: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(out)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..=0xDBFF).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..=0xDFFF).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b).ok_or_else(|| self.err("bad utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" é""#).unwrap(),
            Json::Str("a\nb\t\"c\" é".into())
        );
        // Surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // Raw multibyte utf-8 passthrough.
        assert_eq!(parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\x\"", "{a:1}"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn error_has_position() {
        let e = parse("{\n  \"a\": oops}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col > 1);
    }

    #[test]
    fn nested_structures() {
        let j = parse(r#"[{"a":[1,[2,{"b":null}]]}]"#).unwrap();
        assert_eq!(
            j.as_arr().unwrap()[0].at(&["a"]).as_arr().unwrap()[1]
                .as_arr()
                .unwrap()[1]
                .get("b"),
            &Json::Null
        );
    }

    #[test]
    fn whitespace_tolerated() {
        let j = parse(" \n\t { \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 2);
    }
}
