//! Data-stream reuse over the distributed log (§V, Fig 8).
//!
//! Because the broker retains records independently of consumption, a
//! data stream that was ingested once for deployment D1 can be handed to
//! D2, D3, … by re-sending only its *control message* (tens of bytes)
//! with the new `deployment_id` — as long as the window is still within
//! the retention horizon. This module implements that bookkeeping:
//! listing reusable streams, checking expiry against the live log, and
//! performing the re-send.

use super::control::{ControlMessage, StreamRef, CONTROL_TOPIC};
use crate::broker::{ClientLocality, ClusterHandle, Record};
use crate::registry::{ControlLogEntry, Store};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Why a logged stream can(not) be reused right now.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamAvailability {
    /// Fully within the log: reusable.
    Available,
    /// The log's start has moved past (part of) the window — Fig 8's
    /// "expiring/expired" stream.
    Expired { log_start: u64 },
    /// The topic/partition vanished entirely.
    Gone,
}

pub struct ReuseManager {
    cluster: ClusterHandle,
    store: Arc<Store>,
}

impl ReuseManager {
    pub fn new(cluster: ClusterHandle, store: Arc<Store>) -> ReuseManager {
        ReuseManager { cluster, store }
    }

    /// All logged streams with their live availability (the Web-UI list
    /// the paper describes: "users can see the list of the data streams
    /// sent to Kafka-ML and send again the data stream to other
    /// configurations").
    pub fn list_streams(&self) -> Vec<(ControlLogEntry, StreamAvailability)> {
        self.store
            .control_log()
            .into_iter()
            .map(|e| {
                let avail = self.availability(&e);
                (e, avail)
            })
            .collect()
    }

    pub fn availability(&self, entry: &ControlLogEntry) -> StreamAvailability {
        match self.cluster.offsets(&entry.topic, entry.partition) {
            Err(_) => StreamAvailability::Gone,
            Ok((earliest, _)) => {
                if entry.offset < earliest {
                    StreamAvailability::Expired { log_start: earliest }
                } else {
                    StreamAvailability::Available
                }
            }
        }
    }

    /// Re-send the latest stream of `from_deployment` to `to_deployment`
    /// (Fig 8: C1 re-sent so D2 consumes the same green data). Returns
    /// the control message sent. Costs one control record — the data
    /// stream itself is NOT re-transmitted.
    pub fn resend(
        &self,
        from_deployment: u64,
        to_deployment: u64,
        locality: ClientLocality,
    ) -> Result<ControlMessage> {
        let entry = self
            .store
            .last_control_for(from_deployment)
            .ok_or_else(|| {
                anyhow::anyhow!("no logged stream for deployment {from_deployment}")
            })?;
        match self.availability(&entry) {
            StreamAvailability::Available => {}
            StreamAvailability::Expired { log_start } => bail!(
                "stream {} of deployment {from_deployment} has expired \
                 (log now starts at {log_start}); the data must be re-sent",
                StreamRef::new(&entry.topic, entry.partition, entry.offset, entry.length)
                    .format()
            ),
            StreamAvailability::Gone => {
                bail!("topic {} no longer exists", entry.topic)
            }
        }
        let msg = ControlMessage {
            deployment_id: to_deployment,
            stream: StreamRef::new(&entry.topic, entry.partition, entry.offset, entry.length),
            input_format: entry.input_format.clone(),
            input_config: entry.input_config.clone(),
            validation_rate: entry.validation_rate,
            total_msg: entry.total_msg,
        };
        self.cluster.topic_or_create(CONTROL_TOPIC);
        self.cluster.produce(
            CONTROL_TOPIC,
            0,
            &[Record::new(msg.encode())],
            locality,
            None,
        )?;
        self.cluster
            .metrics
            .counter("kafka_ml.streams.reused")
            .inc();
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, CleanupPolicy, Cluster, LogConfig};
    use crate::json::Json;
    use crate::util::clock::ManualClock;

    fn entry(dep: u64, topic: &str, offset: u64, length: u64) -> ControlLogEntry {
        ControlLogEntry {
            deployment_id: dep,
            topic: topic.to_string(),
            partition: 0,
            offset,
            length,
            input_format: "RAW".into(),
            input_config: Json::obj(vec![
                ("dtype", Json::str("f32")),
                ("shape", Json::arr(vec![Json::from(2u64)])),
            ]),
            validation_rate: 0.1,
            total_msg: length,
            logged_ms: 0,
        }
    }

    fn fill(c: &ClusterHandle, topic: &str, n: usize) {
        c.create_topic(topic, 1);
        for i in 0..n {
            c.produce(
                topic,
                0,
                &[Record::new(vec![i as u8; 8])],
                ClientLocality::InCluster,
                None,
            )
            .unwrap();
        }
    }

    #[test]
    fn resend_copies_stream_with_new_deployment() {
        let c = Cluster::new(BrokerConfig::default());
        fill(&c, "data", 100);
        let store = Arc::new(Store::new());
        store.log_control(entry(1, "data", 0, 100));
        let rm = ReuseManager::new(c.clone(), store);
        let msg = rm.resend(1, 2, ClientLocality::InCluster).unwrap();
        assert_eq!(msg.deployment_id, 2);
        assert_eq!(msg.stream.format(), "[data:0:0:100]");
        assert_eq!(msg.input_format, "RAW");
        // The control topic received exactly one new message.
        let (_, latest) = c.offsets(CONTROL_TOPIC, 0).unwrap();
        assert_eq!(latest, 1);
        // And it decodes to the re-targeted message.
        let recs = c.fetch(CONTROL_TOPIC, 0, 0, 10, ClientLocality::InCluster).unwrap();
        let decoded = ControlMessage::decode(&recs[0].record.value).unwrap();
        assert_eq!(decoded.deployment_id, 2);
    }

    #[test]
    fn expired_stream_cannot_be_reused() {
        let clock = ManualClock::new(1_000);
        let c = Cluster::with_clock(
            BrokerConfig {
                log: LogConfig {
                    segment_bytes: 128,
                    retention_ms: Some(500),
                    retention_bytes: None,
                    cleanup_policy: CleanupPolicy::Delete,
                    ..LogConfig::default()
                },
                ..Default::default()
            },
            std::sync::Arc::new(clock.clone()),
        );
        fill(&c, "data", 50);
        let store = Arc::new(Store::new());
        store.log_control(entry(1, "data", 0, 50));
        let rm = ReuseManager::new(c.clone(), store);
        assert_eq!(
            rm.availability(&entry(1, "data", 0, 50)),
            StreamAvailability::Available
        );
        // Let it expire.
        clock.advance_ms(60_000);
        fill(&c, "data", 5); // fresh segment so old ones can drop
        c.run_retention();
        match rm.availability(&entry(1, "data", 0, 50)) {
            StreamAvailability::Expired { log_start } => assert!(log_start > 0),
            other => panic!("expected Expired, got {other:?}"),
        }
        let err = rm.resend(1, 2, ClientLocality::InCluster).unwrap_err();
        assert!(err.to_string().contains("expired"), "{err}");
    }

    #[test]
    fn availability_survives_cluster_restart() {
        use crate::broker::StorageMode;
        // With tiered storage the Expired-vs-Available verdict must be
        // answerable after a full cluster restart, from the log start
        // recovered off the segment files on disk.
        let data_dir = std::env::temp_dir().join(format!("kafka-ml-reuse-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&data_dir);
        let clock = ManualClock::new(1_000);
        let config = BrokerConfig {
            log: LogConfig {
                segment_bytes: 128,
                retention_ms: Some(500),
                retention_bytes: None,
                cleanup_policy: CleanupPolicy::Delete,
                storage: StorageMode::Tiered {
                    data_dir: data_dir.clone(),
                },
                ..LogConfig::default()
            },
            ..Default::default()
        };
        let store = Arc::new(Store::new());
        store.log_control(entry(1, "old-data", 0, 50));
        store.log_control(entry(2, "live-data", 0, 10));
        {
            let c = Cluster::with_clock(config.clone(), Arc::new(clock.clone()));
            fill(&c, "old-data", 50);
            clock.advance_ms(60_000);
            fill(&c, "old-data", 5); // fresh tail so old segments can drop
            c.run_retention(); // deletes the expired segment *files*
            fill(&c, "live-data", 10);
            let rm = ReuseManager::new(c.clone(), store.clone());
            // Pre-restart verdicts, for comparison below.
            let old = rm.availability(&entry(1, "old-data", 0, 50));
            assert!(matches!(old, StreamAvailability::Expired { .. }));
            let live = rm.availability(&entry(2, "live-data", 0, 10));
            assert_eq!(live, StreamAvailability::Available);
            c.flush_storage().unwrap();
        } // cluster dropped: the "restart"

        let c = Cluster::with_clock(config, Arc::new(clock.clone()));
        let rm = ReuseManager::new(c.clone(), store);
        match rm.availability(&entry(1, "old-data", 0, 50)) {
            StreamAvailability::Expired { log_start } => {
                assert!(log_start > 0, "recovered log start must reflect retention");
            }
            other => panic!("expected Expired after restart, got {other:?}"),
        }
        let live = rm.availability(&entry(2, "live-data", 0, 10));
        assert_eq!(live, StreamAvailability::Available);
        // And the still-available stream is actually re-sendable.
        let msg = rm.resend(2, 3, ClientLocality::InCluster).unwrap();
        assert_eq!(msg.stream.format(), "[live-data:0:0:10]");
        drop(rm);
        drop(c);
        let _ = std::fs::remove_dir_all(&data_dir);
    }

    #[test]
    fn unknown_topic_is_gone() {
        let c = Cluster::new(BrokerConfig::default());
        let store = Arc::new(Store::new());
        let rm = ReuseManager::new(c, store);
        assert_eq!(rm.availability(&entry(1, "ghost", 0, 5)), StreamAvailability::Gone);
    }

    #[test]
    fn resend_without_log_entry_errors() {
        let c = Cluster::new(BrokerConfig::default());
        let rm = ReuseManager::new(c, Arc::new(Store::new()));
        assert!(rm.resend(1, 2, ClientLocality::InCluster).is_err());
    }

    #[test]
    fn list_streams_reports_mixed_availability() {
        let c = Cluster::new(BrokerConfig::default());
        fill(&c, "live", 10);
        let store = Arc::new(Store::new());
        store.log_control(entry(1, "live", 0, 10));
        store.log_control(entry(2, "ghost", 0, 10));
        let rm = ReuseManager::new(c, store);
        let list = rm.list_streams();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].1, StreamAvailability::Available);
        assert_eq!(list[1].1, StreamAvailability::Gone);
    }
}
