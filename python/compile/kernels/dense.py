"""Fused dense layer (``activation(x @ W + b)``) as a Pallas kernel.

This is the FLOPs hot spot of the Kafka-ML model (every training step and
every inference is dominated by the dense layers), so it is the kernel the
three-layer architecture pushes down to Pallas.

TPU-oriented structure (see DESIGN.md §Hardware-Adaptation):
  * the grid tiles the output as ``(M/bm, N/bn)`` blocks; each program
    keeps an ``(bm, K)`` x-tile and a ``(K, bn)`` w-tile resident in VMEM
    via ``BlockSpec`` — the HBM↔VMEM schedule the paper's CPU/TF stack
    leaves implicit;
  * the inner contraction uses ``jnp.dot`` with
    ``preferred_element_type=float32`` so the MXU accumulates in f32 even
    for bf16 inputs;
  * ragged edges are handled by zero-padding in the wrapper (cheap at
    these sizes, and keeps the kernel branch-free).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is the correctness path on this image and
real-TPU performance is estimated analytically (EXPERIMENTS.md §Perf).

The backward pass is *also* Pallas: ``dense`` carries a ``custom_vjp``
whose cotangents are computed with the same matmul kernel
(``dx = g @ W^T``, ``dW = x^T @ g``), so ``jax.grad`` through the model
never leaves Layer 1 for its heavy lifting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile sizes. 128 matches the MXU lane width; tiles are
# shrunk (to padded-to-8 sizes) for the small shapes Kafka-ML's HCOPD
# model actually uses so interpret-mode tests stay fast.
BLOCK_M = 128
BLOCK_N = 128


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pick_block(dim: int, block: int) -> int:
    """Tile size: full (padded) extent for small dims, ``block`` otherwise."""
    return min(_round_up(dim, 8), block)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One ``(bm, bn)`` output tile: f32 accumulate, bias, activation."""
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _dense_impl(x, w, b, activation, block_m=BLOCK_M, block_n=BLOCK_N):
    if activation not in ("linear", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm, bn = _pick_block(m, block_m), _pick_block(n, block_n)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, 8)

    # Zero-pad ragged edges; padding contributes 0 to the contraction and
    # is sliced off after the call.
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))

    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def matmul(a, b):
    """Plain ``a @ b`` through the dense kernel (zero bias, linear).

    Used by the custom VJP so the backward matmuls also run in Pallas.
    """
    zeros = jnp.zeros((b.shape[1],), dtype=a.dtype)
    return _dense_impl(a, b, zeros, "linear")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="linear"):
    """Fused ``activation(x @ w + b)``; differentiable via custom VJP.

    Args:
      x: ``(m, k)`` input activations.
      w: ``(k, n)`` weights.
      b: ``(n,)`` bias.
      activation: ``"linear"`` or ``"relu"``.
    """
    return _dense_impl(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    out = _dense_impl(x, w, b, activation)
    # Residuals: x and w for the matmul cotangents, out for the relu mask.
    return out, (x, w, out)


def _dense_bwd(activation, res, g):
    x, w, out = res
    if activation == "relu":
        # d relu = 1 where the *post*-activation output is positive.
        g = g * (out > 0).astype(g.dtype)
    dx = matmul(g, w.T)                       # (m, n) @ (n, k)
    dw = matmul(x.T, g)                       # (k, m) @ (m, n)
    db = jnp.sum(g.astype(jnp.float32), axis=0).astype(g.dtype)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
