"""Pallas softmax kernel vs oracle + invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import softmax
from compile.kernels.ref import softmax_ref

DTYPES = [jnp.float32, jnp.bfloat16]


@given(
    m=st.integers(1, 64),
    n=st.integers(1, 40),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_matches_ref(m, n, dt, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=3.0, size=(m, n)), dt)
    got, want = softmax(x), softmax_ref(x)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-6
    assert got.shape == x.shape and got.dtype == dt
    assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@given(m=st.integers(1, 32), n=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_rows_sum_to_one_and_nonnegative(m, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=5.0, size=(m, n)), jnp.float32)
    out = np.asarray(softmax(x))
    assert (out >= 0).all()
    assert_allclose(out.sum(axis=-1), np.ones(m), rtol=1e-5, atol=1e-5)


def test_softmax_stable_for_large_logits():
    x = jnp.asarray([[1000.0, 1000.0, -1000.0]], jnp.float32)
    out = np.asarray(softmax(x))
    assert np.isfinite(out).all()
    assert_allclose(out[0, :2], [0.5, 0.5], atol=1e-6)
    assert out[0, 2] == 0.0


def test_softmax_multirow_blocks():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(600, 4)), jnp.float32)  # > BLOCK_M rows
    assert_allclose(
        np.asarray(softmax(x)), np.asarray(softmax_ref(x)), rtol=1e-6, atol=1e-6
    )
