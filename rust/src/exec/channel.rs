//! MPMC channels with optional capacity bound (blocking backpressure).
//!
//! `std::sync::mpsc` is single-consumer; Kafka-ML's consumer groups and
//! worker pools need multi-consumer queues, and the paper's ingestion
//! path needs *backpressure* (a bounded queue whose `send` blocks when
//! the downstream is slower — §coordinator::backpressure builds on this).

use crate::util::sync::wait_deadline;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// All senders dropped and the queue is drained.
    Disconnected,
    /// `recv_timeout` elapsed.
    Timeout,
}

#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

struct Shared<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: Option<usize>,
}

struct State<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Bounded channel: `send` blocks while full (backpressure).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    make(Some(capacity.max(1)))
}

/// Unbounded channel.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    make(None)
}

fn make<T>(capacity: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(State { items: VecDeque::new(), senders: 1, receivers: 1 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; errors only when every receiver is gone.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError(item));
            }
            match self.shared.capacity {
                Some(cap) if st.items.len() >= cap => {
                    st = self.shared.not_full.wait(st).unwrap();
                }
                _ => break,
            }
        }
        st.items.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send; returns the item back if the queue is full.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.queue.lock().unwrap();
        if st.receivers == 0 {
            return Err(SendError(item));
        }
        if let Some(cap) = self.shared.capacity {
            if st.items.len() >= cap {
                return Err(SendError(item));
            }
        }
        st.items.push_back(item);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Blocking receive with a relative timeout.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Result<T, RecvError> {
        self.recv_deadline(Instant::now() + timeout)
    }

    /// Blocking receive with an **absolute** deadline. Callers that
    /// drain in a loop (e.g. the ingestion backpressure drain) compute
    /// the deadline once per flush window instead of re-deriving a
    /// relative timeout on every spin; the condvar discipline is the
    /// crate-wide [`wait_deadline`] helper the broker's waiters share.
    pub fn recv_deadline(&self, deadline: Instant) -> Result<T, RecvError> {
        let mut st = self.shared.queue.lock().unwrap();
        let mut timed_out = false;
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            if timed_out {
                return Err(RecvError::Timeout);
            }
            (st, timed_out) = wait_deadline(&self.shared.not_empty, st, deadline);
        }
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.shared.queue.lock().unwrap();
        if let Some(item) = st.items.pop_front() {
            drop(st);
            self.shared.not_full.notify_one();
            Ok(item)
        } else if st.senders == 0 {
            Err(TryRecvError::Disconnected)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = unbounded();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn disconnect_on_sender_drop() {
        let (tx, rx) = unbounded::<u32>();
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = unbounded::<u32>();
        drop(rx);
        assert_eq!(tx.send(5), Err(SendError(5)));
    }

    #[test]
    fn bounded_backpressure_blocks_then_unblocks() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(SendError(3)));
        let h = std::thread::spawn(move || tx.send(3));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1); // frees a slot
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn multi_consumer_each_item_once() {
        let (tx, rx) = unbounded::<u32>();
        let n = 1000u32;
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = unbounded::<u32>();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvError::Timeout)
        );
    }

    #[test]
    fn recv_deadline_times_out_at_deadline() {
        let (_tx, rx) = unbounded::<u32>();
        let t0 = Instant::now();
        let deadline = t0 + Duration::from_millis(20);
        assert_eq!(rx.recv_deadline(deadline), Err(RecvError::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // An already-passed deadline fails fast (no park).
        assert_eq!(rx.recv_deadline(Instant::now()), Err(RecvError::Timeout));
    }

    #[test]
    fn recv_deadline_returns_item_sent_before_deadline() {
        let (tx, rx) = unbounded::<u32>();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(42).unwrap();
        });
        let t0 = Instant::now();
        assert_eq!(rx.recv_deadline(t0 + Duration::from_secs(5)), Ok(42));
        assert!(t0.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
    }

    #[test]
    fn recv_deadline_disconnect_beats_timeout() {
        let (tx, rx) = unbounded::<u32>();
        drop(tx);
        assert_eq!(
            rx.recv_deadline(Instant::now() + Duration::from_secs(5)),
            Err(RecvError::Disconnected)
        );
    }
}
