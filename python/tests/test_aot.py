"""AOT lowering: artifacts exist, are valid HLO text, meta is consistent."""

import json
import os

import pytest

from compile.aot import compile_artifacts
from compile.model import ModelSpec


@pytest.fixture(scope="module")
def out(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("artifacts"))
    spec = ModelSpec(input_dim=6, hidden=(8,), classes=3, batch=4, seed=1)
    meta = compile_artifacts(spec, d, verbose=False)
    return d, spec, meta


def test_all_artifacts_written(out):
    d, spec, meta = out
    for art in meta["artifacts"].values():
        path = os.path.join(d, art["file"])
        assert os.path.exists(path), art["file"]
        assert os.path.getsize(path) > 100


def test_hlo_text_is_parseable_module(out):
    d, _, meta = out
    for art in meta["artifacts"].values():
        text = open(os.path.join(d, art["file"])).read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # Interchange must be text, never a serialized proto.
        assert not text.startswith("\x08")


def test_meta_matches_spec(out):
    d, spec, meta = out
    disk = json.load(open(os.path.join(d, "meta.json")))
    assert disk == meta
    assert disk["spec"]["input_dim"] == spec.input_dim
    assert disk["spec"]["batch"] == spec.batch
    n = 2 * spec.n_layers
    assert len(disk["params"]) == n
    assert disk["artifacts"]["train_step"]["n_params"] == n
    assert disk["artifacts"]["predict_single"]["batch"] == 1


def test_param_entry_counts_in_hlo(out):
    """train_step HLO must declare 3n+3 parameters (p, m, v, t, x, y)."""
    d, spec, meta = out
    n = 2 * spec.n_layers
    text = open(os.path.join(d, meta["artifacts"]["train_step"]["file"])).read()
    entry = text[text.index("ENTRY"):]
    body = entry[:entry.index("\n", entry.index("parameter"))]
    count = entry.count("parameter(")
    assert count == 3 * n + 3, f"expected {3*n+3} params, found {count}"


def test_predict_declares_params_plus_input(out):
    d, spec, meta = out
    n = 2 * spec.n_layers
    text = open(os.path.join(d, meta["artifacts"]["predict"]["file"])).read()
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == n + 1
