//! A partition: one segmented log plus replication bookkeeping (leader
//! broker, replica set, in-sync replicas). Replication is simulated at
//! metadata level — §IV-F of the paper runs one Kafka broker per pod and
//! relies on partition replicas for fault tolerance; what matters for
//! Kafka-ML's behaviour is leader failover, which [`super::Cluster`]
//! exercises via `kill_broker`.

use super::log::{LogConfig, SegmentedLog};
use super::notify::WaitSet;
use super::record::Record;
use crate::util::clock::SharedClock;
use std::collections::HashMap;
use std::sync::Arc;

/// Idempotent-producer state: highest sequence number seen per producer.
#[derive(Debug, Default)]
struct ProducerSeqs {
    seqs: HashMap<u64, u64>,
}

#[derive(Debug)]
pub struct Partition {
    pub topic: String,
    pub index: u32,
    pub leader: usize,
    pub replicas: Vec<usize>,
    pub isr: Vec<usize>,
    log: SegmentedLog,
    producer_seqs: ProducerSeqs,
    /// Replication high-watermark: offsets below this are known
    /// replicated to the follower. Under `acks=replicated` both produce
    /// acks and consumer visibility gate here; under `acks=leader` it
    /// trails `latest_offset` and nothing reads it.
    high_watermark: u64,
    /// Consumers parked on this partition; appends signal it. Shared
    /// (`Arc`) so [`super::Topic`] can hand out registration handles
    /// without taking the partition mutex.
    wait_set: Arc<WaitSet>,
}

impl Partition {
    pub fn new(
        topic: &str,
        index: u32,
        leader: usize,
        replicas: Vec<usize>,
        config: LogConfig,
        clock: SharedClock,
    ) -> Partition {
        let isr = replicas.clone();
        // In tiered mode `open` recovers sealed segments from the
        // partition's data dir; an unusable data dir is a fatal
        // misconfiguration, surfaced loudly rather than degraded
        // silently to in-memory (which would break durability).
        let log = SegmentedLog::open(config, clock, topic, index)
            .unwrap_or_else(|e| panic!("opening log for {topic}:{index}: {e:#}"));
        let high_watermark = log.latest_offset();
        Partition {
            topic: topic.to_string(),
            index,
            leader,
            replicas,
            isr,
            log,
            producer_seqs: ProducerSeqs::default(),
            high_watermark,
            wait_set: Arc::new(WaitSet::new()),
        }
    }

    /// Offsets below this are replicated to the follower. Recovered
    /// logs start with the watermark at `latest_offset` (everything on
    /// disk is the durable prefix by definition).
    pub fn high_watermark(&self) -> u64 {
        self.high_watermark
    }

    /// Raise the high-watermark (monotonic; never past `latest_offset`)
    /// and wake parked waiters — producers blocked on a replicated ack
    /// and watermark-gated consumers both park on this partition's
    /// wait-set.
    pub fn advance_high_watermark(&mut self, hwm: u64) {
        let hwm = hwm.min(self.log.latest_offset());
        if hwm > self.high_watermark {
            self.high_watermark = hwm;
            self.wait_set.notify_all();
        }
    }

    /// The wait-set consumers park on to be woken by appends.
    pub fn wait_set(&self) -> &Arc<WaitSet> {
        &self.wait_set
    }

    /// Append, de-duplicating on `(producer_id, seq)` when provided —
    /// the exactly-once path. Returns `(offset, was_duplicate)` and
    /// wakes any consumer parked on this partition.
    pub fn append(
        &mut self,
        record: Record,
        producer_seq: Option<(u64, u64)>,
    ) -> (u64, bool) {
        let res = self.append_quiet(record, producer_seq);
        self.wait_set.notify_all();
        res
    }

    /// Append a whole message set under the one lock hold the caller
    /// already has, signalling parked consumers **once** for the batch
    /// instead of once per record. Returns the base offset of the first
    /// non-duplicate append (`None` = the entire batch was an idempotent
    /// replay).
    pub fn append_batch(
        &mut self,
        records: &[Record],
        producer_seq: Option<(u64, u64)>,
    ) -> Option<u64> {
        let mut base = None;
        for (i, r) in records.iter().enumerate() {
            let seq = producer_seq.map(|(pid, s)| (pid, s + i as u64));
            let (off, dup) = self.append_quiet(r.clone(), seq);
            if base.is_none() && !dup {
                base = Some(off);
            }
        }
        if !records.is_empty() {
            self.wait_set.notify_all();
        }
        base
    }

    fn append_quiet(
        &mut self,
        record: Record,
        producer_seq: Option<(u64, u64)>,
    ) -> (u64, bool) {
        if let Some((pid, seq)) = producer_seq {
            let last = self.producer_seqs.seqs.get(&pid).copied();
            if let Some(last_seq) = last {
                if seq <= last_seq {
                    // Duplicate of an already-appended batch member.
                    return (self.log.latest_offset().saturating_sub(1), true);
                }
            }
            self.producer_seqs.seqs.insert(pid, seq);
        }
        (self.log.append(record), false)
    }

    /// Read takes `&mut self` because sealed-segment reads may load a
    /// file into the residency LRU; callers already hold the partition
    /// mutex, so this costs nothing extra.
    pub fn read(&mut self, from: u64, max: usize) -> Vec<(u64, Record)> {
        self.log.read(from, max)
    }

    /// Seal the active segment to disk (tiered storage; no-op in
    /// memory mode) so a subsequent reopen recovers every record.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.log.flush()
    }

    /// Bytes of sealed-segment buffers currently resident in memory.
    pub fn resident_bytes(&self) -> usize {
        self.log.resident_bytes()
    }

    /// The effective log configuration (recovered topics keep their
    /// persisted per-topic overrides — see `topic.meta`).
    pub fn log_config(&self) -> &LogConfig {
        self.log.config()
    }

    pub fn earliest_offset(&self) -> u64 {
        self.log.earliest_offset()
    }

    pub fn latest_offset(&self) -> u64 {
        self.log.latest_offset()
    }

    pub fn size_bytes(&self) -> u64 {
        self.log.size_bytes()
    }

    pub fn len(&self) -> u64 {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    pub fn enforce_retention(&mut self) -> u64 {
        self.log.enforce_retention()
    }

    /// Leader failover: remove `broker` from ISR; if it led, promote the
    /// next in-sync replica. Returns the new leader (None = offline).
    pub fn handle_broker_down(&mut self, broker: usize) -> Option<usize> {
        self.isr.retain(|&b| b != broker);
        if self.leader == broker {
            match self.isr.first() {
                Some(&next) => {
                    self.leader = next;
                    Some(next)
                }
                None => None,
            }
        } else {
            Some(self.leader)
        }
    }

    /// A recovered broker rejoins the ISR.
    pub fn handle_broker_up(&mut self, broker: usize) {
        if self.replicas.contains(&broker) && !self.isr.contains(&broker) {
            self.isr.push(broker);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::system_clock;

    fn part() -> Partition {
        Partition::new("t", 0, 0, vec![0, 1, 2], LogConfig::default(), system_clock())
    }

    #[test]
    fn append_and_read() {
        let mut p = part();
        let (o0, dup0) = p.append(Record::new(vec![1]), None);
        let (o1, _) = p.append(Record::new(vec![2]), None);
        assert_eq!((o0, o1), (0, 1));
        assert!(!dup0);
        assert_eq!(p.read(0, 10).len(), 2);
    }

    #[test]
    fn append_signals_parked_waiter() {
        use crate::broker::notify::Waiter;
        let mut p = part();
        let waiter = Waiter::new();
        p.wait_set().register(&waiter);
        let seen = waiter.generation();
        p.append(Record::new(vec![1]), None);
        // Generation advanced => a parked consumer would have woken.
        assert!(waiter.wait_until(seen, std::time::Instant::now()));
    }

    #[test]
    fn append_batch_appends_all_and_signals() {
        use crate::broker::notify::Waiter;
        let mut p = part();
        let waiter = Waiter::new();
        p.wait_set().register(&waiter);
        let seen = waiter.generation();
        let batch: Vec<Record> = (0..4u8).map(|i| Record::new(vec![i])).collect();
        let base = p.append_batch(&batch, None);
        assert_eq!(base, Some(0));
        assert_eq!(p.len(), 4);
        assert!(waiter.wait_until(seen, std::time::Instant::now()));
        // Idempotent replay of the same seq range: no base, no growth.
        let (_, d) = p.append(Record::new(vec![9]), Some((3, 1)));
        assert!(!d);
        assert_eq!(p.append_batch(&batch[..1], Some((3, 1))), None);
        assert_eq!(p.len(), 5);
    }

    #[test]
    fn idempotent_dedup() {
        let mut p = part();
        let (_, d1) = p.append(Record::new(vec![1]), Some((7, 1)));
        let (_, d2) = p.append(Record::new(vec![1]), Some((7, 1))); // retry
        let (_, d3) = p.append(Record::new(vec![2]), Some((7, 2)));
        assert!(!d1 && d2 && !d3);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn distinct_producers_do_not_collide() {
        let mut p = part();
        p.append(Record::new(vec![1]), Some((1, 1)));
        let (_, dup) = p.append(Record::new(vec![2]), Some((2, 1)));
        assert!(!dup);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn failover_promotes_next_isr() {
        let mut p = part();
        assert_eq!(p.leader, 0);
        assert_eq!(p.handle_broker_down(0), Some(1));
        assert_eq!(p.leader, 1);
        assert!(!p.isr.contains(&0));
    }

    #[test]
    fn failover_of_non_leader_keeps_leader() {
        let mut p = part();
        assert_eq!(p.handle_broker_down(2), Some(0));
        assert_eq!(p.leader, 0);
    }

    #[test]
    fn all_replicas_down_is_offline() {
        let mut p = part();
        p.handle_broker_down(1);
        p.handle_broker_down(2);
        assert_eq!(p.handle_broker_down(0), None);
    }

    #[test]
    fn high_watermark_is_monotonic_and_capped() {
        use crate::broker::notify::Waiter;
        let mut p = part();
        assert_eq!(p.high_watermark(), 0);
        p.append(Record::new(vec![1]), None);
        p.append(Record::new(vec![2]), None);
        let waiter = Waiter::new();
        p.wait_set().register(&waiter);
        let seen = waiter.generation();
        p.advance_high_watermark(1);
        assert_eq!(p.high_watermark(), 1);
        // A raise signals parked waiters (replicated-ack producers).
        assert!(waiter.wait_until(seen, std::time::Instant::now()));
        p.advance_high_watermark(99); // capped at latest_offset
        assert_eq!(p.high_watermark(), 2);
        p.advance_high_watermark(0); // monotonic: never regresses
        assert_eq!(p.high_watermark(), 2);
    }

    #[test]
    fn recovered_broker_rejoins_isr() {
        let mut p = part();
        p.handle_broker_down(2);
        assert_eq!(p.isr, vec![0, 1]);
        p.handle_broker_up(2);
        assert_eq!(p.isr, vec![0, 1, 2]);
        p.handle_broker_up(9); // not a replica: ignored
        assert_eq!(p.isr, vec![0, 1, 2]);
    }
}
