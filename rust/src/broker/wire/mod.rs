//! The TCP wire protocol: the broker as a real network service.
//!
//! Four pieces, all plain `std::net` plus a thin vendored FFI shim
//! (the build is hermetic — no tokio, no serde, no mio):
//!
//! * [`codec`] — the binary frame format. Every request and response is
//!   one length-prefixed, CRC-32-checksummed frame (the same framing
//!   discipline as the on-disk segment format,
//!   `broker/log/format.rs`), and records travel *as* segment-format
//!   record frames, so both sides decode them zero-copy into
//!   [`crate::util::Bytes`] slice views of the received buffer. Fetch
//!   responses can also be *encoded* zero-copy, as gather-write chunk
//!   lists whose record payloads alias the broker log
//!   ([`codec::encode_fetch_response_chunks`]).
//! * [`reactor`] — the event-loop substrate: a readiness [`Poller`]
//!   (epoll on Linux, portable `poll(2)` elsewhere), an eventfd/pipe
//!   [`WakeFd`] for cross-thread wakeups, and vectored
//!   [`writev`](reactor::writev) — all over the vendored `libc` shim.
//! * [`server`] — [`BrokerServer`]: an epoll reactor thread plus a
//!   small request worker pool, serving a [`crate::broker::Cluster`].
//!   Thread count is O(worker pool), not O(connections). Blocking
//!   long-polls (`FetchWait`) park **server-side** as registrations on
//!   the broker's [`crate::broker::notify`] wait-sets, bridged to the
//!   reactor through a wake hook — the wire carries the deadline in
//!   the request and the wakeup in the response, so a parked remote
//!   consumer reacts to a produce in one socket round trip, with zero
//!   polling on the wire and zero threads per parked connection.
//!   Shutdown rides the crate's cancel primitives and unblocks every
//!   connection deterministically.
//! * [`client`] — [`RemoteBroker`]: the socket client implementing
//!   [`crate::broker::BrokerTransport`], with a small connection pool
//!   and transparent reconnect, so `Producer`/`Consumer`/coordinator
//!   jobs run against a broker in another OS process exactly as they
//!   run in-process.
//!
//! On this path the *real* network replaces the simulated
//! [`crate::broker::NetProfile`] delay — the server dispatches every
//! operation with [`crate::broker::ClientLocality::Remote`], whose
//! traversal is always free.

pub mod client;
pub mod codec;
pub mod reactor;
pub mod server;

pub use client::RemoteBroker;
pub use reactor::{Poller, WakeFd};
pub use server::BrokerServer;
