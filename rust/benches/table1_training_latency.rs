//! **Table I** — Training latency response (s).
//!
//! Paper (HCOPD, MacBook Pro, epochs=1000): normal 27.37 / data streams
//! 29.61 / data streams & containerization 31.44.
//!
//! Three modes, identical workload (220 synthetic HCOPD samples, batch
//! 10, shuffle, Adam 1e-4):
//!   * **normal** — samples already in memory; the bare training loop on
//!     the PJRT engine (the paper's plain TF script).
//!   * **data streams** — the stream is produced to the broker by an
//!     *external* client and the training job (run inline, no containers)
//!     waits for the control message, reads the log window and uploads
//!     the trained model to the back-end.
//!   * **streams & containerization** — the job additionally runs as an
//!     orchestrator Job (image pull + schedule + container start,
//!     calibrated costs) on the in-cluster network.
//!
//! Absolute numbers differ from the paper's testbed; the expected SHAPE
//! is normal < streams < streams+containers, with the container penalty
//! ≈ the orchestrator startup cost. Epochs are scaled down (default 20,
//! override with KML_BENCH_EPOCHS) so the bench stays minutes, not hours.

use kafka_ml::benchkit::{secs, Bench, Table};
use kafka_ml::broker::{BrokerConfig, ClientLocality, NetProfile};
use kafka_ml::coordinator::training::{run_training_job, train_on_samples};
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams, TrainingJobConfig};
use kafka_ml::exec::CancelToken;
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;
use kafka_ml::orchestrator::OrchestratorCosts;
use kafka_ml::runtime::Engine;
use std::time::Duration;

fn raw() -> Json {
    Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ])
}

fn epochs() -> usize {
    std::env::var("KML_BENCH_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20)
}

fn main() -> anyhow::Result<()> {
    let epochs = epochs();
    let net = NetProfile::calibrated();
    let costs = OrchestratorCosts::calibrated();
    println!("Table I reproduction — epochs={epochs}, 220 samples, batch 10");
    println!(
        "calibration: external {}µs / in-cluster {}µs per leg; container start \
         {}+{}+{}ms",
        net.external_one_way.as_micros(),
        net.in_cluster_one_way.as_micros(),
        costs.image_pull.as_millis(),
        costs.schedule_delay.as_millis(),
        costs.container_start.as_millis(),
    );

    let bench = Bench::new(1, 3);
    let ds = hcopd_dataset(220, 8, 42);

    // ---- mode 1: normal -------------------------------------------------
    // Includes model build+compile (Engine::load), exactly like the
    // paper's plain TF script builds its Keras model each run — modes 2
    // and 3 pay the same cost inside run_training_job.
    let normal = bench.run(|| {
        let engine = Engine::load("artifacts").unwrap();
        let (_params, _out) = train_on_samples(
            &engine,
            ds.samples.clone(),
            0.0,
            epochs,
            true,
            42,
            &CancelToken::new(),
        )
        .unwrap();
    });

    // ---- mode 2: data streams (no containers) ------------------------------
    let kml = KafkaMl::start(KafkaMlConfig {
        broker: BrokerConfig { net, ..Default::default() },
        control_logger: false,
        ..Default::default()
    })?;
    let model = kml.create_model("t1")?;
    let conf = kml.create_configuration("t1", &[model])?;
    let streams = bench.run(|| {
        // Fresh deployment per iteration (results are single-use rows).
        let dep = kml.store.create_deployment(conf, 10, epochs, true).unwrap();
        kml.send_stream(
            dep.id,
            &ds.samples,
            "t1-data",
            "RAW",
            &raw(),
            0.0,
            ClientLocality::External,
        )
        .unwrap();
        let mut cfg = TrainingJobConfig::new(
            dep.id,
            dep.result_ids[0],
            "artifacts",
            kml.backend_url(),
        );
        cfg.epochs = epochs;
        cfg.locality = ClientLocality::External; // plain script next to Kafka
        run_training_job(&kml.broker(), &cfg, &CancelToken::new()).unwrap();
    });
    kml.shutdown();

    // ---- mode 3: data streams & containerization ------------------------------
    let kml = KafkaMl::start(KafkaMlConfig {
        broker: BrokerConfig { net, ..Default::default() },
        costs,
        control_logger: false,
        ..Default::default()
    })?;
    let model = kml.create_model("t1c")?;
    let conf = kml.create_configuration("t1c", &[model])?;
    let containers = bench.run(|| {
        let dep = kml
            .deploy_training(conf, &TrainParams { epochs, ..Default::default() })
            .unwrap();
        kml.send_stream(
            dep.id,
            &ds.samples,
            "t1c-data",
            "RAW",
            &raw(),
            0.0,
            ClientLocality::External,
        )
        .unwrap();
        kml.wait_training(&dep, Duration::from_secs(1800)).unwrap();
    });
    kml.shutdown();

    let mut t = Table::new(
        "TABLE I — Training latency response (s)",
        &["", "Normal", "Data streams", "Data streams & containerization"],
    );
    t.row(&[
        format!("measured (epochs={epochs})"),
        secs(normal.mean),
        secs(streams.mean),
        secs(containers.mean),
    ]);
    t.row(&[
        "paper (epochs=1000)".into(),
        "27.37".into(),
        "29.61".into(),
        "31.44".into(),
    ]);
    t.print();
    println!(
        "\nshape check: streams/normal = {:.3}x (paper 1.082x), \
         containers/streams = {:.3}x (paper 1.062x)",
        streams.mean_secs() / normal.mean_secs(),
        containers.mean_secs() / streams.mean_secs(),
    );
    assert!(streams.mean > normal.mean, "streams must cost more than normal");
    assert!(
        containers.mean > streams.mean,
        "containerization must cost more than plain streams for training"
    );
    Ok(())
}
