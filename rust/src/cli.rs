//! Command-line interface for the `kafka-ml` leader binary.
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set).
//!
//! ```text
//! kafka-ml pipeline [--samples N] [--epochs E] [--replicas R] [--artifacts DIR]
//! kafka-ml serve    [--port P] [--listen ADDR] [--artifacts DIR]
//! kafka-ml info     [--artifacts DIR]
//! kafka-ml produce  --broker ADDR --topic T ...
//! kafka-ml consume  --broker ADDR --topic T ...
//! kafka-ml train    --broker ADDR --backend-url URL ...
//! kafka-ml infer    --broker ADDR --backend-url URL ...
//! ```
//!
//! `serve --listen` exposes the broker's TCP wire protocol; the
//! `produce`/`consume`/`train`/`infer` subcommands are workers that
//! reach it with `--broker ADDR` over a [`RemoteBroker`] transport —
//! broker and workers as separate OS processes, the paper's separate
//! containers.

use crate::broker::{
    AckMode, BrokerConfig, BrokerHandle, BrokerServer, BrokerTransport, ClientLocality,
    ClusterCtl, Consumer, LogConfig, PeerConnector, Producer, ProducerConfig, Record,
    RemoteBroker, ReplicaPuller, StorageMode,
};
use crate::coordinator::{
    InferenceReplicaConfig, KafkaMl, KafkaMlConfig, TrainParams, TrainingJobConfig,
};
use crate::exec::CancelToken;
use crate::json::Json;
use crate::ml::hcopd_dataset;
use crate::registry::{AuthKeys, BackendClient, DEFAULT_TENANT};
use crate::runtime::BackendSelect;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Parse `--key value` style flags after the subcommand.
pub fn parse_flags(args: &[String]) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            bail!("unexpected argument '{}'", args[i]);
        };
        let value = args
            .get(i + 1)
            .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
        out.insert(key.to_string(), value.clone());
        i += 2;
    }
    Ok(out)
}

fn flag_u64(flags: &BTreeMap<String, String>, key: &str, default: u64) -> Result<u64> {
    match flags.get(key) {
        Some(v) => v
            .parse()
            .map_err(|e| anyhow::anyhow!("--{key} must be an integer: {e}")),
        None => Ok(default),
    }
}

const USAGE: &str = "\
kafka-ml — ML/AI pipelines through data streams (paper reproduction)

USAGE:
  kafka-ml pipeline [--samples N] [--epochs E] [--replicas R] [--artifacts DIR]
                    [--data-dir DIR] [--backend auto|pjrt|native]
      Run the full Fig-1 pipeline (A-F) on the synthetic HCOPD workload.
  kafka-ml serve [--port P] [--listen ADDR] [--io-workers N] [--reactors N]
                 [--artifacts DIR] [--state FILE.json] [--data-dir DIR]
                 [--backend auto|pjrt|native]
                 [--auth-keys FILE.json] [--require-auth true]
                 [--broker-id N --cluster-peers ID@HOST:PORT,...]
                 [--acks leader|replicated]
      Boot the platform (broker + back-end + orchestrator) and serve the
      RESTful back-end until Ctrl-C; --state snapshots the registry.
      --auth-keys loads an API-key table (see `kafka-ml keys`) and turns
      authentication on for the REST API and the wire protocol alike;
      --require-auth true enforces even without a file. The platform
      mints its own internal admin service key for its pods either way.
      --listen ADDR additionally serves the broker's TCP wire protocol
      (e.g. 127.0.0.1:9092), so workers in other processes can attach
      with --broker. The wire server is a sharded epoll reactor:
      --reactors event-loop shards (default min(4, cores)) plus
      --io-workers request threads (default 4) shared across shards,
      regardless of how many connections are attached. Accepted
      connections are dealt round-robin across shards and each shard
      owns its connections end to end.
      --cluster-peers joins an N-broker cluster (requires --listen):
      the comma-separated roster lists every broker as id@host:port,
      --broker-id says which row is this process, and each partition
      gets a leader + follower by rendezvous hashing over the roster.
      The follower replicates the leader's log over the wire; a
      heartbeat supervisor declares silent brokers dead, bumps the
      metadata epoch and promotes followers, and the epoch fences
      deposed leaders (stale requests answer not-leader). --acks picks
      the produce ack discipline: 'leader' (default) acks on the
      leader's append, 'replicated' acks only once the follower has the
      record (consumers also only see replicated records).
  kafka-ml info [--artifacts DIR] [--backend auto|pjrt|native]
      Print the model's metadata and which execution backend loads.
  kafka-ml keys create --file F [--tenant T] [--admin true]
  kafka-ml keys revoke --file F --token K
  kafka-ml keys rotate --file F --token K [--grace-secs N]
  kafka-ml keys quota  --file F --tenant T [--records-per-sec N] [--burst N]
                       [--stored-bytes N]
  kafka-ml keys list   --file F
      Administer the API-key file a `serve --auth-keys F` loads: mint a
      key for a tenant (prints the token once), revoke one, rotate one
      (prints the successor token; the old key keeps working for
      --grace-secs, default 0, then answers 403 like a revoked key),
      set the tenant's produce rate (token bucket: --records-per-sec
      refill rate, --burst bucket capacity) / stored-bytes quotas, or
      list keys with their usage counters.

REMOTE WORKERS (separate OS processes; need a `serve --listen` broker;
all take --api-key K when the server runs with authentication — the key
is presented on the wire protocol AND as the REST bearer token):
  kafka-ml produce --broker ADDR --topic T [--partition P] [--value V | --count N]
      Produce records (--value once, or --count synthetic records).
  kafka-ml consume --broker ADDR --topic T [--partition P] [--group G]
                   [--from OFFSET] [--max N] [--idle-ms MS]
      Print records as they arrive; exits after MS idle (default 5000).
  kafka-ml train --broker ADDR --backend-url URL --deployment ID --result ID
                 [--model ID | --artifacts DIR] [--epochs E]
                 [--backend auto|pjrt|native]
      Run one training Job (Algorithm 1) against the remote broker.
  kafka-ml infer --broker ADDR --backend-url URL --inference ID
                 [--member NAME] [--backend auto|pjrt|native]
      Run one inference replica (Algorithm 2) until Ctrl-C.

  --data-dir enables tiered segment storage: rolled log segments are
  sealed to checksummed files under DIR and recovered on the next boot,
  so retained data streams stay reusable across restarts.

  --backend picks the model execution engine: 'pjrt' compiles the AOT
  HLO artifacts (needs `make artifacts` + a real xla-rs link), 'native'
  is the pure-Rust MLP engine that needs no artifacts at all, and
  'auto' (default) prefers PJRT when available and falls back to
  native.
";

pub fn main_entry() {
    crate::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

pub fn run(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("pipeline") => cmd_pipeline(&parse_flags(&args[1..])?),
        Some("serve") => cmd_serve(&parse_flags(&args[1..])?),
        Some("info") => cmd_info(&parse_flags(&args[1..])?),
        Some("produce") => cmd_produce(&parse_flags(&args[1..])?),
        Some("consume") => cmd_consume(&parse_flags(&args[1..])?),
        Some("train") => cmd_train(&parse_flags(&args[1..])?),
        Some("infer") => cmd_infer(&parse_flags(&args[1..])?),
        Some("keys") => cmd_keys(&args[1..]),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

/// Connect the remote broker transport named by `--broker ADDR`,
/// presenting `--api-key` (if given) on every connection.
fn remote_broker(flags: &BTreeMap<String, String>) -> Result<BrokerHandle> {
    let addr = flags
        .get("broker")
        .context("this subcommand needs --broker ADDR (a `kafka-ml serve --listen` endpoint)")?;
    let broker = RemoteBroker::connect_with_key(addr, flags.get("api-key").map(String::as_str))?;
    Ok(broker)
}

fn required<'a>(flags: &'a BTreeMap<String, String>, key: &str) -> Result<&'a String> {
    flags
        .get(key)
        .with_context(|| format!("missing required flag --{key}"))
}

fn required_u64(flags: &BTreeMap<String, String>, key: &str) -> Result<u64> {
    required(flags, key)?
        .parse()
        .map_err(|e| anyhow::anyhow!("--{key} must be an integer: {e}"))
}

/// A default group member id unique across hosts AND processes: pids
/// alone collide in containers (every pod's worker is pid 1), and a
/// colliding id silently merges two workers into one member.
fn default_member_id(prefix: &str) -> String {
    let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "local".to_string());
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    format!("{prefix}-{host}-{}-{nanos:08x}", std::process::id())
}

fn artifacts_dir(flags: &BTreeMap<String, String>) -> String {
    flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string())
}

/// The `--backend` knob (`auto` when absent).
fn backend_flag(flags: &BTreeMap<String, String>) -> Result<BackendSelect> {
    match flags.get("backend") {
        Some(v) => v.parse(),
        None => Ok(BackendSelect::Auto),
    }
}

/// Broker config honouring `--data-dir` (tiered, durable segment
/// storage) when given — in-memory otherwise — and `--acks` (the
/// produce ack discipline; only observable in a clustered deployment).
fn broker_config(flags: &BTreeMap<String, String>) -> Result<BrokerConfig> {
    let storage = match flags.get("data-dir") {
        Some(dir) => StorageMode::tiered(dir),
        None => StorageMode::InMemory,
    };
    let ack_mode = match flags.get("acks") {
        Some(v) => AckMode::parse(v)?,
        None => AckMode::Leader,
    };
    Ok(BrokerConfig {
        log: LogConfig {
            storage,
            ..LogConfig::default()
        },
        ack_mode,
        ..Default::default()
    })
}

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let engine = crate::runtime::Engine::load_with(artifacts_dir(flags), backend_flag(flags)?)?;
    let meta = engine.meta();
    println!("Kafka-ML model ({})", meta.dir.display());
    println!("  backend   : {} ({})", engine.backend_name(), engine.platform());
    println!("  input_dim : {}", meta.input_dim);
    println!("  hidden    : {:?}", meta.hidden);
    println!("  classes   : {}", meta.classes);
    println!("  batch     : {}", meta.batch);
    println!("  lr        : {}", meta.lr);
    println!("  weights   : {}", meta.total_weights());
    if meta.artifacts.is_empty() {
        println!("  artifact  : (none — artifact-less native model)");
    }
    for (name, info) in &meta.artifacts {
        println!("  artifact  : {name} <- {}", info.file);
    }
    Ok(())
}

/// A `--flag true|false` boolean (absent = false).
fn flag_bool(flags: &BTreeMap<String, String>, key: &str) -> Result<bool> {
    match flags.get(key).map(String::as_str) {
        None | Some("false") => Ok(false),
        Some("true") => Ok(true),
        Some(other) => bail!("--{key} must be true or false, got '{other}'"),
    }
}

fn cmd_serve(flags: &BTreeMap<String, String>) -> Result<()> {
    let port = flag_u64(flags, "port", 8080)? as u16;
    // Either flag turns authentication on: a keys file names who may
    // call, and --require-auth true enforces even without one (only
    // keys minted at runtime over POST /keys work until then).
    let keys_path = flags.get("auth-keys");
    let require_auth = flag_bool(flags, "require-auth")? || keys_path.is_some();
    let kml = KafkaMl::start(KafkaMlConfig {
        rest_port: port,
        artifact_dir: artifacts_dir(flags),
        broker: broker_config(flags)?,
        backend: backend_flag(flags)?,
        require_auth,
        ..Default::default()
    })?;
    // Re-asserting the platform's own credentials after anything that
    // replaces the key table (keys file now, state restore below): the
    // pods' service key must survive, and the CLI's auth posture wins
    // over whatever a file says.
    let reassert_auth = |kml: &KafkaMl| {
        if let Some(sk) = kml.service_key() {
            kml.store.auth().insert_key(sk, DEFAULT_TENANT, true).ok();
        }
        kml.store.auth().set_require(require_auth);
    };
    // --listen: expose the broker over the TCP wire protocol so remote
    // workers (produce/consume/train/infer --broker) can attach. The
    // server lives as long as the serve loop below. --reactors sizes
    // the event-loop shard count and --io-workers the request worker
    // pool shared across shards; connection count does not add threads.
    let _wire_server = match flags.get("listen") {
        Some(addr) => {
            let io_workers = flag_u64(
                flags,
                "io-workers",
                crate::broker::wire::server::DEFAULT_IO_WORKERS as u64,
            )? as usize;
            let reactors = flag_u64(
                flags,
                "reactors",
                crate::broker::wire::server::default_reactors() as u64,
            )? as usize;
            // The wire server shares the back-end's key table, so one
            // `keys` file (or POST /keys) governs both planes.
            let server = BrokerServer::start_sharded_auth(
                addr,
                kml.cluster.clone(),
                io_workers,
                reactors,
                Some(kml.store.auth().clone()),
            )?;
            println!(
                "broker wire protocol on {} ({} reactor shard(s){})",
                server.addr(),
                server.reactors(),
                if require_auth { ", auth required" } else { "" }
            );
            Some(server)
        }
        None => None,
    };
    // --cluster-peers: join the N-broker cluster. The wire server must
    // already be listening (peers dial it), so this runs after --listen.
    // Per process: the metadata authority (ClusterCtl), a peer
    // connector presenting the platform's service key, the replica
    // puller mirroring followed partitions, and the heartbeat
    // supervisor that declares dead leaders and promotes followers.
    let _cluster_runtime = match flags.get("cluster-peers") {
        Some(spec) => {
            if _wire_server.is_none() {
                bail!("--cluster-peers needs --listen (peers dial the wire protocol)");
            }
            let id = required_u64(flags, "broker-id")? as u32;
            let peers = crate::broker::clusterctl::parse_peers(spec)?;
            if !peers.iter().any(|(pid, _)| *pid == id) {
                bail!("--broker-id {id} does not appear in --cluster-peers");
            }
            let n = peers.len();
            let ctl = ClusterCtl::new(id, peers);
            let key: Option<String> = kml.service_key().map(str::to_string);
            let connector = PeerConnector::new(move |addr| {
                Ok(RemoteBroker::connect_peer(addr, key.as_deref())? as BrokerHandle)
            });
            kml.cluster.attach_clusterctl(ctl.clone(), connector);
            let puller = ReplicaPuller::start(
                kml.cluster.clone(),
                ctl.clone(),
                crate::broker::replication::DEFAULT_PULL_INTERVAL,
            );
            let supervisor = crate::orchestrator::ClusterSupervisor::start(
                kml.cluster.clone(),
                ctl.clone(),
                crate::orchestrator::DEFAULT_HEARTBEAT_INTERVAL,
                crate::orchestrator::DEFAULT_MISS_THRESHOLD,
            );
            println!(
                "cluster broker {id} of {n} (metadata epoch {}, acks={})",
                ctl.epoch(),
                flags.get("acks").map(String::as_str).unwrap_or("leader"),
            );
            Some((puller, supervisor))
        }
        None => None,
    };
    // Optional durability: restore + periodically snapshot the back-end
    // state (--state path.json), like the paper's database-backed Django.
    let state_path = flags.get("state").cloned();
    if let Some(path) = &state_path {
        if std::path::Path::new(path).exists() {
            let restore = std::fs::read_to_string(path)
                .map_err(anyhow::Error::from)
                .and_then(|text| {
                    crate::json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))
                })
                .and_then(|j| kml.store.restore_from_json(&j));
            match restore {
                Ok(()) => {
                    reassert_auth(&kml);
                    println!("restored back-end state from {path}");
                }
                Err(e) => log::warn!("could not restore {path}: {e}"),
            }
        }
    }
    // The keys file is authoritative over whatever a state snapshot
    // carried, so it loads after the restore.
    if let Some(path) = keys_path {
        kml.store
            .auth()
            .load_file(path)
            .with_context(|| format!("loading API keys from {path}"))?;
        reassert_auth(&kml);
        println!("loaded API keys from {path}");
    }
    println!("kafka-ml back-end serving at {}", kml.backend_url());
    println!("(Ctrl-C to stop)");
    loop {
        std::thread::sleep(Duration::from_secs(60));
        if let Some(path) = &state_path {
            if let Err(e) = kml.store.save(path) {
                log::warn!("state snapshot failed: {e}");
            }
        }
    }
}

fn cmd_pipeline(flags: &BTreeMap<String, String>) -> Result<()> {
    let samples = flag_u64(flags, "samples", 220)? as usize;
    let epochs = flag_u64(flags, "epochs", 10)? as usize;
    let replicas = flag_u64(flags, "replicas", 2)? as u32;
    let dir = artifacts_dir(flags);

    println!("== Kafka-ML pipeline (Fig 1, steps A-F) ==");
    let kml = KafkaMl::start(KafkaMlConfig {
        artifact_dir: dir,
        broker: broker_config(flags)?,
        backend: backend_flag(flags)?,
        ..Default::default()
    })?;
    println!("platform up: back-end {}", kml.backend_url());

    let model = kml.create_model("hcopd-mlp")?;
    let conf = kml.create_configuration("hcopd", &[model])?;
    println!("A/B: model {model}, configuration {conf}");

    let dep = kml.deploy_training(conf, &TrainParams { epochs, ..Default::default() })?;
    println!("C: deployment {} (jobs waiting on control topic)", dep.id);

    let ds = hcopd_dataset(samples, 8, 42);
    let raw = Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ]);
    let msg = kml.send_stream(
        dep.id,
        &ds.samples,
        "hcopd-data",
        "RAW",
        &raw,
        0.2,
        ClientLocality::External,
    )?;
    println!("D: streamed {} samples, control {}", samples, msg.stream.format());

    let results = kml.wait_training(&dep, Duration::from_secs(600))?;
    let r = &results[0];
    println!(
        "E: trained — loss {:.4} acc {:.3} val_loss {:?} val_acc {:?}",
        r.metrics.loss, r.metrics.accuracy, r.metrics.val_loss, r.metrics.val_accuracy
    );

    let inf = kml.deploy_inference(r.id, replicas, "hcopd-in", "hcopd-out")?;
    println!("E: inference {} up with {replicas} replicas", inf.id);

    let mut client = kml.inference_client(&inf, ClientLocality::External)?;
    let test = hcopd_dataset(20, 8, 77);
    let mut correct = 0;
    let t0 = std::time::Instant::now();
    for s in &test.samples {
        let p = client.request(&s.features, Duration::from_secs(10))?;
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    println!(
        "F: 20 predictions in {} ({} correct)",
        crate::util::human_duration(t0.elapsed()),
        correct
    );
    kml.stop_inference(inf.id)?;
    kml.shutdown();
    println!("done.");
    Ok(())
}

/// Offline API-key administration on the keys file `serve --auth-keys`
/// loads. Every action rewrites the file atomically (tmp + rename).
fn cmd_keys(args: &[String]) -> Result<()> {
    let action = args
        .first()
        .context("keys needs an action: create | revoke | rotate | list | quota")?
        .as_str();
    let flags = parse_flags(&args[1..])?;
    let path = required(&flags, "file")?;
    let keys = AuthKeys::new();
    if std::path::Path::new(path).exists() {
        keys.load_file(path)?;
    } else if action != "create" {
        bail!("keys file {path} does not exist");
    }
    match action {
        "create" => {
            let tenant = flags.get("tenant").map(String::as_str).unwrap_or(DEFAULT_TENANT);
            let token = keys.create_key(tenant, flag_bool(&flags, "admin")?)?;
            keys.save_file(path)?;
            println!("{token}");
        }
        "revoke" => {
            let token = required(&flags, "token")?;
            if !keys.revoke(token) {
                bail!("no such key in {path}");
            }
            keys.save_file(path)?;
            println!("revoked {token}");
        }
        "rotate" => {
            let token = required(&flags, "token")?;
            let grace = flag_u64(&flags, "grace-secs", 0)?;
            let successor = keys.rotate(token, grace)?;
            keys.save_file(path)?;
            // Like create: the successor token prints exactly once.
            println!("{successor}");
        }
        "quota" => {
            let tenant = required(&flags, "tenant")?;
            let mut q = keys.quota(tenant);
            if let Some(v) = flags.get("records-per-sec") {
                q.records_per_sec = Some(v.parse().context("--records-per-sec must be an integer")?);
            }
            if let Some(v) = flags.get("burst") {
                q.burst = Some(v.parse().context("--burst must be an integer")?);
            }
            if let Some(v) = flags.get("stored-bytes") {
                q.stored_bytes = Some(v.parse().context("--stored-bytes must be an integer")?);
            }
            keys.set_quota(tenant, q);
            keys.save_file(path)?;
            println!("quota set for tenant {tenant}");
        }
        "list" => {
            for k in keys.list() {
                println!(
                    "{}  tenant={} admin={} revoked={} expires={} requests={} records={} bytes={}",
                    k.token,
                    k.tenant,
                    k.admin,
                    k.revoked,
                    k.expires_at
                        .map(|e| e.to_string())
                        .unwrap_or_else(|| "-".to_string()),
                    k.usage.requests,
                    k.usage.records_produced,
                    k.usage.bytes_stored
                );
            }
        }
        other => bail!("unknown keys action '{other}' (create | revoke | rotate | list | quota)"),
    }
    Ok(())
}

// ---- remote workers (separate OS processes over the wire) -----------------

fn cmd_produce(flags: &BTreeMap<String, String>) -> Result<()> {
    let broker = remote_broker(flags)?;
    let topic = required(flags, "topic")?;
    let partition = flag_u64(flags, "partition", 0)? as u32;
    let mut producer = Producer::new(
        broker,
        ProducerConfig {
            batch_size: 64,
            locality: ClientLocality::Remote,
            ..Default::default()
        },
    );
    let n = match flags.get("value") {
        Some(v) => {
            producer.send_to(topic, partition, Record::new(v.as_bytes().to_vec()))?;
            1
        }
        None => {
            let count = flag_u64(flags, "count", 10)?;
            for i in 0..count {
                producer.send_to(
                    topic,
                    partition,
                    Record::new(format!("record-{i}").into_bytes()),
                )?;
            }
            count
        }
    };
    producer.flush()?;
    println!("produced {n} record(s) to {topic}:{partition}");
    Ok(())
}

fn cmd_consume(flags: &BTreeMap<String, String>) -> Result<()> {
    let broker = remote_broker(flags)?;
    let topic = required(flags, "topic")?;
    let max = flag_u64(flags, "max", u64::MAX)?;
    let idle_ms = flag_u64(flags, "idle-ms", 5000)?;
    let mut consumer = Consumer::new(broker.clone(), ClientLocality::Remote);
    match flags.get("group") {
        Some(group) => {
            if flags.contains_key("from") {
                bail!("--from replays a fixed offset and --group resumes from commits; pick one");
            }
            // Auto-create (like producers do): joining a group on a
            // not-yet-created topic would yield an empty assignment
            // that no later produce can fix (topic creation does not
            // rebalance existing groups).
            broker.create_topic(topic, 0)?;
            let member = default_member_id("cli");
            consumer.subscribe(group, &member, &[topic.clone()], crate::broker::Assignor::Range)?;
        }
        None => {
            let parts = match flags.get("partition") {
                Some(_) => vec![flag_u64(flags, "partition", 0)? as u32],
                None => {
                    let n = broker
                        .topic_partitions(topic)?
                        .with_context(|| format!("unknown topic '{topic}'"))?;
                    (0..n).collect()
                }
            };
            consumer.assign(parts.iter().map(|&p| (topic.clone(), p)).collect());
            if let Some(from) = flags.get("from") {
                let from: u64 = from.parse().context("--from must be an offset")?;
                for &p in &parts {
                    consumer.seek((topic.clone(), p), from);
                }
            }
        }
    }
    let mut seen = 0u64;
    while seen < max {
        let budget = (max - seen).min(256) as usize;
        let recs = consumer.poll_wait(budget, Duration::from_millis(idle_ms))?;
        if recs.is_empty() {
            break; // idle window elapsed with nothing new
        }
        for rec in recs {
            println!(
                "{}:{} @{}  {}",
                rec.topic,
                rec.partition,
                rec.offset,
                String::from_utf8_lossy(&rec.record.value)
            );
            seen += 1;
        }
        consumer.commit()?;
    }
    // Leave promptly so a dead CLI member does not hold partitions
    // until session expiry (best-effort; no-op for manual assignment).
    consumer.leave();
    println!("consumed {seen} record(s) from {topic}");
    Ok(())
}

fn cmd_train(flags: &BTreeMap<String, String>) -> Result<()> {
    let broker = remote_broker(flags)?;
    let backend_url = required(flags, "backend-url")?;
    let deployment_id = required_u64(flags, "deployment")?;
    let result_id = required_u64(flags, "result")?;
    // The artifact dir comes from the model registry (--model ID, the
    // containerized path) or straight from --artifacts.
    let api_key = flags.get("api-key").cloned();
    let artifact_dir = match flags.get("model") {
        Some(m) => {
            let model_id: u64 = m.parse().context("--model must be an id")?;
            BackendClient::new_with_key(backend_url, api_key.as_deref())
                .model_artifact_dir(model_id)?
        }
        None => artifacts_dir(flags),
    };
    let config = TrainingJobConfig {
        epochs: flag_u64(flags, "epochs", 1)? as usize,
        control_timeout: Duration::from_secs(flag_u64(flags, "control-timeout-s", 120)?),
        locality: ClientLocality::Remote,
        backend: backend_flag(flags)?,
        api_key,
        ..TrainingJobConfig::new(deployment_id, result_id, &artifact_dir, backend_url)
    };
    println!("training job: deployment {deployment_id}, result {result_id}, broker {}",
        required(flags, "broker")?);
    let outcome =
        crate::coordinator::training::run_training_job(&broker, &config, &CancelToken::new())?;
    println!(
        "trained: loss {:.4} acc {:.3} ({} steps, {} train / {} val samples)",
        outcome.metrics.loss,
        outcome.metrics.accuracy,
        outcome.steps,
        outcome.samples_train,
        outcome.samples_val
    );
    Ok(())
}

fn cmd_infer(flags: &BTreeMap<String, String>) -> Result<()> {
    let broker = remote_broker(flags)?;
    let backend_url = required(flags, "backend-url")?;
    let inference_id = required_u64(flags, "inference")?;
    let member = flags
        .get("member")
        .cloned()
        .unwrap_or_else(|| default_member_id("replica"));
    // Same auto-configuration the orchestrator entrypoint does: the
    // deployment row names topics, format and the trained result.
    let api_key = flags.get("api-key").cloned();
    let backend = BackendClient::new_with_key(backend_url, api_key.as_deref());
    let info = backend.inference_info(inference_id)?;
    let result_id = info.req_u64("result_id")?;
    let result = backend.result_info(result_id)?;
    let model_id = result.req_u64("model_id")?;
    let artifact_dir = backend.model_artifact_dir(model_id)?;
    let config = InferenceReplicaConfig {
        inference_id,
        result_id,
        artifact_dir,
        backend_url: backend_url.clone(),
        input_topic: info.req_str("input_topic")?.to_string(),
        output_topic: info.req_str("output_topic")?.to_string(),
        input_format: info.req_str("input_format")?.to_string(),
        input_config: info.get("input_config").clone(),
        locality: ClientLocality::Remote,
        max_poll: 32,
        backend: backend_flag(flags)?,
        api_key,
    };
    println!(
        "inference replica '{member}' on {} -> {} (Ctrl-C to stop)",
        config.input_topic, config.output_topic
    );
    crate::coordinator::inference::run_inference_replica(
        &broker,
        &config,
        &member,
        &CancelToken::new(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let f = parse_flags(&s(&["--epochs", "5", "--replicas", "3"])).unwrap();
        assert_eq!(f.get("epochs").unwrap(), "5");
        assert_eq!(flag_u64(&f, "replicas", 1).unwrap(), 3);
        assert_eq!(flag_u64(&f, "missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(parse_flags(&s(&["epochs"])).is_err());
        assert!(parse_flags(&s(&["--epochs"])).is_err());
        let f = parse_flags(&s(&["--epochs", "x"])).unwrap();
        assert!(flag_u64(&f, "epochs", 1).is_err());
    }

    #[test]
    fn data_dir_flag_enables_tiered_storage() {
        let f = parse_flags(&s(&["--data-dir", "/tmp/kafka-ml-data"])).unwrap();
        match broker_config(&f).unwrap().log.storage {
            StorageMode::Tiered { data_dir } => {
                assert_eq!(data_dir, std::path::PathBuf::from("/tmp/kafka-ml-data"));
            }
            other => panic!("expected tiered storage, got {other:?}"),
        }
        let cfg = broker_config(&BTreeMap::new()).unwrap();
        assert_eq!(cfg.log.storage, StorageMode::InMemory);
        assert_eq!(cfg.ack_mode, AckMode::Leader);
    }

    #[test]
    fn acks_flag_parses_and_rejects() {
        let f = parse_flags(&s(&["--acks", "replicated"])).unwrap();
        assert_eq!(broker_config(&f).unwrap().ack_mode, AckMode::Replicated);
        let f = parse_flags(&s(&["--acks", "quorum"])).unwrap();
        assert!(broker_config(&f).is_err());
    }

    #[test]
    fn backend_flag_parses_and_rejects() {
        assert_eq!(backend_flag(&BTreeMap::new()).unwrap(), BackendSelect::Auto);
        let f = parse_flags(&s(&["--backend", "native"])).unwrap();
        assert_eq!(backend_flag(&f).unwrap(), BackendSelect::Native);
        let f = parse_flags(&s(&["--backend", "pjrt"])).unwrap();
        assert_eq!(backend_flag(&f).unwrap(), BackendSelect::Pjrt);
        let f = parse_flags(&s(&["--backend", "tensorflow"])).unwrap();
        assert!(backend_flag(&f).is_err());
    }

    #[test]
    fn help_and_unknown() {
        assert!(run(&s(&["help"])).is_ok());
        assert!(run(&[]).is_ok());
        assert!(run(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn remote_workers_require_broker_flag() {
        for cmd in ["produce", "consume", "train", "infer"] {
            let err = run(&s(&[cmd, "--topic", "t"])).unwrap_err();
            assert!(
                err.to_string().contains("--broker"),
                "{cmd}: {err}"
            );
        }
    }

    #[test]
    fn flag_bool_accepts_only_true_false() {
        assert!(!flag_bool(&BTreeMap::new(), "require-auth").unwrap());
        let f = parse_flags(&s(&["--require-auth", "true"])).unwrap();
        assert!(flag_bool(&f, "require-auth").unwrap());
        let f = parse_flags(&s(&["--require-auth", "yes"])).unwrap();
        assert!(flag_bool(&f, "require-auth").is_err());
    }

    #[test]
    fn keys_subcommand_roundtrips_a_key_file() {
        let dir = std::env::temp_dir().join(format!("kml-keys-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("keys.json");
        let file = file.to_str().unwrap();

        // create prints nothing we can capture here, but the file must
        // exist afterwards and hold one key for the tenant.
        run(&s(&["keys", "create", "--file", file, "--tenant", "acme"])).unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        let listed = keys.list();
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].tenant, "acme");
        assert!(!listed[0].admin);
        let token = listed[0].token.clone();

        // quota lands in the file too.
        run(&s(&[
            "keys", "quota", "--file", file, "--tenant", "acme",
            "--records-per-sec", "100", "--stored-bytes", "4096",
        ]))
        .unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        assert_eq!(keys.quota("acme").records_per_sec, Some(100));
        assert_eq!(keys.quota("acme").stored_bytes, Some(4096));

        // revoke flips the flag without deleting (403, not 401).
        run(&s(&["keys", "revoke", "--file", file, "--token", &token])).unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        assert!(keys.list()[0].revoked);
        // list and unknown actions.
        run(&s(&["keys", "list", "--file", file])).unwrap();
        assert!(run(&s(&["keys", "frob", "--file", file])).is_err());
        // every non-create action demands an existing file.
        let missing = dir.join("nope.json");
        let err = run(&s(&["keys", "list", "--file", missing.to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("does not exist"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keys_rotate_and_burst_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kml-rotate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("keys.json");
        let file = file.to_str().unwrap();

        run(&s(&["keys", "create", "--file", file, "--tenant", "acme"])).unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        let old = keys.list()[0].token.clone();

        // Rotate with a long grace: the file gains a successor key and
        // the old key now carries a deadline.
        run(&s(&[
            "keys", "rotate", "--file", file, "--token", &old, "--grace-secs", "3600",
        ]))
        .unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        let listed = keys.list();
        assert_eq!(listed.len(), 2);
        let old_info = listed.iter().find(|k| k.token == old).unwrap();
        assert!(old_info.expires_at.is_some());
        let successor = listed.iter().find(|k| k.token != old).unwrap();
        assert_eq!(successor.tenant, "acme");
        assert!(successor.expires_at.is_none());
        // Rotating an unknown token refuses.
        assert!(run(&s(&["keys", "rotate", "--file", file, "--token", "ghost"])).is_err());

        // --burst lands in the tenant quota alongside the rate.
        run(&s(&[
            "keys", "quota", "--file", file, "--tenant", "acme",
            "--records-per-sec", "100", "--burst", "250",
        ]))
        .unwrap();
        let keys = AuthKeys::new();
        keys.load_file(file).unwrap();
        assert_eq!(keys.quota("acme").records_per_sec, Some(100));
        assert_eq!(keys.quota("acme").burst, Some(250));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_cluster_flags_are_validated() {
        // --cluster-peers without --listen refuses before anything
        // heavyweight starts... but cmd_serve boots the platform first,
        // so validate the cheap pieces directly instead.
        let peers = crate::broker::clusterctl::parse_peers("0@a:1,1@b:2").unwrap();
        assert!(!peers.iter().any(|(id, _)| *id == 7));
        assert!(crate::broker::clusterctl::parse_peers("bogus").is_err());
    }

    #[test]
    fn produce_requires_topic() {
        // An unreachable broker address fails before --topic is read;
        // use a local listener so connect succeeds.
        let c = crate::broker::Cluster::new(BrokerConfig::default());
        let srv = BrokerServer::start("127.0.0.1:0", c).unwrap();
        let addr = srv.addr().to_string();
        let err = run(&s(&["produce", "--broker", &addr])).unwrap_err();
        assert!(err.to_string().contains("--topic"), "{err}");
        srv.shutdown();
    }
}
