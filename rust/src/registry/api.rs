//! The RESTful API over [`Store`] — the paper's §IV-B back-end surface.
//!
//! | Endpoint | § |
//! |---|---|
//! | `POST /models`, `GET /models`, `GET /models/:id` | III-A |
//! | `POST /configurations`, `GET /configurations/:id` | III-B |
//! | `POST /deployments`, `GET /deployments/:id` | III-C |
//! | `GET /results/:id`, `POST /results/:id/finish`, `GET/POST .../model` | III-E |
//! | `POST /inferences`, `GET /inferences/:id` | III-E/F |
//! | `POST /control`, `GET /control` | IV-E (control logger) |
//! | `POST /keys`, `GET /keys`, `POST /keys/revoke`, `POST /keys/quota` | admin |
//!
//! When the store's [`AuthKeys`] table runs with `require_auth`, every
//! route demands `authorization: Bearer <key>` (401 missing/unknown,
//! 403 revoked) and non-admin keys see only their own tenant's
//! entities — a cross-tenant id answers the same 404 as a missing one.

use super::auth::{AuthOutcome, Identity};
use super::store::{ControlLogEntry, Store, TrainingMetrics, TrainingStatus};
use crate::json::Json;
use crate::rest::{Method, Request, Response, Router, Status};
use std::sync::Arc;

fn ok(j: Json) -> Response {
    Response::json(Status::Ok, &j)
}

fn created(j: Json) -> Response {
    Response::json(Status::Created, &j)
}

fn bad(e: impl std::fmt::Display) -> Response {
    Response::error(Status::BadRequest, &format!("{e}"))
}

fn quota_exceeded() -> Response {
    Response::error(Status::TooManyRequests, "tenant quota exceeded")
}

/// Registry scope for this request: `None` (unscoped) for admin keys
/// and for servers running without auth; the key's tenant otherwise.
/// Reads the annotations the auth guard left in `req.params`.
fn scope_of(req: &Request) -> Option<&str> {
    if req.params.get("auth.admin").map(String::as_str) == Some("true") {
        return None;
    }
    req.params.get("auth.tenant").map(String::as_str)
}

/// The authenticated identity, when there is one (auth enabled and the
/// guard accepted a key). Quota charges need the full identity; scoped
/// reads only need [`scope_of`].
fn identity_of(req: &Request) -> Option<Identity> {
    Some(Identity {
        token: req.params.get("auth.token")?.clone(),
        tenant: req.params.get("auth.tenant")?.clone(),
        admin: req.params.get("auth.admin").map(String::as_str) == Some("true"),
    })
}

/// Gate for key-management routes: only unscoped (admin) callers pass.
fn require_admin(req: &Request) -> Option<Response> {
    if scope_of(req).is_some() {
        return Some(Response::error(Status::Forbidden, "admin key required"));
    }
    None
}

fn parse_body(req: &Request) -> Result<Json, Response> {
    req.body_str()
        .ok()
        .and_then(|s| crate::json::parse(s).ok())
        .ok_or_else(|| bad("invalid JSON body"))
}

fn id_param(req: &Request) -> Result<u64, Response> {
    req.param("id")
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("invalid :id"))
}

pub fn metrics_to_json(m: &TrainingMetrics) -> Json {
    Json::obj(vec![
        ("loss", Json::num(m.loss)),
        ("accuracy", Json::num(m.accuracy)),
        ("val_loss", m.val_loss.map(Json::num).unwrap_or(Json::Null)),
        (
            "val_accuracy",
            m.val_accuracy.map(Json::num).unwrap_or(Json::Null),
        ),
        (
            "loss_curve",
            Json::arr(m.loss_curve.iter().map(|&l| Json::num(l)).collect()),
        ),
    ])
}

pub fn metrics_from_json(j: &Json) -> TrainingMetrics {
    TrainingMetrics {
        loss: j.get("loss").as_f64().unwrap_or(0.0),
        accuracy: j.get("accuracy").as_f64().unwrap_or(0.0),
        val_loss: j.get("val_loss").as_f64(),
        val_accuracy: j.get("val_accuracy").as_f64(),
        loss_curve: j
            .get("loss_curve")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_f64())
            .collect(),
    }
}

pub fn control_to_json(e: &ControlLogEntry) -> Json {
    Json::obj(vec![
        ("deployment_id", Json::from(e.deployment_id)),
        ("topic", Json::str(&e.topic)),
        ("partition", Json::from(e.partition as u64)),
        ("offset", Json::from(e.offset)),
        ("length", Json::from(e.length)),
        ("input_format", Json::str(&e.input_format)),
        ("input_config", e.input_config.clone()),
        ("validation_rate", Json::num(e.validation_rate)),
        ("total_msg", Json::from(e.total_msg)),
        ("logged_ms", Json::from(e.logged_ms)),
    ])
}

pub fn control_from_json(j: &Json) -> anyhow::Result<ControlLogEntry> {
    Ok(ControlLogEntry {
        deployment_id: j.req_u64("deployment_id")?,
        topic: j.req_str("topic")?.to_string(),
        partition: j.req_u64("partition")? as u32,
        offset: j.req_u64("offset")?,
        length: j.req_u64("length")?,
        input_format: j.req_str("input_format")?.to_string(),
        input_config: j.get("input_config").clone(),
        validation_rate: j.get("validation_rate").as_f64().unwrap_or(0.0),
        total_msg: j.get("total_msg").as_u64().unwrap_or(0),
        logged_ms: j.get("logged_ms").as_u64().unwrap_or(0),
    })
}

/// Build the back-end router over a shared store.
pub fn router(store: Arc<Store>) -> Router {
    let s = store;
    let auth = s.auth().clone();
    Router::new()
        // ---- auth guard ---------------------------------------------------
        // Runs before route matching: with auth enforced, a missing or
        // unknown key is 401 and a revoked key 403 on EVERY path, known
        // or not. Accepted keys annotate the request with their
        // identity for the scoped handlers below.
        .guard(move |req| {
            if !auth.require_auth() {
                return None;
            }
            let token = match req
                .header("authorization")
                .and_then(|h| h.strip_prefix("Bearer "))
                .map(str::trim)
                .filter(|t| !t.is_empty())
            {
                Some(t) => t.to_string(),
                None => {
                    return Some(Response::error(
                        Status::Unauthorized,
                        "missing bearer token",
                    ))
                }
            };
            match auth.authenticate(&token) {
                AuthOutcome::Accepted(id) => {
                    req.params.insert("auth.token".into(), id.token);
                    req.params.insert("auth.tenant".into(), id.tenant);
                    req.params
                        .insert("auth.admin".into(), id.admin.to_string());
                    None
                }
                AuthOutcome::Revoked => {
                    Some(Response::error(Status::Forbidden, "key revoked"))
                }
                // Expiry is revocation-by-clock: the caller proved
                // possession, so 403 (not 401) like a revoked key.
                AuthOutcome::Expired => {
                    Some(Response::error(Status::Forbidden, "key expired"))
                }
                AuthOutcome::Unknown => {
                    Some(Response::error(Status::Unauthorized, "unknown key"))
                }
            }
        })
        // ---- models (§III-A) --------------------------------------------
        .route(Method::Post, "/models", {
            let s = s.clone();
            move |req| {
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                // A tenant at its storage ceiling can't mint more
                // storage-bearing resources.
                if let Some(ident) = identity_of(&req) {
                    if s.auth().storage_exhausted(&ident) {
                        return quota_exceeded();
                    }
                }
                let name = body.get("name").as_str().unwrap_or("model");
                let dir = match body.req_str("artifact_dir") {
                    Ok(d) => d,
                    Err(e) => return bad(e),
                };
                let desc = body.get("description").as_str().unwrap_or("");
                match s.create_model_scoped(scope_of(&req), name, dir, desc) {
                    Ok(id) => created(Json::obj(vec![("id", Json::from(id))])),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/models", {
            let s = s.clone();
            move |req| {
                ok(Json::arr(
                    s.models_scoped(scope_of(&req))
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("id", Json::from(m.id)),
                                ("name", Json::str(&m.name)),
                                ("artifact_dir", Json::str(&m.artifact_dir)),
                            ])
                        })
                        .collect(),
                ))
            }
        })
        .route(Method::Get, "/models/:id", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.model_scoped(scope_of(&req), id) {
                    Ok(m) => ok(Json::obj(vec![
                        ("id", Json::from(m.id)),
                        ("name", Json::str(&m.name)),
                        ("artifact_dir", Json::str(&m.artifact_dir)),
                        ("description", Json::str(&m.description)),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        // ---- configurations (§III-B) -------------------------------------
        .route(Method::Post, "/configurations", {
            let s = s.clone();
            move |req| {
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let name = body.get("name").as_str().unwrap_or("configuration");
                let ids: Vec<u64> = body
                    .get("model_ids")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_u64())
                    .collect();
                match s.create_configuration_scoped(scope_of(&req), name, &ids) {
                    Ok(id) => created(Json::obj(vec![("id", Json::from(id))])),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/configurations/:id", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.configuration_scoped(scope_of(&req), id) {
                    Ok(c) => ok(Json::obj(vec![
                        ("id", Json::from(c.id)),
                        ("name", Json::str(&c.name)),
                        (
                            "model_ids",
                            Json::arr(c.model_ids.iter().map(|&m| Json::from(m)).collect()),
                        ),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        // ---- training deployments (§III-C) ----------------------------------
        .route(Method::Post, "/deployments", {
            let s = s.clone();
            move |req| {
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let conf = match body.req_u64("configuration_id") {
                    Ok(c) => c,
                    Err(e) => return bad(e),
                };
                let batch = body.get("batch_size").as_usize().unwrap_or(10);
                let epochs = body.get("epochs").as_usize().unwrap_or(1);
                let shuffle = body.get("shuffle").as_bool().unwrap_or(true);
                match s.create_deployment_scoped(scope_of(&req), conf, batch, epochs, shuffle) {
                    Ok(d) => created(Json::obj(vec![
                        ("id", Json::from(d.id)),
                        (
                            "result_ids",
                            Json::arr(d.result_ids.iter().map(|&r| Json::from(r)).collect()),
                        ),
                    ])),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/deployments/:id", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.deployment_scoped(scope_of(&req), id) {
                    Ok(d) => ok(Json::obj(vec![
                        ("id", Json::from(d.id)),
                        ("configuration_id", Json::from(d.configuration_id)),
                        ("batch_size", Json::from(d.batch_size)),
                        ("epochs", Json::from(d.epochs)),
                        ("shuffle", Json::from(d.shuffle)),
                        (
                            "result_ids",
                            Json::arr(d.result_ids.iter().map(|&r| Json::from(r)).collect()),
                        ),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        // ---- results (§III-E) ----------------------------------------------
        .route(Method::Get, "/results/:id", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.result_scoped(scope_of(&req), id) {
                    Ok(r) => ok(Json::obj(vec![
                        ("id", Json::from(r.id)),
                        ("deployment_id", Json::from(r.deployment_id)),
                        ("model_id", Json::from(r.model_id)),
                        ("status", Json::str(r.status.as_str())),
                        ("metrics", metrics_to_json(&r.metrics)),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        .route(Method::Post, "/results/:id/status", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let status = match body
                    .req_str("status")
                    .and_then(|st| TrainingStatus::parse(st))
                {
                    Ok(st) => st,
                    Err(e) => return bad(e),
                };
                match s.set_result_status_scoped(scope_of(&req), id, status) {
                    Ok(()) => ok(Json::Bool(true)),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        .route(Method::Post, "/results/:id/model", {
            // Binary upload: body is the ModelParams blob; metrics travel
            // in the x-kafka-ml-metrics header (JSON) to keep one call.
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                let metrics = req
                    .headers
                    .get("x-kafka-ml-metrics")
                    .and_then(|h| crate::json::parse(h).ok())
                    .map(|j| metrics_from_json(&j))
                    .unwrap_or_default();
                // The blob counts against the tenant's stored-bytes
                // quota; charge before accepting it.
                if let Some(ident) = identity_of(&req) {
                    if s.auth().charge_stored(&ident, req.body.len() as u64).is_err() {
                        return quota_exceeded();
                    }
                }
                let scope = scope_of(&req).map(str::to_string);
                match s.finish_result_scoped(scope.as_deref(), id, metrics, req.body) {
                    Ok(()) => ok(Json::Bool(true)),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/results/:id/model", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.download_model_blob_scoped(scope_of(&req), id) {
                    Ok(blob) => Response::binary(Status::Ok, blob),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        // ---- inference deployments (§III-E/F) ---------------------------------
        .route(Method::Post, "/inferences", {
            let s = s.clone();
            move |req| {
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let result_id = match body.req_u64("result_id") {
                    Ok(r) => r,
                    Err(e) => return bad(e),
                };
                let replicas = body.get("replicas").as_u64().unwrap_or(1) as u32;
                let input = body.get("input_topic").as_str().unwrap_or("inference-in");
                let output = body.get("output_topic").as_str().unwrap_or("inference-out");
                let fmt = body.get("input_format").as_str().map(|f| {
                    (f.to_string(), body.get("input_config").clone())
                });
                match s.create_inference_scoped(scope_of(&req), result_id, replicas, input, output, fmt) {
                    Ok(d) => created(Json::obj(vec![("id", Json::from(d.id))])),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/inferences/:id", {
            let s = s.clone();
            move |req| {
                let id = match id_param(&req) {
                    Ok(id) => id,
                    Err(r) => return r,
                };
                match s.inference_scoped(scope_of(&req), id) {
                    Ok(d) => ok(Json::obj(vec![
                        ("id", Json::from(d.id)),
                        ("result_id", Json::from(d.result_id)),
                        ("replicas", Json::from(d.replicas as u64)),
                        ("input_topic", Json::str(&d.input_topic)),
                        ("output_topic", Json::str(&d.output_topic)),
                        ("input_format", Json::str(&d.input_format)),
                        ("input_config", d.input_config.clone()),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        // ---- control logger (§IV-E) --------------------------------------------
        .route(Method::Post, "/control", {
            let s = s.clone();
            move |req| {
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                match control_from_json(&body) {
                    Ok(e) => {
                        // A tenant can only log control entries for
                        // deployments it can see. Unscoped callers
                        // (auth off, or an admin key — the control
                        // logger pod) keep the historical behavior of
                        // logging entries for any deployment id, even
                        // one not registered here.
                        if let Some(scope) = scope_of(&req) {
                            if s.deployment_scoped(Some(scope), e.deployment_id).is_err() {
                                return Response::error(
                                    Status::NotFound,
                                    &format!("unknown deployment {}", e.deployment_id),
                                );
                            }
                        }
                        s.log_control(e);
                        created(Json::Bool(true))
                    }
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/control", {
            let s = s.clone();
            move |req| {
                ok(Json::arr(
                    s.control_log_scoped(scope_of(&req))
                        .iter()
                        .map(control_to_json)
                        .collect(),
                ))
            }
        })
        // ---- key management (admin only) ---------------------------------
        .route(Method::Post, "/keys", {
            let s = s.clone();
            move |req| {
                if let Some(resp) = require_admin(&req) {
                    return resp;
                }
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let tenant = match body.req_str("tenant") {
                    Ok(t) => t,
                    Err(e) => return bad(e),
                };
                let admin = body.get("admin").as_bool().unwrap_or(false);
                match s.auth().create_key(tenant, admin) {
                    Ok(token) => created(Json::obj(vec![
                        ("token", Json::str(&token)),
                        ("tenant", Json::str(tenant)),
                        ("admin", Json::from(admin)),
                    ])),
                    Err(e) => bad(e),
                }
            }
        })
        .route(Method::Get, "/keys", {
            let s = s.clone();
            move |req| {
                if let Some(resp) = require_admin(&req) {
                    return resp;
                }
                ok(Json::arr(
                    s.auth()
                        .list()
                        .iter()
                        .map(|k| {
                            let mut fields = vec![
                                ("token", Json::str(&k.token)),
                                ("tenant", Json::str(&k.tenant)),
                                ("admin", Json::from(k.admin)),
                                ("revoked", Json::from(k.revoked)),
                            ];
                            if let Some(deadline) = k.expires_at {
                                fields.push(("expires_at", Json::from(deadline)));
                            }
                            fields.extend([
                                ("requests", Json::from(k.usage.requests)),
                                ("records_produced", Json::from(k.usage.records_produced)),
                                ("bytes_stored", Json::from(k.usage.bytes_stored)),
                            ]);
                            Json::obj(fields)
                        })
                        .collect(),
                ))
            }
        })
        .route(Method::Post, "/keys/revoke", {
            let s = s.clone();
            move |req| {
                if let Some(resp) = require_admin(&req) {
                    return resp;
                }
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let token = match body.req_str("token") {
                    Ok(t) => t,
                    Err(e) => return bad(e),
                };
                if s.auth().revoke(token) {
                    ok(Json::Bool(true))
                } else {
                    Response::error(Status::NotFound, "no such key")
                }
            }
        })
        .route(Method::Post, "/keys/rotate", {
            let s = s.clone();
            move |req| {
                if let Some(resp) = require_admin(&req) {
                    return resp;
                }
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let token = match body.req_str("token") {
                    Ok(t) => t,
                    Err(e) => return bad(e),
                };
                let grace = body.get("grace_secs").as_u64().unwrap_or(0);
                match s.auth().rotate(token, grace) {
                    Ok(successor) => created(Json::obj(vec![
                        ("token", Json::str(&successor)),
                        ("grace_secs", Json::from(grace)),
                    ])),
                    Err(e) => Response::error(Status::NotFound, &format!("{e}")),
                }
            }
        })
        .route(Method::Post, "/keys/quota", {
            let s = s.clone();
            move |req| {
                if let Some(resp) = require_admin(&req) {
                    return resp;
                }
                let body = match parse_body(&req) {
                    Ok(b) => b,
                    Err(r) => return r,
                };
                let tenant = match body.req_str("tenant") {
                    Ok(t) => t,
                    Err(e) => return bad(e),
                };
                s.auth().set_quota(
                    tenant,
                    super::auth::Quota {
                        records_per_sec: body.get("records_per_sec").as_u64(),
                        burst: body.get("burst").as_u64(),
                        stored_bytes: body.get("stored_bytes").as_u64(),
                    },
                );
                ok(Json::Bool(true))
            }
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        let dir = std::env::temp_dir().join("kafka-ml-test-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"spec": {"input_dim": 2, "hidden": [3], "classes": 2, "batch": 4,
                 "lr": 0.001, "seed": 1},
                "params": [{"name": "w1", "shape": [2, 3], "dtype": "f32"}],
                "artifacts": {}}"#,
        )
        .unwrap();
        dir.to_string_lossy().to_string()
    }

    fn dispatch(r: &Router, method: Method, path: &str, body: Option<&str>) -> Response {
        let mut req = Request::new(method, path);
        if let Some(b) = body {
            req = req.with_body(b.as_bytes().to_vec(), "application/json");
        }
        r.dispatch(req)
    }

    #[test]
    fn full_api_pipeline() {
        let store = Arc::new(Store::new());
        let r = router(store.clone());

        // Create model.
        let body = format!(r#"{{"name": "copd", "artifact_dir": "{}"}}"#, artifact_dir());
        let resp = dispatch(&r, Method::Post, "/models", Some(&body));
        assert_eq!(resp.status, Status::Created);
        let mid = resp.body_json().unwrap().req_u64("id").unwrap();

        // Configuration.
        let resp = dispatch(
            &r,
            Method::Post,
            "/configurations",
            Some(&format!(r#"{{"name": "c", "model_ids": [{mid}]}}"#)),
        );
        let cid = resp.body_json().unwrap().req_u64("id").unwrap();

        // Deployment.
        let resp = dispatch(
            &r,
            Method::Post,
            "/deployments",
            Some(&format!(
                r#"{{"configuration_id": {cid}, "batch_size": 10, "epochs": 3}}"#
            )),
        );
        assert_eq!(resp.status, Status::Created);
        let j = resp.body_json().unwrap();
        let rid = j.get("result_ids").as_arr().unwrap()[0].as_u64().unwrap();

        // Result starts deployed.
        let resp = dispatch(&r, Method::Get, &format!("/results/{rid}"), None);
        assert_eq!(
            resp.body_json().unwrap().get("status").as_str(),
            Some("deployed")
        );

        // Upload trained model (binary + metrics header).
        let blob = crate::runtime::ModelParams {
            tensors: vec![crate::runtime::ParamTensor {
                name: "w1".into(),
                shape: vec![2, 3],
                data: vec![0.5; 6],
            }],
        }
        .to_bytes();
        let mut req = Request::new(Method::Post, &format!("/results/{rid}/model"))
            .with_body(blob.clone(), "application/octet-stream");
        req.headers.insert(
            "x-kafka-ml-metrics".into(),
            r#"{"loss": 0.4, "accuracy": 0.9}"#.into(),
        );
        let resp = r.dispatch(req);
        assert_eq!(resp.status, Status::Ok, "{:?}", String::from_utf8_lossy(&resp.body));

        // Download.
        let resp = dispatch(&r, Method::Get, &format!("/results/{rid}/model"), None);
        assert_eq!(resp.body, blob);

        // Control log + inference auto-config.
        let dep_id = store.deployments()[0].id;
        let ctrl = format!(
            r#"{{"deployment_id": {dep_id}, "topic": "data", "partition": 0,
                 "offset": 0, "length": 220, "input_format": "RAW",
                 "input_config": {{"dtype": "f32", "shape": [8]}},
                 "validation_rate": 0.2, "total_msg": 220}}"#
        );
        assert_eq!(
            dispatch(&r, Method::Post, "/control", Some(&ctrl)).status,
            Status::Created
        );
        let resp = dispatch(
            &r,
            Method::Post,
            "/inferences",
            Some(&format!(r#"{{"result_id": {rid}, "replicas": 2}}"#)),
        );
        assert_eq!(resp.status, Status::Created);
        let iid = resp.body_json().unwrap().req_u64("id").unwrap();
        let resp = dispatch(&r, Method::Get, &format!("/inferences/{iid}"), None);
        let j = resp.body_json().unwrap();
        assert_eq!(j.get("input_format").as_str(), Some("RAW"));
        assert_eq!(j.at(&["input_config", "dtype"]).as_str(), Some("f32"));
    }

    #[test]
    fn errors_are_4xx() {
        let r = router(Arc::new(Store::new()));
        assert_eq!(
            dispatch(&r, Method::Get, "/models/99", None).status,
            Status::NotFound
        );
        assert_eq!(
            dispatch(&r, Method::Post, "/models", Some("not json")).status,
            Status::BadRequest
        );
        assert_eq!(
            dispatch(&r, Method::Post, "/models", Some(r#"{"name": "x"}"#)).status,
            Status::BadRequest
        );
        assert_eq!(
            dispatch(&r, Method::Get, "/results/abc", None).status,
            Status::BadRequest
        );
    }

    // ---- auth + tenancy ---------------------------------------------------

    fn dispatch_as(
        r: &Router,
        key: Option<&str>,
        method: Method,
        path: &str,
        body: Option<&str>,
    ) -> Response {
        let mut req = Request::new(method, path);
        if let Some(b) = body {
            req = req.with_body(b.as_bytes().to_vec(), "application/json");
        }
        if let Some(k) = key {
            req.headers
                .insert("authorization".into(), format!("Bearer {k}"));
        }
        r.dispatch(req)
    }

    #[test]
    fn with_auth_required_every_route_demands_a_key() {
        let store = Arc::new(Store::new());
        store.auth().set_require(true);
        let good = store.auth().create_key("alice", false).unwrap();
        let revoked = store.auth().create_key("alice", false).unwrap();
        store.auth().revoke(&revoked);
        let r = router(store);
        // Known and unknown paths alike answer 401 with no key…
        for path in ["/models", "/control", "/definitely/not/a/route"] {
            assert_eq!(
                dispatch_as(&r, None, Method::Get, path, None).status,
                Status::Unauthorized,
                "{path}"
            );
        }
        // …401 with a wrong key, 403 with a revoked one.
        assert_eq!(
            dispatch_as(&r, Some("kml_bogus"), Method::Get, "/models", None).status,
            Status::Unauthorized
        );
        assert_eq!(
            dispatch_as(&r, Some(&revoked), Method::Get, "/models", None).status,
            Status::Forbidden
        );
        assert_eq!(
            dispatch_as(&r, Some(&good), Method::Get, "/models", None).status,
            Status::Ok
        );
    }

    #[test]
    fn cross_tenant_reads_are_404_not_403() {
        let store = Arc::new(Store::new());
        store.auth().set_require(true);
        let alice = store.auth().create_key("alice", false).unwrap();
        let bob = store.auth().create_key("bob", false).unwrap();
        let admin = store.auth().create_key("ops", true).unwrap();
        let r = router(store);
        let body = format!(r#"{{"name": "m", "artifact_dir": "{}"}}"#, artifact_dir());
        let resp = dispatch_as(&r, Some(&alice), Method::Post, "/models", Some(&body));
        assert_eq!(resp.status, Status::Created);
        let mid = resp.body_json().unwrap().req_u64("id").unwrap();
        // Alice and the admin see it; bob gets the same 404 a missing
        // id would produce (no existence leak via 403).
        let path = format!("/models/{mid}");
        assert_eq!(dispatch_as(&r, Some(&alice), Method::Get, &path, None).status, Status::Ok);
        assert_eq!(dispatch_as(&r, Some(&admin), Method::Get, &path, None).status, Status::Ok);
        assert_eq!(dispatch_as(&r, Some(&bob), Method::Get, &path, None).status, Status::NotFound);
        let listed = dispatch_as(&r, Some(&bob), Method::Get, "/models", None);
        assert_eq!(listed.body_json().unwrap().as_arr().unwrap().len(), 0);
        // Bob can't build a configuration on alice's model either.
        let steal = format!(r#"{{"name": "c", "model_ids": [{mid}]}}"#);
        assert_eq!(
            dispatch_as(&r, Some(&bob), Method::Post, "/configurations", Some(&steal)).status,
            Status::BadRequest
        );
    }

    #[test]
    fn key_management_is_admin_only() {
        let store = Arc::new(Store::new());
        store.auth().set_require(true);
        let admin = store.auth().create_key("ops", true).unwrap();
        let tenant = store.auth().create_key("alice", false).unwrap();
        let r = router(store);
        for (method, path, body) in [
            (Method::Post, "/keys", Some(r#"{"tenant": "x"}"#)),
            (Method::Get, "/keys", None),
            (Method::Post, "/keys/revoke", Some(r#"{"token": "t"}"#)),
            (Method::Post, "/keys/quota", Some(r#"{"tenant": "x"}"#)),
        ] {
            assert_eq!(
                dispatch_as(&r, Some(&tenant), method, path, body).status,
                Status::Forbidden,
                "{path} must be admin-only"
            );
        }
        // The admin mints a key over the API and the new key works.
        let resp = dispatch_as(
            &r,
            Some(&admin),
            Method::Post,
            "/keys",
            Some(r#"{"tenant": "carol"}"#),
        );
        assert_eq!(resp.status, Status::Created);
        let token = resp.body_json().unwrap().req_str("token").unwrap().to_string();
        assert_eq!(
            dispatch_as(&r, Some(&token), Method::Get, "/models", None).status,
            Status::Ok
        );
        // Revoking it over the API flips it to 403.
        let resp = dispatch_as(
            &r,
            Some(&admin),
            Method::Post,
            "/keys/revoke",
            Some(&format!(r#"{{"token": "{token}"}}"#)),
        );
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(
            dispatch_as(&r, Some(&token), Method::Get, "/models", None).status,
            Status::Forbidden
        );
    }

    #[test]
    fn storage_quota_answers_429() {
        let store = Arc::new(Store::new());
        store.auth().set_require(true);
        let admin = store.auth().create_key("ops", true).unwrap();
        let alice = store.auth().create_key("alice", false).unwrap();
        store.auth().set_quota(
            "alice",
            crate::registry::auth::Quota {
                stored_bytes: Some(8),
                ..Default::default()
            },
        );
        let r = router(store);
        // Upload path: a blob bigger than the ceiling answers 429
        // before touching the store.
        let body = format!(r#"{{"name": "m", "artifact_dir": "{}"}}"#, artifact_dir());
        let resp = dispatch_as(&r, Some(&alice), Method::Post, "/models", Some(&body));
        assert_eq!(resp.status, Status::Created);
        let mut req = Request::new(Method::Post, "/results/999/model")
            .with_body(vec![0u8; 64], "application/octet-stream");
        req.headers
            .insert("authorization".into(), format!("Bearer {alice}"));
        assert_eq!(r.dispatch(req).status, Status::TooManyRequests);
        // The admin (no quota on "ops") is unaffected.
        let resp = dispatch_as(&r, Some(&admin), Method::Post, "/models", Some(&body));
        assert_eq!(resp.status, Status::Created);
    }
}
