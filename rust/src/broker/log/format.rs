//! The framed on-disk segment format.
//!
//! A sealed segment file is a plain concatenation of *record frames*:
//!
//! ```text
//! frame := len:u32 | crc:u32 | body            (all integers little-endian)
//! body  := offset:u64
//!        | timestamp_ms:u64
//!        | key_len:u32                         (u32::MAX = no key)
//!        | value_len:u32
//!        | header_count:u32
//!        | { name_len:u32, name, val_len:u32, val } * header_count
//!        | key bytes                           (when key_len != u32::MAX)
//!        | value bytes
//! ```
//!
//! `len` is the body length and `crc` a CRC-32 (IEEE) over the body, so
//! a reader can walk a file frame-by-frame and *prove* where the valid
//! prefix ends: a torn tail frame (crash mid-write, lost page) fails the
//! length or checksum test and recovery truncates the file there.
//!
//! Frames are self-contained (they carry their own offset), which keeps
//! two operations trivial: recovery re-derives `next_offset` from the
//! last decodable frame, and compaction can drop frames without
//! renumbering survivors — offset holes are already legal in the log.
//!
//! Decoding is zero-copy: key/value/header payloads come back as
//! [`Bytes`] slices of the caller's segment buffer, so every record read
//! from one resident segment shares that single allocation.

use crate::broker::record::Record;
use crate::util::bytes::Bytes;

/// Bytes of `len` + `crc` before each frame body.
pub const FRAME_HEADER_BYTES: usize = 8;

/// Fixed body bytes before the variable-length parts.
pub const BODY_FIXED_BYTES: usize = 28;

/// `key_len` sentinel for records without a key.
pub const NO_KEY: u32 = u32::MAX;

const CRC_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 (IEEE 802.3 polynomial) — the per-frame checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

/// Streaming CRC-32 over discontiguous parts. The wire layer checksums
/// a fetch response assembled as header chunks plus shared payload
/// slices (`writev`) — this lets it do so without ever concatenating
/// the parts into one buffer. `Crc32::new().update(a).finish()` equals
/// `crc32(a)`, and updates over split slices equal one update over
/// their concatenation.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) -> &mut Self {
        for &b in data {
            self.state = CRC_TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
        self
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// Why a frame could not be decoded. To the recovery scanner all three
/// mean the same thing: the valid prefix of the file ends here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// Fewer bytes remain than the frame claims (torn tail).
    Truncated,
    /// The body does not match its checksum (partial/corrupt write).
    BadChecksum,
    /// Internal lengths are inconsistent despite a matching checksum.
    Malformed,
}

/// One decoded frame: the record, its offset, and where the next frame
/// starts.
#[derive(Debug)]
pub struct DecodedFrame {
    pub offset: u64,
    pub record: Record,
    /// Byte position just past this frame.
    pub end: usize,
}

/// Exact encoded size of one record frame (header + body), without
/// encoding it — what the wire server uses to bound a response frame
/// before building it.
pub fn frame_size(record: &Record) -> usize {
    let key = record.key.as_ref().map(|k| k.len()).unwrap_or(0);
    let headers: usize = record
        .headers
        .iter()
        .map(|(name, val)| 8 + name.len() + val.len())
        .sum();
    FRAME_HEADER_BYTES + BODY_FIXED_BYTES + headers + key + record.value.len()
}

/// Append one record frame to `out`.
pub fn encode_frame(out: &mut Vec<u8>, offset: u64, record: &Record) {
    let start = out.len();
    out.extend_from_slice(&[0u8; FRAME_HEADER_BYTES]); // len + crc, patched below
    let body = out.len();
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&record.timestamp_ms.to_le_bytes());
    let key_len = record.key.as_ref().map(|k| k.len() as u32).unwrap_or(NO_KEY);
    out.extend_from_slice(&key_len.to_le_bytes());
    out.extend_from_slice(&(record.value.len() as u32).to_le_bytes());
    out.extend_from_slice(&(record.headers.len() as u32).to_le_bytes());
    for (name, val) in &record.headers {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(val.len() as u32).to_le_bytes());
        out.extend_from_slice(val);
    }
    if let Some(k) = &record.key {
        out.extend_from_slice(k);
    }
    out.extend_from_slice(&record.value);
    let len = (out.len() - body) as u32;
    let crc = crc32(&out[body..]);
    out[start..start + 4].copy_from_slice(&len.to_le_bytes());
    out[start + 4..start + 8].copy_from_slice(&crc.to_le_bytes());
}

/// Append everything of one record frame *except* the value payload —
/// `len` and `crc` still describe the complete frame (value included),
/// so `encode_frame_header(out, o, r)` followed by the raw bytes of
/// `r.value` is byte-identical to [`encode_frame`]. This is the
/// gather-write form: the wire server emits the header into a small
/// owned buffer and hands the value's [`Bytes`] straight to `writev`,
/// so a large fetched record never gets copied into a response buffer.
pub fn encode_frame_header(out: &mut Vec<u8>, offset: u64, record: &Record) {
    let start = out.len();
    out.extend_from_slice(&[0u8; FRAME_HEADER_BYTES]); // len + crc, patched below
    let body = out.len();
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&record.timestamp_ms.to_le_bytes());
    let key_len = record.key.as_ref().map(|k| k.len() as u32).unwrap_or(NO_KEY);
    out.extend_from_slice(&key_len.to_le_bytes());
    out.extend_from_slice(&(record.value.len() as u32).to_le_bytes());
    out.extend_from_slice(&(record.headers.len() as u32).to_le_bytes());
    for (name, val) in &record.headers {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(val.len() as u32).to_le_bytes());
        out.extend_from_slice(val);
    }
    if let Some(k) = &record.key {
        out.extend_from_slice(k);
    }
    let len = (out.len() - body + record.value.len()) as u32;
    let crc = Crc32::new()
        .update(&out[body..])
        .update(record.value.as_slice())
        .finish();
    out[start..start + 4].copy_from_slice(&len.to_le_bytes());
    out[start + 4..start + 8].copy_from_slice(&crc.to_le_bytes());
}

fn read_u32(data: &[u8], pos: usize, end: usize) -> Result<u32, FrameError> {
    if pos + 4 > end {
        return Err(FrameError::Malformed);
    }
    Ok(u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()))
}

fn read_u64(data: &[u8], pos: usize, end: usize) -> Result<u64, FrameError> {
    if pos + 8 > end {
        return Err(FrameError::Malformed);
    }
    Ok(u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap()))
}

/// Decode the frame starting at `pos` in `buf`. The returned record's
/// payloads are O(1) slices of `buf` — no bytes are copied (header
/// *names* are materialized as `String`s; they are metadata, not
/// payload).
pub fn decode_frame(buf: &Bytes, pos: usize) -> Result<DecodedFrame, FrameError> {
    let data = buf.as_slice();
    if pos + FRAME_HEADER_BYTES > data.len() {
        return Err(FrameError::Truncated);
    }
    let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
    let body = pos + FRAME_HEADER_BYTES;
    if len < BODY_FIXED_BYTES {
        return Err(FrameError::Malformed);
    }
    let Some(end) = body.checked_add(len) else {
        return Err(FrameError::Truncated);
    };
    if end > data.len() {
        return Err(FrameError::Truncated);
    }
    if crc32(&data[body..end]) != crc {
        return Err(FrameError::BadChecksum);
    }

    let offset = read_u64(data, body, end)?;
    let timestamp_ms = read_u64(data, body + 8, end)?;
    let key_len = read_u32(data, body + 16, end)?;
    let value_len = read_u32(data, body + 20, end)? as usize;
    let header_count = read_u32(data, body + 24, end)? as usize;
    let mut cur = body + BODY_FIXED_BYTES;

    let mut headers = Vec::with_capacity(header_count.min(64));
    for _ in 0..header_count {
        let name_len = read_u32(data, cur, end)? as usize;
        cur += 4;
        if cur + name_len > end {
            return Err(FrameError::Malformed);
        }
        let name = std::str::from_utf8(&data[cur..cur + name_len])
            .map_err(|_| FrameError::Malformed)?
            .to_string();
        cur += name_len;
        let val_len = read_u32(data, cur, end)? as usize;
        cur += 4;
        if cur + val_len > end {
            return Err(FrameError::Malformed);
        }
        headers.push((name, buf.slice(cur..cur + val_len)));
        cur += val_len;
    }

    let key = if key_len == NO_KEY {
        None
    } else {
        let key_len = key_len as usize;
        if cur + key_len > end {
            return Err(FrameError::Malformed);
        }
        let k = buf.slice(cur..cur + key_len);
        cur += key_len;
        Some(k)
    };

    if cur + value_len != end {
        return Err(FrameError::Malformed);
    }
    let value = buf.slice(cur..end);

    Ok(DecodedFrame {
        offset,
        record: Record {
            key,
            value,
            timestamp_ms,
            headers,
        },
        end,
    })
}

/// `<base offset, zero-padded to 20 digits>.seg` — zero-padding keeps
/// lexicographic directory order equal to offset order (Kafka's naming).
pub fn segment_file_name(base_offset: u64) -> String {
    format!("{base_offset:020}.seg")
}

/// Inverse of [`segment_file_name`]; `None` for foreign files.
pub fn parse_segment_file_name(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".seg")?;
    if stem.len() != 20 || !stem.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    stem.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_of(offset: u64, record: &Record) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_frame(&mut buf, offset, record);
        buf
    }

    #[test]
    fn frame_size_matches_encoding() {
        let records = [
            Record::new(Vec::<u8>::new()),
            Record::new(vec![1u8; 77]),
            Record::with_key(vec![1, 2, 3], vec![9u8; 100]).header("fmt", b"avro"),
            Record::new(vec![5]).header("a", b"x").header("bb", b"yy"),
        ];
        for rec in &records {
            assert_eq!(frame_of(9, rec).len(), frame_size(rec), "{rec:?}");
        }
    }

    #[test]
    fn roundtrip_full_record() {
        let rec = Record {
            key: Some(Bytes::from_vec(vec![1, 2, 3])),
            value: Bytes::from_vec(vec![9; 100]),
            timestamp_ms: 123_456,
            headers: vec![("fmt".to_string(), Bytes::from_vec(vec![7, 8]))],
        };
        let buf = Bytes::from_vec(frame_of(42, &rec));
        let f = decode_frame(&buf, 0).unwrap();
        assert_eq!(f.offset, 42);
        assert_eq!(f.end, buf.len());
        assert_eq!(f.record, rec);
        // Decoded payloads are slices of the frame buffer.
        assert!(Bytes::ptr_eq(&f.record.value, &buf));
        assert!(Bytes::ptr_eq(f.record.key.as_ref().unwrap(), &buf));
        assert!(Bytes::ptr_eq(&f.record.headers[0].1, &buf));
    }

    #[test]
    fn roundtrip_minimal_record() {
        let rec = Record {
            key: None,
            value: Bytes::new(),
            timestamp_ms: 1,
            headers: Vec::new(),
        };
        let buf = Bytes::from_vec(frame_of(0, &rec));
        assert_eq!(buf.len(), FRAME_HEADER_BYTES + BODY_FIXED_BYTES);
        let f = decode_frame(&buf, 0).unwrap();
        assert_eq!(f.offset, 0);
        assert_eq!(f.record, rec);
    }

    #[test]
    fn consecutive_frames_walk() {
        let mut raw = Vec::new();
        for i in 0..5u64 {
            encode_frame(&mut raw, i, &Record::new(vec![i as u8; 10]));
        }
        let buf = Bytes::from_vec(raw);
        let mut pos = 0;
        for i in 0..5u64 {
            let f = decode_frame(&buf, pos).unwrap();
            assert_eq!(f.offset, i);
            assert_eq!(f.record.value, vec![i as u8; 10]);
            pos = f.end;
        }
        assert_eq!(pos, buf.len());
        assert!(matches!(decode_frame(&buf, pos), Err(FrameError::Truncated)));
    }

    #[test]
    fn truncated_tail_detected() {
        let raw = frame_of(7, &Record::new(vec![5u8; 50]));
        for cut in [raw.len() - 1, raw.len() - 20, 7, 1] {
            let buf = Bytes::from_vec(raw[..cut].to_vec());
            match decode_frame(&buf, 0) {
                Err(FrameError::Truncated) => {}
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupted_byte_fails_checksum() {
        let raw = frame_of(7, &Record::with_key(vec![1], vec![5u8; 50]));
        for i in FRAME_HEADER_BYTES..raw.len() {
            let mut bad = raw.clone();
            bad[i] ^= 0xFF;
            let buf = Bytes::from_vec(bad);
            match decode_frame(&buf, 0) {
                Err(FrameError::BadChecksum) => {}
                other => panic!("flip at {i}: expected BadChecksum, got {other:?}"),
            }
        }
    }

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_crc_matches_one_shot_over_any_split() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = crc32(data);
        for split in 0..=data.len() {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), whole, "split at {split}");
        }
        assert_eq!(Crc32::new().finish(), crc32(b""));
    }

    #[test]
    fn frame_header_plus_value_equals_full_frame() {
        let records = [
            Record::new(Vec::<u8>::new()),
            Record::new(vec![0xAB; 300]),
            Record::with_key(vec![1, 2, 3], vec![9u8; 100]).header("fmt", b"avro"),
            Record::new(vec![5]).header("a", b"x").header("bb", b"yy"),
        ];
        for rec in &records {
            let full = frame_of(42, rec);
            let mut split = Vec::new();
            encode_frame_header(&mut split, 42, rec);
            assert_eq!(split.len(), frame_size(rec) - rec.value.len(), "{rec:?}");
            split.extend_from_slice(&rec.value);
            assert_eq!(split, full, "{rec:?}");
            // The patched crc covers the value, so the assembled frame
            // decodes like any other.
            let buf = Bytes::from_vec(split);
            let f = decode_frame(&buf, 0).unwrap();
            assert_eq!(&f.record, rec);
        }
    }

    #[test]
    fn file_name_roundtrip() {
        assert_eq!(segment_file_name(0), "00000000000000000000.seg");
        assert_eq!(parse_segment_file_name(&segment_file_name(12345)), Some(12345));
        assert_eq!(parse_segment_file_name("foo.seg"), None);
        assert_eq!(parse_segment_file_name("00000000000000000000.tmp"), None);
        assert_eq!(parse_segment_file_name("123.seg"), None);
    }
}
