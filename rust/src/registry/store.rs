//! Back-end state: models, configurations, deployments, results,
//! inference deployments and the control log.
//!
//! The object model mirrors §III's pipeline:
//!   model (A) → configuration (B) → deployment (C) → per-model
//!   training result (D/E) → inference deployment (E/F),
//! plus the control-message log the control logger (§IV-E) maintains so
//! data streams can be *reused* (§V) and inference input formats
//! auto-configured.

use super::auth::{AuthKeys, DEFAULT_TENANT};
use crate::broker::notify::{wait_any, WaitSet};
use crate::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Does a tenant scope admit an entity owned by `tenant`? `None` is the
/// unscoped view (auth disabled, or an admin key).
fn visible(scope: Option<&str>, tenant: &str) -> bool {
    scope.is_none_or(|s| s == tenant)
}

/// An ML model definition. In the paper this is Keras source pasted into
/// the Web UI; in the three-layer build it names an AOT artifact
/// directory (the model was authored+lowered at build time) — the
/// `source` field carries that reference and is validated on creation.
#[derive(Debug, Clone, PartialEq)]
pub struct MlModel {
    pub id: u64,
    /// Owning tenant (multi-tenant control plane); entities created
    /// through the unscoped in-process API belong to [`DEFAULT_TENANT`].
    pub tenant: String,
    pub name: String,
    /// Artifact directory (the compiled model), e.g. "artifacts/".
    pub artifact_dir: String,
    /// Free-form description (the paper's `imports`/source echo).
    pub description: String,
}

/// A logical group of models trained from the *same* data stream (§III-B).
#[derive(Debug, Clone, PartialEq)]
pub struct Configuration {
    pub id: u64,
    pub tenant: String,
    pub name: String,
    pub model_ids: Vec<u64>,
}

/// A training deployment of a configuration (§III-C).
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    pub id: u64,
    pub tenant: String,
    pub configuration_id: u64,
    pub batch_size: usize,
    pub epochs: usize,
    pub shuffle: bool,
    /// One result row per model in the configuration.
    pub result_ids: Vec<u64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainingStatus {
    Deployed,
    Training,
    Finished,
    Failed,
}

impl TrainingStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            TrainingStatus::Deployed => "deployed",
            TrainingStatus::Training => "training",
            TrainingStatus::Finished => "finished",
            TrainingStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<TrainingStatus> {
        Ok(match s {
            "deployed" => TrainingStatus::Deployed,
            "training" => TrainingStatus::Training,
            "finished" => TrainingStatus::Finished,
            "failed" => TrainingStatus::Failed,
            other => bail!("unknown status {other}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrainingMetrics {
    pub loss: f64,
    pub accuracy: f64,
    pub val_loss: Option<f64>,
    pub val_accuracy: Option<f64>,
    /// Per-epoch training loss (the loss curve of EXPERIMENTS.md).
    pub loss_curve: Vec<f64>,
}

/// Result of training one model of a deployment (§III-E).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingResult {
    pub id: u64,
    /// Inherited from the owning deployment.
    pub tenant: String,
    pub deployment_id: u64,
    pub model_id: u64,
    pub status: TrainingStatus,
    pub metrics: TrainingMetrics,
    /// Trained model blob (ModelParams wire format). Held separately so
    /// listing results doesn't copy weights.
    pub model_blob: Vec<u8>,
}

/// An inference deployment of a trained result (§III-E/F).
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceDeployment {
    pub id: u64,
    pub tenant: String,
    pub result_id: u64,
    pub replicas: u32,
    pub input_topic: String,
    pub output_topic: String,
    /// Auto-configured from the control log (§IV-E) unless overridden.
    pub input_format: String,
    pub input_config: Json,
}

/// A control message as logged by the control logger (§IV-E), enabling
/// §V's re-send without re-streaming.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlLogEntry {
    pub deployment_id: u64,
    pub topic: String,
    pub partition: u32,
    pub offset: u64,
    pub length: u64,
    pub input_format: String,
    pub input_config: Json,
    pub validation_rate: f64,
    pub total_msg: u64,
    pub logged_ms: u64,
}

#[derive(Default)]
struct State {
    models: BTreeMap<u64, MlModel>,
    configurations: BTreeMap<u64, Configuration>,
    deployments: BTreeMap<u64, Deployment>,
    results: BTreeMap<u64, TrainingResult>,
    inferences: BTreeMap<u64, InferenceDeployment>,
    control_log: Vec<ControlLogEntry>,
}

/// Thread-safe back-end store.
#[derive(Default)]
pub struct Store {
    state: Mutex<State>,
    next_id: AtomicU64,
    /// Signalled on every control-log append so pipeline callers can
    /// park in [`Store::wait_control_logged`] instead of sleep-polling
    /// the asynchronous control logger.
    control_wait: WaitSet,
    /// API keys / tenants / quotas — shared with the REST auth guard
    /// and the broker wire server so one credential model covers both
    /// planes. Persisted inside the store snapshot.
    auth: Arc<AuthKeys>,
}

impl Store {
    pub fn new() -> Store {
        Store {
            state: Mutex::new(State::default()),
            next_id: AtomicU64::new(1),
            control_wait: WaitSet::new(),
            auth: Arc::new(AuthKeys::new()),
        }
    }

    /// The key/tenant/quota table this store persists.
    pub fn auth(&self) -> &Arc<AuthKeys> {
        &self.auth
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// The tenant new entities belong to under `scope` (`None` = the
    /// unscoped in-process/admin view).
    fn owner(scope: Option<&str>) -> String {
        scope.unwrap_or(DEFAULT_TENANT).to_string()
    }

    // ---- models -----------------------------------------------------------

    pub fn create_model(&self, name: &str, artifact_dir: &str, description: &str) -> Result<u64> {
        self.create_model_scoped(None, name, artifact_dir, description)
    }

    pub fn create_model_scoped(
        &self,
        scope: Option<&str>,
        name: &str,
        artifact_dir: &str,
        description: &str,
    ) -> Result<u64> {
        // "the source code will be checked as a valid TensorFlow model"
        // (§III-A) — our equivalent: the artifact dir must resolve to a
        // runnable model spec. A dir without meta.json is fine (the
        // native backend runs the built-in spec with zero artifacts); a
        // meta.json that exists but does not parse is rejected.
        crate::runtime::ArtifactMeta::load_or_native(artifact_dir)
            .map_err(|e| anyhow!("invalid model artifact dir '{artifact_dir}': {e}"))?;
        let id = self.fresh_id();
        self.state.lock().unwrap().models.insert(
            id,
            MlModel {
                id,
                tenant: Store::owner(scope),
                name: name.to_string(),
                artifact_dir: artifact_dir.to_string(),
                description: description.to_string(),
            },
        );
        Ok(id)
    }

    pub fn model(&self, id: u64) -> Result<MlModel> {
        self.model_scoped(None, id)
    }

    /// Scoped read: an entity outside `scope` answers the SAME "unknown"
    /// error as a missing id, so existence never leaks across tenants.
    pub fn model_scoped(&self, scope: Option<&str>, id: u64) -> Result<MlModel> {
        self.state
            .lock()
            .unwrap()
            .models
            .get(&id)
            .filter(|m| visible(scope, &m.tenant))
            .cloned()
            .ok_or_else(|| anyhow!("unknown model {id}"))
    }

    pub fn models(&self) -> Vec<MlModel> {
        self.models_scoped(None)
    }

    pub fn models_scoped(&self, scope: Option<&str>) -> Vec<MlModel> {
        self.state
            .lock()
            .unwrap()
            .models
            .values()
            .filter(|m| visible(scope, &m.tenant))
            .cloned()
            .collect()
    }

    // ---- configurations ------------------------------------------------------

    pub fn create_configuration(&self, name: &str, model_ids: &[u64]) -> Result<u64> {
        self.create_configuration_scoped(None, name, model_ids)
    }

    pub fn create_configuration_scoped(
        &self,
        scope: Option<&str>,
        name: &str,
        model_ids: &[u64],
    ) -> Result<u64> {
        if model_ids.is_empty() {
            bail!("a configuration needs at least one model");
        }
        let st = self.state.lock().unwrap();
        for mid in model_ids {
            // Another tenant's model is as good as nonexistent.
            if !st.models.get(mid).is_some_and(|m| visible(scope, &m.tenant)) {
                bail!("configuration references unknown model {mid}");
            }
        }
        drop(st);
        let id = self.fresh_id();
        self.state.lock().unwrap().configurations.insert(
            id,
            Configuration {
                id,
                tenant: Store::owner(scope),
                name: name.to_string(),
                model_ids: model_ids.to_vec(),
            },
        );
        Ok(id)
    }

    pub fn configuration(&self, id: u64) -> Result<Configuration> {
        self.configuration_scoped(None, id)
    }

    pub fn configuration_scoped(&self, scope: Option<&str>, id: u64) -> Result<Configuration> {
        self.state
            .lock()
            .unwrap()
            .configurations
            .get(&id)
            .filter(|c| visible(scope, &c.tenant))
            .cloned()
            .ok_or_else(|| anyhow!("unknown configuration {id}"))
    }

    // ---- training deployments ---------------------------------------------------

    /// Deploy a configuration for training (§III-C): one result row (and
    /// later one Job) per model.
    pub fn create_deployment(
        &self,
        configuration_id: u64,
        batch_size: usize,
        epochs: usize,
        shuffle: bool,
    ) -> Result<Deployment> {
        self.create_deployment_scoped(None, configuration_id, batch_size, epochs, shuffle)
    }

    pub fn create_deployment_scoped(
        &self,
        scope: Option<&str>,
        configuration_id: u64,
        batch_size: usize,
        epochs: usize,
        shuffle: bool,
    ) -> Result<Deployment> {
        let conf = self.configuration_scoped(scope, configuration_id)?;
        if batch_size == 0 || epochs == 0 {
            bail!("batch_size and epochs must be positive");
        }
        // The deployment (and its results) inherit the CONFIGURATION's
        // tenant, so an admin deploying a tenant's configuration keeps
        // the rows inside that tenant.
        let tenant = conf.tenant.clone();
        let id = self.fresh_id();
        let mut result_ids = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            for mid in &conf.model_ids {
                let rid = self.fresh_id();
                st.results.insert(
                    rid,
                    TrainingResult {
                        id: rid,
                        tenant: tenant.clone(),
                        deployment_id: id,
                        model_id: *mid,
                        status: TrainingStatus::Deployed,
                        metrics: TrainingMetrics::default(),
                        model_blob: Vec::new(),
                    },
                );
                result_ids.push(rid);
            }
            st.deployments.insert(
                id,
                Deployment {
                    id,
                    tenant,
                    configuration_id,
                    batch_size,
                    epochs,
                    shuffle,
                    result_ids: result_ids.clone(),
                },
            );
        }
        self.deployment(id)
    }

    pub fn deployment(&self, id: u64) -> Result<Deployment> {
        self.deployment_scoped(None, id)
    }

    pub fn deployment_scoped(&self, scope: Option<&str>, id: u64) -> Result<Deployment> {
        self.state
            .lock()
            .unwrap()
            .deployments
            .get(&id)
            .filter(|d| visible(scope, &d.tenant))
            .cloned()
            .ok_or_else(|| anyhow!("unknown deployment {id}"))
    }

    pub fn deployments(&self) -> Vec<Deployment> {
        self.deployments_scoped(None)
    }

    pub fn deployments_scoped(&self, scope: Option<&str>) -> Vec<Deployment> {
        self.state
            .lock()
            .unwrap()
            .deployments
            .values()
            .filter(|d| visible(scope, &d.tenant))
            .cloned()
            .collect()
    }

    // ---- results ---------------------------------------------------------------

    pub fn result(&self, id: u64) -> Result<TrainingResult> {
        self.result_scoped(None, id)
    }

    pub fn result_scoped(&self, scope: Option<&str>, id: u64) -> Result<TrainingResult> {
        self.state
            .lock()
            .unwrap()
            .results
            .get(&id)
            .filter(|r| visible(scope, &r.tenant))
            .cloned()
            .ok_or_else(|| anyhow!("unknown result {id}"))
    }

    pub fn set_result_status(&self, id: u64, status: TrainingStatus) -> Result<()> {
        self.set_result_status_scoped(None, id, status)
    }

    pub fn set_result_status_scoped(
        &self,
        scope: Option<&str>,
        id: u64,
        status: TrainingStatus,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let r = st
            .results
            .get_mut(&id)
            .filter(|r| visible(scope, &r.tenant))
            .ok_or_else(|| anyhow!("unknown result {id}"))?;
        r.status = status;
        Ok(())
    }

    /// Upload trained model + metrics (the end of Algorithm 1).
    pub fn finish_result(
        &self,
        id: u64,
        metrics: TrainingMetrics,
        model_blob: Vec<u8>,
    ) -> Result<()> {
        self.finish_result_scoped(None, id, metrics, model_blob)
    }

    pub fn finish_result_scoped(
        &self,
        scope: Option<&str>,
        id: u64,
        metrics: TrainingMetrics,
        model_blob: Vec<u8>,
    ) -> Result<()> {
        // Validate the blob parses before accepting it.
        crate::runtime::ModelParams::from_bytes(&model_blob)
            .map_err(|e| anyhow!("result {id}: rejected model blob: {e}"))?;
        let mut st = self.state.lock().unwrap();
        let r = st
            .results
            .get_mut(&id)
            .filter(|r| visible(scope, &r.tenant))
            .ok_or_else(|| anyhow!("unknown result {id}"))?;
        r.metrics = metrics;
        r.model_blob = model_blob;
        r.status = TrainingStatus::Finished;
        Ok(())
    }

    pub fn download_model_blob(&self, result_id: u64) -> Result<Vec<u8>> {
        self.download_model_blob_scoped(None, result_id)
    }

    pub fn download_model_blob_scoped(
        &self,
        scope: Option<&str>,
        result_id: u64,
    ) -> Result<Vec<u8>> {
        let st = self.state.lock().unwrap();
        let r = st
            .results
            .get(&result_id)
            .filter(|r| visible(scope, &r.tenant))
            .ok_or_else(|| anyhow!("unknown result {result_id}"))?;
        if r.status != TrainingStatus::Finished {
            bail!("result {result_id} is {}, not finished", r.status.as_str());
        }
        Ok(r.model_blob.clone())
    }

    pub fn results_of_deployment(&self, deployment_id: u64) -> Vec<TrainingResult> {
        self.state
            .lock()
            .unwrap()
            .results
            .values()
            .filter(|r| r.deployment_id == deployment_id)
            .cloned()
            .collect()
    }

    // ---- inference deployments -----------------------------------------------------

    /// Deploy a finished result for inference (§III-E). `input_format` /
    /// `input_config` default to what the control logger recorded for
    /// the training deployment — the §IV-E auto-configuration.
    pub fn create_inference(
        &self,
        result_id: u64,
        replicas: u32,
        input_topic: &str,
        output_topic: &str,
        format_override: Option<(String, Json)>,
    ) -> Result<InferenceDeployment> {
        self.create_inference_scoped(None, result_id, replicas, input_topic, output_topic, format_override)
    }

    pub fn create_inference_scoped(
        &self,
        scope: Option<&str>,
        result_id: u64,
        replicas: u32,
        input_topic: &str,
        output_topic: &str,
        format_override: Option<(String, Json)>,
    ) -> Result<InferenceDeployment> {
        let result = self.result_scoped(scope, result_id)?;
        if result.status != TrainingStatus::Finished {
            bail!("result {result_id} not finished (is {})", result.status.as_str());
        }
        if replicas == 0 {
            bail!("replicas must be >= 1");
        }
        let (input_format, input_config) = match format_override {
            Some(fc) => fc,
            None => {
                let st = self.state.lock().unwrap();
                let entry = st
                    .control_log
                    .iter()
                    .rev()
                    .find(|e| e.deployment_id == result.deployment_id)
                    .ok_or_else(|| {
                        anyhow!(
                            "no control log entry for deployment {} — pass an explicit format",
                            result.deployment_id
                        )
                    })?;
                (entry.input_format.clone(), entry.input_config.clone())
            }
        };
        let id = self.fresh_id();
        let dep = InferenceDeployment {
            id,
            result_id,
            replicas,
            input_topic: input_topic.to_string(),
            output_topic: output_topic.to_string(),
            input_format,
            input_config,
            // Inference deployments live wherever the result they serve
            // lives, even when an admin key deployed them.
            tenant: result.tenant.clone(),
        };
        self.state.lock().unwrap().inferences.insert(id, dep.clone());
        Ok(dep)
    }

    pub fn inference(&self, id: u64) -> Result<InferenceDeployment> {
        self.inference_scoped(None, id)
    }

    pub fn inference_scoped(&self, scope: Option<&str>, id: u64) -> Result<InferenceDeployment> {
        self.state
            .lock()
            .unwrap()
            .inferences
            .get(&id)
            .filter(|i| visible(scope, &i.tenant))
            .cloned()
            .ok_or_else(|| anyhow!("unknown inference deployment {id}"))
    }

    // ---- control log ------------------------------------------------------------------

    pub fn log_control(&self, entry: ControlLogEntry) {
        self.state.lock().unwrap().control_log.push(entry);
        self.control_wait.notify_all();
    }

    /// Park until a control entry for `deployment_id` has been logged
    /// (the §IV-E logger consumes asynchronously) or `timeout` passes.
    /// Returns whether the entry is there. Loops around [`wait_any`]
    /// because an append for a *different* deployment also wakes us.
    pub fn wait_control_logged(&self, deployment_id: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.last_control_for(deployment_id).is_some() {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            wait_any(
                &[&self.control_wait],
                || self.last_control_for(deployment_id).is_some(),
                deadline,
            );
        }
    }

    pub fn control_log(&self) -> Vec<ControlLogEntry> {
        self.control_log_scoped(None)
    }

    /// Control entries visible to `scope`: an entry belongs to the
    /// tenant of the deployment it was logged for. Entries whose
    /// deployment has vanished are admin-only.
    pub fn control_log_scoped(&self, scope: Option<&str>) -> Vec<ControlLogEntry> {
        let st = self.state.lock().unwrap();
        st.control_log
            .iter()
            .filter(|e| match scope {
                None => true,
                Some(s) => st
                    .deployments
                    .get(&e.deployment_id)
                    .is_some_and(|d| d.tenant == s),
            })
            .cloned()
            .collect()
    }

    /// Latest control entry for a deployment (used for §V re-sends).
    pub fn last_control_for(&self, deployment_id: u64) -> Option<ControlLogEntry> {
        self.state
            .lock()
            .unwrap()
            .control_log
            .iter()
            .rev()
            .find(|e| e.deployment_id == deployment_id)
            .cloned()
    }

    // ---- persistence ------------------------------------------------------------
    //
    // The paper's Django back-end persists to a database; here the store
    // snapshots to a JSON file (model blobs hex-encoded) so a restarted
    // back-end pod recovers models, results and the control log.

    /// Serialize the whole store (including model blobs) to JSON.
    pub fn to_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let hex = |b: &[u8]| -> String {
            b.iter().map(|x| format!("{x:02x}")).collect()
        };
        Json::obj(vec![
            (
                "next_id",
                Json::from(self.next_id.load(Ordering::SeqCst)),
            ),
            (
                "models",
                Json::arr(
                    st.models
                        .values()
                        .map(|m| {
                            Json::obj(vec![
                                ("id", Json::from(m.id)),
                                ("name", Json::str(&m.name)),
                                ("artifact_dir", Json::str(&m.artifact_dir)),
                                ("description", Json::str(&m.description)),
                                ("tenant", Json::str(&m.tenant)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "configurations",
                Json::arr(
                    st.configurations
                        .values()
                        .map(|c| {
                            Json::obj(vec![
                                ("id", Json::from(c.id)),
                                ("name", Json::str(&c.name)),
                                ("tenant", Json::str(&c.tenant)),
                                (
                                    "model_ids",
                                    Json::arr(
                                        c.model_ids.iter().map(|&m| Json::from(m)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "deployments",
                Json::arr(
                    st.deployments
                        .values()
                        .map(|d| {
                            Json::obj(vec![
                                ("id", Json::from(d.id)),
                                ("configuration_id", Json::from(d.configuration_id)),
                                ("tenant", Json::str(&d.tenant)),
                                ("batch_size", Json::from(d.batch_size)),
                                ("epochs", Json::from(d.epochs)),
                                ("shuffle", Json::from(d.shuffle)),
                                (
                                    "result_ids",
                                    Json::arr(
                                        d.result_ids.iter().map(|&r| Json::from(r)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "results",
                Json::arr(
                    st.results
                        .values()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::from(r.id)),
                                ("deployment_id", Json::from(r.deployment_id)),
                                ("model_id", Json::from(r.model_id)),
                                ("tenant", Json::str(&r.tenant)),
                                ("status", Json::str(r.status.as_str())),
                                (
                                    "metrics",
                                    crate::registry::api::metrics_to_json(&r.metrics),
                                ),
                                ("model_blob_hex", Json::str(hex(&r.model_blob))),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "inferences",
                Json::arr(
                    st.inferences
                        .values()
                        .map(|i| {
                            Json::obj(vec![
                                ("id", Json::from(i.id)),
                                ("result_id", Json::from(i.result_id)),
                                ("tenant", Json::str(&i.tenant)),
                                ("replicas", Json::from(i.replicas as u64)),
                                ("input_topic", Json::str(&i.input_topic)),
                                ("output_topic", Json::str(&i.output_topic)),
                                ("input_format", Json::str(&i.input_format)),
                                ("input_config", i.input_config.clone()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "control_log",
                Json::arr(
                    st.control_log
                        .iter()
                        .map(crate::registry::api::control_to_json)
                        .collect(),
                ),
            ),
            ("auth", self.auth.to_json()),
        ])
    }

    /// Rebuild a store from a [`Store::to_json`] snapshot.
    pub fn from_json(j: &Json) -> Result<Store> {
        let store = Store::new();
        store.restore_from_json(j)?;
        Ok(store)
    }

    /// Load a snapshot into this (live) store, replacing its contents —
    /// used by `kafka-ml serve --state` to recover after a restart.
    pub fn restore_from_json(&self, j: &Json) -> Result<()> {
        // Snapshots from before multi-tenancy carry no tenant field;
        // everything they held belongs to the default tenant.
        let tenant_of = |v: &Json| -> String {
            v.get("tenant").as_str().unwrap_or(DEFAULT_TENANT).to_string()
        };
        let unhex = |s: &str| -> Result<Vec<u8>> {
            if s.len() % 2 != 0 {
                bail!("odd hex length");
            }
            (0..s.len())
                .step_by(2)
                .map(|i| {
                    u8::from_str_radix(&s[i..i + 2], 16)
                        .map_err(|e| anyhow!("bad hex: {e}"))
                })
                .collect()
        };
        {
            let mut st = self.state.lock().unwrap();
            st.models.clear();
            st.configurations.clear();
            st.deployments.clear();
            st.results.clear();
            st.inferences.clear();
            st.control_log.clear();
            for m in j.get("models").as_arr().unwrap_or(&[]) {
                let id = m.req_u64("id")?;
                st.models.insert(
                    id,
                    MlModel {
                        id,
                        name: m.req_str("name")?.to_string(),
                        artifact_dir: m.req_str("artifact_dir")?.to_string(),
                        description: m.get("description").as_str().unwrap_or("").to_string(),
                        tenant: tenant_of(m),
                    },
                );
            }
            for c in j.get("configurations").as_arr().unwrap_or(&[]) {
                let id = c.req_u64("id")?;
                st.configurations.insert(
                    id,
                    Configuration {
                        id,
                        name: c.req_str("name")?.to_string(),
                        tenant: tenant_of(c),
                        model_ids: c
                            .get("model_ids")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_u64())
                            .collect(),
                    },
                );
            }
            for d in j.get("deployments").as_arr().unwrap_or(&[]) {
                let id = d.req_u64("id")?;
                st.deployments.insert(
                    id,
                    Deployment {
                        id,
                        configuration_id: d.req_u64("configuration_id")?,
                        tenant: tenant_of(d),
                        batch_size: d.get("batch_size").as_usize().unwrap_or(10),
                        epochs: d.get("epochs").as_usize().unwrap_or(1),
                        shuffle: d.get("shuffle").as_bool().unwrap_or(true),
                        result_ids: d
                            .get("result_ids")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_u64())
                            .collect(),
                    },
                );
            }
            for r in j.get("results").as_arr().unwrap_or(&[]) {
                let id = r.req_u64("id")?;
                st.results.insert(
                    id,
                    TrainingResult {
                        id,
                        deployment_id: r.req_u64("deployment_id")?,
                        model_id: r.req_u64("model_id")?,
                        tenant: tenant_of(r),
                        status: TrainingStatus::parse(r.req_str("status")?)?,
                        metrics: crate::registry::api::metrics_from_json(r.get("metrics")),
                        model_blob: unhex(r.get("model_blob_hex").as_str().unwrap_or(""))?,
                    },
                );
            }
            for i in j.get("inferences").as_arr().unwrap_or(&[]) {
                let id = i.req_u64("id")?;
                st.inferences.insert(
                    id,
                    InferenceDeployment {
                        id,
                        result_id: i.req_u64("result_id")?,
                        tenant: tenant_of(i),
                        replicas: i.get("replicas").as_u64().unwrap_or(1) as u32,
                        input_topic: i.req_str("input_topic")?.to_string(),
                        output_topic: i.req_str("output_topic")?.to_string(),
                        input_format: i.req_str("input_format")?.to_string(),
                        input_config: i.get("input_config").clone(),
                    },
                );
            }
            for e in j.get("control_log").as_arr().unwrap_or(&[]) {
                st.control_log
                    .push(crate::registry::api::control_from_json(e)?);
            }
        }
        if !j.get("auth").is_null() {
            self.auth.restore_from_json(j.get("auth"))?;
        }
        self.next_id
            .store(j.get("next_id").as_u64().unwrap_or(1), Ordering::SeqCst);
        Ok(())
    }

    /// Persist to a file (atomic-ish: write then rename).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, crate::json::to_string_pretty(&self.to_json()))?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Store> {
        let text = std::fs::read_to_string(path)?;
        let j = crate::json::parse(&text).map_err(|e| anyhow!("store snapshot: {e}"))?;
        Store::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelParams, ParamTensor};

    // A store whose model validation can pass: we create a real minimal
    // artifact dir once per test binary.
    fn artifact_dir() -> String {
        let dir = std::env::temp_dir().join("kafka-ml-test-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let meta = r#"{
          "spec": {"input_dim": 2, "hidden": [3], "classes": 2, "batch": 4,
                   "lr": 0.001, "seed": 1},
          "params": [{"name": "w1", "shape": [2, 3], "dtype": "f32"}],
          "artifacts": {}
        }"#;
        std::fs::write(dir.join("meta.json"), meta).unwrap();
        dir.to_string_lossy().to_string()
    }

    fn blob() -> Vec<u8> {
        ModelParams {
            tensors: vec![ParamTensor {
                name: "w1".into(),
                shape: vec![2, 3],
                data: vec![0.0; 6],
            }],
        }
        .to_bytes()
    }

    fn store_with_model() -> (Store, u64) {
        let s = Store::new();
        let mid = s.create_model("copd", &artifact_dir(), "HCOPD MLP").unwrap();
        (s, mid)
    }

    #[test]
    fn model_creation_validates_artifacts() {
        let s = Store::new();
        // A dir with a *corrupt* meta.json is rejected…
        let bad_dir = std::env::temp_dir()
            .join(format!("kafka-ml-test-bad-artifacts-{}", std::process::id()));
        std::fs::create_dir_all(&bad_dir).unwrap();
        std::fs::write(bad_dir.join("meta.json"), "{definitely not json").unwrap();
        assert!(s
            .create_model("bad", &bad_dir.to_string_lossy(), "")
            .is_err());
        let _ = std::fs::remove_dir_all(&bad_dir);
        // …but a dir with no meta.json at all is a valid *native* model
        // (the pure-Rust backend needs zero artifacts).
        assert!(s.create_model("native", "/nonexistent", "").is_ok());
        let (_, mid) = store_with_model();
        assert!(mid > 0);
    }

    #[test]
    fn pipeline_objects_chain() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("grid", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 5, true).unwrap();
        assert_eq!(dep.result_ids.len(), 1);
        let r = s.result(dep.result_ids[0]).unwrap();
        assert_eq!(r.status, TrainingStatus::Deployed);
        assert_eq!(r.model_id, mid);
    }

    #[test]
    fn configuration_with_n_models_spawns_n_results() {
        let (s, m1) = store_with_model();
        let m2 = s.create_model("copd-2", &artifact_dir(), "").unwrap();
        let cid = s.create_configuration("pair", &[m1, m2]).unwrap();
        let dep = s.create_deployment(cid, 10, 1, false).unwrap();
        assert_eq!(dep.result_ids.len(), 2);
    }

    #[test]
    fn configuration_requires_known_models() {
        let (s, mid) = store_with_model();
        assert!(s.create_configuration("x", &[]).is_err());
        assert!(s.create_configuration("x", &[mid, 999]).is_err());
    }

    #[test]
    fn finish_result_and_download() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("c", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 1, false).unwrap();
        let rid = dep.result_ids[0];
        // Not downloadable while unfinished.
        assert!(s.download_model_blob(rid).is_err());
        let metrics = TrainingMetrics {
            loss: 0.5,
            accuracy: 0.8,
            val_loss: Some(0.6),
            val_accuracy: Some(0.75),
            loss_curve: vec![1.0, 0.7, 0.5],
        };
        s.finish_result(rid, metrics.clone(), blob()).unwrap();
        let r = s.result(rid).unwrap();
        assert_eq!(r.status, TrainingStatus::Finished);
        assert_eq!(r.metrics, metrics);
        assert_eq!(s.download_model_blob(rid).unwrap(), blob());
    }

    #[test]
    fn finish_rejects_garbage_blob() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("c", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 1, false).unwrap();
        assert!(s
            .finish_result(dep.result_ids[0], TrainingMetrics::default(), vec![1, 2, 3])
            .is_err());
    }

    #[test]
    fn inference_requires_finished_result() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("c", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 1, false).unwrap();
        let rid = dep.result_ids[0];
        assert!(s.create_inference(rid, 2, "in", "out", None).is_err());
        s.finish_result(rid, TrainingMetrics::default(), blob()).unwrap();
        // No control log + no override => error.
        assert!(s.create_inference(rid, 2, "in", "out", None).is_err());
        // With override it works.
        let inf = s
            .create_inference(rid, 2, "in", "out", Some(("RAW".into(), Json::Null)))
            .unwrap();
        assert_eq!(inf.replicas, 2);
    }

    #[test]
    fn wait_control_logged_wakes_on_async_append() {
        use std::sync::Arc;
        let s = Arc::new(Store::new());
        // Nothing logged: the wait times out empty-handed.
        let t0 = Instant::now();
        assert!(!s.wait_control_logged(7, Duration::from_millis(10)));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.log_control(ControlLogEntry {
                deployment_id: 7,
                topic: "data".into(),
                partition: 0,
                offset: 0,
                length: 1,
                input_format: "RAW".into(),
                input_config: Json::Null,
                validation_rate: 0.0,
                total_msg: 1,
                logged_ms: 1,
            });
        });
        let t0 = Instant::now();
        assert!(s.wait_control_logged(7, Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
        // Fast path: an already-logged entry returns without parking.
        let t0 = Instant::now();
        assert!(s.wait_control_logged(7, Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn inference_autoconfigures_from_control_log() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("c", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 1, false).unwrap();
        let rid = dep.result_ids[0];
        s.finish_result(rid, TrainingMetrics::default(), blob()).unwrap();
        s.log_control(ControlLogEntry {
            deployment_id: dep.id,
            topic: "data".into(),
            partition: 0,
            offset: 0,
            length: 100,
            input_format: "AVRO".into(),
            input_config: Json::obj(vec![("x", Json::num(1.0))]),
            validation_rate: 0.2,
            total_msg: 100,
            logged_ms: 1,
        });
        let inf = s.create_inference(rid, 1, "in", "out", None).unwrap();
        assert_eq!(inf.input_format, "AVRO");
        assert_eq!(inf.input_config.get("x").as_f64(), Some(1.0));
    }

    #[test]
    fn persistence_roundtrip() {
        let (s, mid) = store_with_model();
        let cid = s.create_configuration("c", &[mid]).unwrap();
        let dep = s.create_deployment(cid, 10, 3, true).unwrap();
        let rid = dep.result_ids[0];
        s.finish_result(
            rid,
            TrainingMetrics {
                loss: 0.3,
                accuracy: 0.9,
                val_loss: Some(0.4),
                val_accuracy: Some(0.85),
                loss_curve: vec![1.0, 0.5, 0.3],
            },
            blob(),
        )
        .unwrap();
        s.log_control(ControlLogEntry {
            deployment_id: dep.id,
            topic: "data".into(),
            partition: 0,
            offset: 0,
            length: 50,
            input_format: "RAW".into(),
            input_config: Json::obj(vec![("dtype", Json::str("f32"))]),
            validation_rate: 0.2,
            total_msg: 50,
            logged_ms: 123,
        });
        let inf = s
            .create_inference(rid, 2, "in", "out", None)
            .unwrap();

        let path = std::env::temp_dir().join("kafka-ml-store-test.json");
        s.save(&path).unwrap();
        let back = Store::load(&path).unwrap();

        assert_eq!(back.model(mid).unwrap(), s.model(mid).unwrap());
        assert_eq!(back.configuration(cid).unwrap(), s.configuration(cid).unwrap());
        assert_eq!(back.deployment(dep.id).unwrap(), s.deployment(dep.id).unwrap());
        assert_eq!(back.result(rid).unwrap(), s.result(rid).unwrap());
        assert_eq!(back.inference(inf.id).unwrap(), inf);
        assert_eq!(back.control_log(), s.control_log());
        assert_eq!(back.download_model_blob(rid).unwrap(), blob());
        // Fresh ids continue past the snapshot (no collisions).
        let m2 = back.create_model("again", &artifact_dir(), "").unwrap();
        assert!(m2 > inf.id);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("kafka-ml-store-garbage.json");
        std::fs::write(&path, "{ not json").unwrap();
        assert!(Store::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn control_log_latest_wins() {
        let (s, _) = store_with_model();
        for i in 0..3u64 {
            s.log_control(ControlLogEntry {
                deployment_id: 7,
                topic: format!("t{i}"),
                partition: 0,
                offset: i,
                length: 10,
                input_format: "RAW".into(),
                input_config: Json::Null,
                validation_rate: 0.0,
                total_msg: 10,
                logged_ms: i,
            });
        }
        assert_eq!(s.last_control_for(7).unwrap().topic, "t2");
        assert!(s.last_control_for(8).is_none());
        assert_eq!(s.control_log().len(), 3);
    }

    // ---- multi-tenancy ----------------------------------------------------

    /// A full pipeline owned by tenant `t`, returning (model, config,
    /// deployment, finished result) ids.
    fn tenant_pipeline(s: &Store, t: &str) -> (u64, u64, u64, u64) {
        let scope = Some(t);
        let mid = s
            .create_model_scoped(scope, &format!("{t}-model"), &artifact_dir(), "")
            .unwrap();
        let cid = s.create_configuration_scoped(scope, "c", &[mid]).unwrap();
        let dep = s.create_deployment_scoped(scope, cid, 10, 1, false).unwrap();
        let rid = dep.result_ids[0];
        s.finish_result_scoped(scope, rid, TrainingMetrics::default(), blob())
            .unwrap();
        (mid, cid, dep.id, rid)
    }

    #[test]
    fn cross_tenant_rows_are_invisible_and_immutable() {
        let s = Store::new();
        let (mid, cid, did, rid) = tenant_pipeline(&s, "alice");
        let bob = Some("bob");
        // Reads: every lookup answers exactly like a missing id.
        let missing = s.model_scoped(bob, 999_999).unwrap_err().to_string();
        let hidden = s.model_scoped(bob, mid).unwrap_err().to_string();
        assert_eq!(
            missing.replace("999999", &mid.to_string()),
            hidden,
            "cross-tenant miss must be indistinguishable from a missing id"
        );
        assert!(s.configuration_scoped(bob, cid).is_err());
        assert!(s.deployment_scoped(bob, did).is_err());
        assert!(s.result_scoped(bob, rid).is_err());
        assert!(s.download_model_blob_scoped(bob, rid).is_err());
        assert!(s.models_scoped(bob).is_empty());
        assert!(s.deployments_scoped(bob).is_empty());
        // Writes: bob can neither mutate alice's result nor build on her
        // model/result.
        assert!(s
            .set_result_status_scoped(bob, rid, TrainingStatus::Training)
            .is_err());
        assert!(s
            .finish_result_scoped(bob, rid, TrainingMetrics::default(), blob())
            .is_err());
        assert!(s.create_configuration_scoped(bob, "steal", &[mid]).is_err());
        assert!(s
            .create_inference_scoped(bob, rid, 1, "in", "out", Some(("RAW".into(), Json::Null)))
            .is_err());
        // Alice herself (and an unscoped admin) still see everything.
        assert!(s.model_scoped(Some("alice"), mid).is_ok());
        assert!(s.model_scoped(None, mid).is_ok());
        assert_eq!(s.models_scoped(Some("alice")).len(), 1);
        assert_eq!(s.models_scoped(None).len(), 1);
    }

    #[test]
    fn control_log_is_scoped_to_the_deployments_tenant() {
        let s = Store::new();
        let (_, _, did, _) = tenant_pipeline(&s, "alice");
        s.log_control(ControlLogEntry {
            deployment_id: did,
            topic: "data".into(),
            partition: 0,
            offset: 0,
            length: 1,
            input_format: "RAW".into(),
            input_config: Json::Null,
            validation_rate: 0.0,
            total_msg: 1,
            logged_ms: 0,
        });
        assert_eq!(s.control_log_scoped(Some("alice")).len(), 1);
        assert!(s.control_log_scoped(Some("bob")).is_empty());
        assert_eq!(s.control_log_scoped(None).len(), 1);
    }

    #[test]
    fn deployment_and_results_inherit_configuration_tenant() {
        let s = Store::new();
        let (mid, cid, did, rid) = tenant_pipeline(&s, "alice");
        assert_eq!(s.model(mid).unwrap().tenant, "alice");
        assert_eq!(s.configuration(cid).unwrap().tenant, "alice");
        // An *admin* deploying alice's configuration keeps the rows in
        // alice's tenant (they describe her workload, not the admin's).
        let dep2 = s.create_deployment_scoped(None, cid, 10, 1, false).unwrap();
        assert_eq!(dep2.tenant, "alice");
        assert_eq!(s.result(dep2.result_ids[0]).unwrap().tenant, "alice");
        assert_eq!(s.deployment(did).unwrap().tenant, "alice");
        let inf = s
            .create_inference_scoped(
                Some("alice"),
                rid,
                1,
                "in",
                "out",
                Some(("RAW".into(), Json::Null)),
            )
            .unwrap();
        assert_eq!(inf.tenant, "alice");
    }

    #[test]
    fn unscoped_calls_default_to_the_default_tenant() {
        let (s, mid) = store_with_model();
        assert_eq!(s.model(mid).unwrap().tenant, DEFAULT_TENANT);
        // Scoped readers of the default tenant see it; others don't.
        assert!(s.model_scoped(Some(DEFAULT_TENANT), mid).is_ok());
        assert!(s.model_scoped(Some("bob"), mid).is_err());
    }

    #[test]
    fn persistence_keeps_tenants_and_auth_keys() {
        let s = Store::new();
        let (mid, _, _, rid) = tenant_pipeline(&s, "alice");
        let token = s.auth().create_key("alice", false).unwrap();
        s.auth()
            .set_quota("alice", crate::registry::auth::Quota {
                records_per_sec: Some(100),
                stored_bytes: Some(1 << 20),
                ..Default::default()
            });
        s.auth().set_require(true);
        let path = std::env::temp_dir().join(format!(
            "kafka-ml-store-tenancy-{}.json",
            std::process::id()
        ));
        s.save(&path).unwrap();
        let back = Store::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.model(mid).unwrap().tenant, "alice");
        assert_eq!(back.result(rid).unwrap().tenant, "alice");
        assert!(back.auth().require_auth());
        match back.auth().authenticate(&token) {
            crate::registry::auth::AuthOutcome::Accepted(id) => {
                assert_eq!(id.tenant, "alice");
                assert!(!id.admin);
            }
            other => panic!("expected key to survive the snapshot, got {other:?}"),
        }
        assert_eq!(
            back.auth().quota("alice").stored_bytes,
            Some(1 << 20)
        );
    }

    #[test]
    fn pre_tenancy_snapshots_load_into_the_default_tenant() {
        // A snapshot written before multi-tenancy existed has no
        // "tenant" keys and no "auth" section.
        let j = crate::json::parse(
            r#"{"next_id": 5, "models": [
                 {"id": 1, "name": "m", "artifact_dir": "/nonexistent",
                  "description": ""}]}"#,
        )
        .unwrap();
        let back = Store::from_json(&j).unwrap();
        assert_eq!(back.model(1).unwrap().tenant, DEFAULT_TENANT);
        assert!(!back.auth().require_auth());
    }
}
