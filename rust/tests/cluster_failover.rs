//! The ISSUE-10 acceptance tests: a 3-broker cluster over loopback TCP
//! surviving the death of a partition leader.
//!
//! Topology: three `Cluster` processes-in-miniature, each with its own
//! wire server, replica puller and heartbeat supervisor, sharing one
//! epoch-versioned roster. "Killing" a broker shuts its wire server
//! down and stops its background threads — to every peer and client it
//! looks exactly like a SIGKILLed process: connections reset, dials
//! refused, heartbeats unanswered.
//!
//! * `killing_the_leader_loses_no_acked_records` — the kill-the-leader
//!   e2e: at `acks=replicated`, records acked before and after the
//!   leader dies are all readable from the promoted follower; the
//!   routed client converges on the new leader without surfacing an
//!   error.
//! * `deposed_leader_fences_stale_produces` — the split-brain fence: a
//!   broker that adopted a view under which it no longer leads refuses
//!   a direct (stale) produce with `not-leader`, while a routed client
//!   transparently refreshes and lands on the real leader.

use kafka_ml::broker::{
    Acks, AckMode, BrokerConfig, BrokerHandle, BrokerServer, BrokerTransport, ClientLocality,
    Cluster, ClusterCtl, ClusterHandle, PeerConnector, Producer, ProducerConfig, Record,
    RemoteBroker, ReplicaPuller,
};
use kafka_ml::orchestrator::ClusterSupervisor;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One broker "process": in-process core + wire server + cluster
/// runtime threads.
struct TestBroker {
    cluster: ClusterHandle,
    ctl: Arc<ClusterCtl>,
    server: Option<BrokerServer>,
    puller: Option<ReplicaPuller>,
    supervisor: Option<ClusterSupervisor>,
}

impl TestBroker {
    fn addr(&self) -> String {
        self.server.as_ref().expect("broker already killed").addr().to_string()
    }

    /// SIGKILL, as seen from outside the process: background threads
    /// stop, the listener closes, live connections reset.
    fn kill(&mut self) {
        self.supervisor.take();
        self.puller.take();
        if let Some(server) = self.server.take() {
            server.shutdown();
        }
    }
}

impl Drop for TestBroker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Boot an N=3 cluster: servers bind first (the roster needs real
/// addresses), then every broker attaches the shared roster and starts
/// its replica puller + heartbeat supervisor (50 ms beat, 3 misses —
/// death declared in ~150 ms).
fn start_trio(ack: AckMode) -> Vec<TestBroker> {
    let cfg = BrokerConfig { ack_mode: ack, ..Default::default() };
    let cores: Vec<ClusterHandle> = (0..3).map(|_| Cluster::new(cfg.clone())).collect();
    let servers: Vec<BrokerServer> = cores
        .iter()
        .map(|c| BrokerServer::start("127.0.0.1:0", c.clone()).unwrap())
        .collect();
    let roster: Vec<(u32, String)> = servers
        .iter()
        .enumerate()
        .map(|(i, s)| (i as u32, s.addr().to_string()))
        .collect();
    cores
        .iter()
        .zip(servers)
        .enumerate()
        .map(|(i, (cluster, server))| {
            let ctl = ClusterCtl::new(i as u32, roster.clone());
            cluster.attach_clusterctl(
                ctl.clone(),
                PeerConnector::new(|addr| {
                    Ok(RemoteBroker::connect_peer(addr, None)? as BrokerHandle)
                }),
            );
            let puller =
                ReplicaPuller::start(cluster.clone(), ctl.clone(), Duration::from_millis(5));
            let supervisor = ClusterSupervisor::start(
                cluster.clone(),
                ctl.clone(),
                Duration::from_millis(50),
                3,
            );
            TestBroker {
                cluster: cluster.clone(),
                ctl,
                server: Some(server),
                puller: Some(puller),
                supervisor: Some(supervisor),
            }
        })
        .collect()
}

/// Rendezvous placement is deterministic per name: scan candidates for
/// a topic whose partition 0 is NOT led by broker 0 — broker 0 stays
/// alive as the client's bootstrap while we kill the leader.
fn topic_not_led_by_zero(ctl: &ClusterCtl) -> (String, u32) {
    let view = ctl.view();
    for i in 0..32 {
        let name = format!("fo-t{i}");
        let leader = view.leader_of(&name, 0).unwrap();
        if leader != 0 {
            return (name, leader);
        }
    }
    panic!("no candidate topic avoids broker 0 as leader");
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn killing_the_leader_loses_no_acked_records() {
    let mut brokers = start_trio(AckMode::Replicated);
    let (topic, leader) = topic_not_led_by_zero(&brokers[0].ctl);

    // The client bootstraps off broker 0 (a survivor) and routes every
    // produce to the partition leader.
    let client: BrokerHandle = RemoteBroker::connect(&brokers[0].addr()).unwrap();
    client.create_topic(&topic, 1).unwrap();
    let mut producer = Producer::new(
        client.clone(),
        ProducerConfig {
            batch_size: 8,
            acks: Acks::AtLeastOnce,
            locality: ClientLocality::Remote,
            ..Default::default()
        },
    );

    // Phase 1: 20 records acked at acks=replicated (each is on the
    // follower before its ack, by construction).
    for i in 0..20u32 {
        producer
            .send_to(&topic, 0, Record::new(format!("v-{i}").into_bytes()))
            .unwrap();
    }
    producer.flush().unwrap();

    // SIGKILL the leader mid-pipeline.
    brokers[leader as usize].kill();

    // Phase 2: 40 more records through the failover window. The routed
    // client re-resolves on reset connections / not-leader answers; at
    // least-once, every record that gets an ack must survive.
    for i in 20..60u32 {
        producer
            .send_to(&topic, 0, Record::new(format!("v-{i}").into_bytes()))
            .unwrap();
    }
    producer.flush().unwrap();
    drop(producer);

    // The survivors declared the death within the heartbeat timeout and
    // agree on a promoted leader that is not the corpse.
    let survivors: Vec<&TestBroker> =
        brokers.iter().filter(|b| b.ctl.local_id() != leader).collect();
    for s in &survivors {
        wait_until("survivor sees the leader dead", Duration::from_secs(5), || {
            !s.ctl.view().is_alive(leader)
        });
        assert!(s.ctl.epoch() > 1);
    }
    let new_leader = survivors[0].ctl.view().leader_of(&topic, 0).unwrap();
    assert_ne!(new_leader, leader, "promotion did not move the partition");
    assert_eq!(survivors[1].ctl.view().leader_of(&topic, 0), Some(new_leader));

    // Zero acked-record loss: every acked value is readable through the
    // routed client (served by the promoted leader). At-least-once may
    // duplicate; it must never lose.
    let batch = client
        .fetch_batch(&topic, 0, 0, 10_000, ClientLocality::Remote)
        .unwrap();
    let seen: std::collections::HashSet<String> = batch
        .records
        .iter()
        .map(|(_, r)| String::from_utf8(r.value.to_vec()).unwrap())
        .collect();
    for i in 0..60u32 {
        assert!(seen.contains(&format!("v-{i}")), "acked record v-{i} lost in failover");
    }

    // And the promoted copy is the one the new leader serves locally.
    let on_new_leader = brokers[new_leader as usize]
        .cluster
        .fetch_batch(&topic, 0, 0, 10_000, ClientLocality::InCluster)
        .unwrap();
    assert!(on_new_leader.len() >= 60);
}

#[test]
fn deposed_leader_fences_stale_produces() {
    let brokers = start_trio(AckMode::Leader);
    let (topic, leader) = topic_not_led_by_zero(&brokers[0].ctl);

    let client: BrokerHandle = RemoteBroker::connect(&brokers[0].addr()).unwrap();
    client.create_topic(&topic, 1).unwrap();
    client
        .produce(&topic, 0, &[Record::new(b"before".to_vec())], ClientLocality::Remote, None)
        .unwrap();

    // The heir is the old follower; wait for the async pull to mirror
    // "before" onto it so offsets stay deterministic post-promotion.
    let heir = brokers[0].ctl.view().follower_of(&topic, 0).unwrap();
    wait_until("heir mirrors the first record", Duration::from_secs(5), || {
        brokers[heir as usize]
            .cluster
            .offsets(&topic, 0)
            .map(|(_, latest)| latest >= 1)
            .unwrap_or(false)
    });

    // Depose the leader without killing it: every broker adopts a view
    // under which it is dead (what the supervisors would converge on;
    // installing everywhere makes the test deterministic instead of
    // racing the heartbeat threads).
    let (_, post_mortem) = brokers[0].ctl.mark_dead(leader).unwrap();
    for b in &brokers {
        // mark_dead already moved broker 0's ctl; install is a no-op
        // there and adopts the strictly newer epoch on the others —
        // including the deposed leader itself.
        b.cluster.install_cluster_view(post_mortem.clone()).unwrap();
    }
    let new_leader = post_mortem.leader_of(&topic, 0).unwrap();
    assert_ne!(new_leader, leader);

    // A direct, non-routing produce at the deposed broker — a client
    // still believing the old map — is refused with the fence, not
    // silently appended.
    let stale: BrokerHandle =
        RemoteBroker::connect_peer(&brokers[leader as usize].addr(), None).unwrap();
    let err = stale
        .produce(&topic, 0, &[Record::new(b"stale".to_vec())], ClientLocality::Remote, None)
        .unwrap_err();
    assert!(
        kafka_ml::broker::clusterctl::is_not_leader(&format!("{err:#}")),
        "expected a not-leader fence, got: {err:#}"
    );

    // The routed client holds the old epoch too — its produce hits the
    // same fence, refreshes metadata, and transparently re-routes to
    // the promoted leader.
    let base = client
        .produce(&topic, 0, &[Record::new(b"after".to_vec())], ClientLocality::Remote, None)
        .unwrap();
    assert_eq!(base, 1, "re-routed produce did not extend the log");

    // The fenced record exists nowhere; the re-routed one is readable
    // through the routed client (served by the promoted leader).
    let batch = client
        .fetch_batch(&topic, 0, 0, 10, ClientLocality::Remote)
        .unwrap();
    let values: Vec<&[u8]> = batch.records.iter().map(|(_, r)| r.value.as_slice()).collect();
    assert!(values.contains(&b"after".as_slice()), "re-routed record missing");
    assert!(!values.contains(&b"stale".as_slice()), "fenced record was appended");
}
