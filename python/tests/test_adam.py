"""Pallas fused Adam kernel vs oracle + optimizer invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import adam_update
from compile.kernels.ref import adam_update_ref

SHAPES = [(7,), (3, 5), (8, 16), (1,), (2, 3, 4), (130,), (1030,)]


@given(
    shape=st.sampled_from(SHAPES),
    t=st.integers(1, 10_000),
    lr=st.floats(1e-5, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_matches_ref(shape, t, lr, seed):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=shape), jnp.float32)  # noqa: E731
    p, g, m, v = mk(), mk(), jnp.abs(mk()) * 0.1, jnp.abs(mk()) * 0.01
    got = adam_update(p, g, m, v, jnp.float32(t), lr=lr)
    want = adam_update_ref(p, g, m, v, jnp.float32(t), lr=lr)
    for a, b in zip(got, want):
        assert a.shape == shape
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_zero_grad_zero_moments_is_identity():
    p = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
    z = jnp.zeros_like(p)
    p2, m2, v2 = adam_update(p, z, z, z, jnp.float32(1.0))
    assert_allclose(np.asarray(p2), np.asarray(p))
    assert_allclose(np.asarray(m2), 0.0)
    assert_allclose(np.asarray(v2), 0.0)


def test_step_moves_against_gradient():
    p = jnp.zeros((4,), jnp.float32)
    g = jnp.asarray([1.0, -1.0, 2.0, -2.0], jnp.float32)
    z = jnp.zeros_like(p)
    p2, _, _ = adam_update(p, g, z, z, jnp.float32(1.0), lr=1e-3)
    delta = np.asarray(p2 - p)
    assert (np.sign(delta) == -np.sign(np.asarray(g))).all()


def test_update_magnitude_bounded_by_lr():
    """|Δp| <= lr_t * (1/(1-beta1)) — Adam's bounded-step property."""
    rng = np.random.default_rng(5)
    p = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(64,)) * 100, jnp.float32)
    z = jnp.zeros_like(p)
    lr = 1e-3
    p2, _, _ = adam_update(p, g, z, z, jnp.float32(1.0), lr=lr)
    # At t=1 with zero moments, update = lr * g/(|g| + eps') ≈ lr exactly.
    assert np.abs(np.asarray(p2 - p)).max() <= lr * 1.01
