//! Control messages (§III-D) and stream references (§V).
//!
//! A deployed training job blocks until a control message for its
//! `deployment_id` arrives on the control topic. The message tells it
//! *where the data stream lives in the distributed log* —
//! `[topic:partition:offset:length]`, the KafkaDataset connector format
//! the paper adopts — plus how to decode it (`input_format`,
//! `input_config`), the validation split and the message count. Because
//! the position is explicit, the same tens-of-bytes control message can
//! be re-sent to other deployments to *reuse* the stream (§V) without
//! re-streaming the data.

use crate::json::{parse, Json};
use anyhow::{anyhow, bail, Result};

/// The well-known control topic.
pub const CONTROL_TOPIC: &str = "kafka-ml-control";

/// A window of the distributed log: `[topic:partition:offset:length]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamRef {
    pub topic: String,
    pub partition: u32,
    pub offset: u64,
    pub length: u64,
}

impl StreamRef {
    pub fn new(topic: &str, partition: u32, offset: u64, length: u64) -> StreamRef {
        StreamRef { topic: topic.to_string(), partition, offset, length }
    }

    /// Render in the paper's `[kafka-ml:0:0:70000]` format.
    pub fn format(&self) -> String {
        format!(
            "[{}:{}:{}:{}]",
            self.topic, self.partition, self.offset, self.length
        )
    }

    pub fn parse(s: &str) -> Result<StreamRef> {
        let inner = s
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| anyhow!("stream ref must be [topic:partition:offset:length]: {s}"))?;
        let parts: Vec<&str> = inner.split(':').collect();
        if parts.len() != 4 {
            bail!("stream ref needs 4 fields: {s}");
        }
        Ok(StreamRef {
            topic: parts[0].to_string(),
            partition: parts[1].parse().map_err(|e| anyhow!("partition: {e}"))?,
            offset: parts[2].parse().map_err(|e| anyhow!("offset: {e}"))?,
            length: parts[3].parse().map_err(|e| anyhow!("length: {e}"))?,
        })
    }

    /// Exclusive end offset.
    pub fn end_offset(&self) -> u64 {
        self.offset + self.length
    }
}

/// A control message (§III-D's field list).
#[derive(Debug, Clone, PartialEq)]
pub struct ControlMessage {
    pub deployment_id: u64,
    pub stream: StreamRef,
    pub input_format: String,
    pub input_config: Json,
    pub validation_rate: f64,
    pub total_msg: u64,
}

impl ControlMessage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("deployment_id", Json::from(self.deployment_id)),
            ("topic", Json::str(&self.stream.topic)),
            ("stream_ref", Json::str(self.stream.format())),
            ("input_format", Json::str(&self.input_format)),
            ("input_config", self.input_config.clone()),
            ("validation_rate", Json::num(self.validation_rate)),
            ("total_msg", Json::from(self.total_msg)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ControlMessage> {
        let stream = StreamRef::parse(j.req_str("stream_ref")?)?;
        Ok(ControlMessage {
            deployment_id: j.req_u64("deployment_id")?,
            stream,
            input_format: j.req_str("input_format")?.to_string(),
            input_config: j.get("input_config").clone(),
            validation_rate: j.get("validation_rate").as_f64().unwrap_or(0.0),
            total_msg: j.get("total_msg").as_u64().unwrap_or(0),
        })
    }

    pub fn encode(&self) -> Vec<u8> {
        crate::json::to_string(&self.to_json()).into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<ControlMessage> {
        let s = std::str::from_utf8(bytes)?;
        let j = parse(s).map_err(|e| anyhow!("control message: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_ref_matches_paper_example() {
        let r = StreamRef::new("kafka-ml", 0, 0, 70000);
        assert_eq!(r.format(), "[kafka-ml:0:0:70000]");
        assert_eq!(StreamRef::parse("[kafka-ml:0:0:70000]").unwrap(), r);
        assert_eq!(r.end_offset(), 70000);
    }

    #[test]
    fn stream_ref_rejects_malformed() {
        for bad in ["kafka-ml:0:0:70000", "[a:b]", "[t:0:0:x]", "[t:0:0:1:2]", ""] {
            assert!(StreamRef::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn control_message_roundtrip() {
        let m = ControlMessage {
            deployment_id: 7,
            stream: StreamRef::new("data", 2, 100, 220),
            input_format: "AVRO".into(),
            input_config: Json::obj(vec![("x", Json::num(1.0))]),
            validation_rate: 0.2,
            total_msg: 220,
        };
        let back = ControlMessage::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn control_message_is_tens_of_bytes() {
        // §V's selling point: a re-send costs tens of bytes, not the
        // whole stream.
        let m = ControlMessage {
            deployment_id: 3,
            stream: StreamRef::new("kafka-ml", 0, 0, 70000),
            input_format: "RAW".into(),
            input_config: Json::Null,
            validation_rate: 0.0,
            total_msg: 70000,
        };
        assert!(m.encode().len() < 250, "{}", m.encode().len());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(ControlMessage::decode(b"not json").is_err());
        assert!(ControlMessage::decode(b"{}").is_err());
    }
}
