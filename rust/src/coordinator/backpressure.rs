//! Ingestion backpressure: IoT gateways (§III-D) can emit faster than
//! training/inference consumes. The [`IngestController`] sits between a
//! data source and a broker producer, bounding in-flight records with a
//! blocking queue and draining it on a pacing thread — so a burst from
//! the source turns into sustainable pressure on the broker instead of
//! unbounded memory growth.

use crate::broker::{ClusterHandle, Producer, ProducerConfig, Record};
use crate::exec::{bounded, CancelToken, RecvError, Sender};
use crate::metrics::Registry;
use anyhow::Result;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the drain lets records accumulate in the producer's batch
/// buffer before forcing a flush when the intake goes quiet. Bounds the
/// broker-visible latency a buffered record can suffer mid-stream.
const DRAIN_LINGER: Duration = Duration::from_millis(5);

pub struct IngestController {
    tx: Option<Sender<(String, Record)>>,
    drain: Option<JoinHandle<u64>>,
    cancel: CancelToken,
    pub metrics: Registry,
}

impl IngestController {
    /// `capacity`: max queued records before `offer` blocks.
    pub fn start(
        cluster: ClusterHandle,
        producer_config: ProducerConfig,
        capacity: usize,
    ) -> IngestController {
        let (tx, rx) = bounded::<(String, Record)>(capacity);
        let cancel = CancelToken::new();
        let metrics = Registry::new();
        let m = metrics.clone();
        let drain = std::thread::Builder::new()
            .name("ingest-drain".to_string())
            .spawn(move || {
                let mut producer = Producer::new(cluster, producer_config);
                let mut sent = 0u64;
                let mut send = |producer: &mut Producer, topic: String, rec: Record| {
                    if producer.send(&topic, rec).is_ok() {
                        sent += 1;
                        m.counter("ingest.sent").inc();
                    } else {
                        m.counter("ingest.errors").inc();
                    }
                };
                // Park for the first record of a window, then drain with
                // an absolute linger deadline (computed ONCE per window,
                // not per spin — `recv_deadline`). On a quiet linger the
                // producer's batch buffer is flushed so no record sits
                // unsent behind an unfilled batch.
                'windows: while let Ok((topic, rec)) = rx.recv() {
                    send(&mut producer, topic, rec);
                    let deadline = Instant::now() + DRAIN_LINGER;
                    loop {
                        match rx.recv_deadline(deadline) {
                            Ok((topic, rec)) => send(&mut producer, topic, rec),
                            Err(RecvError::Timeout) => break,
                            Err(RecvError::Disconnected) => break 'windows,
                        }
                    }
                    producer.flush().ok();
                }
                producer.flush().ok();
                sent
            })
            .expect("spawn ingest drain");
        IngestController { tx: Some(tx), drain: Some(drain), cancel, metrics }
    }

    /// Enqueue a record; **blocks** when the queue is full — that is the
    /// backpressure the source observes.
    pub fn offer(&self, topic: &str, record: Record) -> Result<()> {
        self.tx
            .as_ref()
            .expect("controller closed")
            .send((topic.to_string(), record))
            .map_err(|_| anyhow::anyhow!("ingest drain has shut down"))
    }

    /// Non-blocking variant: returns false when the queue is full (the
    /// caller may drop or retry — at-most-once sources).
    pub fn try_offer(&self, topic: &str, record: Record) -> bool {
        match self
            .tx
            .as_ref()
            .expect("controller closed")
            .try_send((topic.to_string(), record))
        {
            Ok(()) => true,
            Err(_) => {
                self.metrics.counter("ingest.rejected").inc();
                false
            }
        }
    }

    pub fn queued(&self) -> usize {
        self.tx.as_ref().map(|t| t.len()).unwrap_or(0)
    }

    /// Close the intake, drain the queue, and return how many records
    /// were produced.
    pub fn finish(mut self) -> u64 {
        self.tx.take(); // closes the channel
        let sent = self.drain.take().map(|h| h.join().unwrap_or(0)).unwrap_or(0);
        self.cancel.cancel();
        sent
    }
}

impl Drop for IngestController {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.drain.take() {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, Cluster};
    use std::sync::Arc;

    fn cluster() -> ClusterHandle {
        Cluster::new(BrokerConfig::default())
    }

    #[test]
    fn drains_everything_offered() {
        let c = cluster();
        c.create_topic("t", 1);
        let ctl = IngestController::start(c.clone(), ProducerConfig::default(), 64);
        for i in 0..500u32 {
            ctl.offer("t", Record::new(i.to_le_bytes().to_vec())).unwrap();
        }
        let sent = ctl.finish();
        assert_eq!(sent, 500);
        assert_eq!(c.topic("t").unwrap().len(), 500);
    }

    #[test]
    fn try_offer_rejects_when_full() {
        let c = cluster();
        c.create_topic("t", 1);
        // Slow drain: the producer's network profile is zero, but we can
        // saturate a size-1 queue faster than the OS schedules the drain.
        let ctl = IngestController::start(c, ProducerConfig::default(), 1);
        let mut rejected = 0;
        for i in 0..10_000u32 {
            if !ctl.try_offer("t", Record::new(i.to_le_bytes().to_vec())) {
                rejected += 1;
            }
        }
        // With a queue of 1 and 10k offers, some must bounce.
        assert!(rejected > 0);
        assert_eq!(ctl.metrics.counter("ingest.rejected").get(), rejected);
        ctl.finish();
    }

    #[test]
    fn offer_blocks_until_capacity_frees() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let c = cluster();
        c.create_topic("t", 1);
        let ctl = IngestController::start(c.clone(), ProducerConfig::default(), 2);
        let ctl = Arc::new(ctl);
        let done = Arc::new(AtomicBool::new(false));
        let d = done.clone();
        let ctl2 = ctl.clone();
        let h = std::thread::spawn(move || {
            for i in 0..1000u32 {
                ctl2.offer("t", Record::new(i.to_le_bytes().to_vec())).unwrap();
            }
            d.store(true, Ordering::SeqCst);
        });
        h.join().unwrap();
        assert!(done.load(Ordering::SeqCst));
        // finish() joins the drain: everything offered must be produced.
        let ctl = Arc::into_inner(ctl).expect("sole handle");
        assert_eq!(ctl.finish(), 1000);
        assert_eq!(c.topic("t").unwrap().len(), 1000);
    }

    #[test]
    fn idle_linger_flushes_partial_batches() {
        // With batch_size 64 and only 3 records offered, the old drain
        // left them parked in the producer buffer until shutdown; the
        // linger deadline must flush them to the broker while the
        // controller stays alive.
        let c = cluster();
        c.create_topic("t", 1);
        let ctl = IngestController::start(
            c.clone(),
            ProducerConfig { batch_size: 64, ..Default::default() },
            16,
        );
        for i in 0..3u32 {
            ctl.offer("t", Record::new(i.to_le_bytes().to_vec())).unwrap();
        }
        // Wait (bounded) for the linger flush — no fixed sleep.
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.topic("t").unwrap().len() < 3 {
            assert!(Instant::now() < deadline, "linger flush never happened");
            std::thread::yield_now();
        }
        assert_eq!(ctl.finish(), 3);
    }
}
