//! Records: the unit of data in the log. Binary values (the paper's
//! "binary message format: data chunks can be transferred without
//! modifications"), optional keys (partitioning + compaction), headers
//! and timestamps.
//!
//! Payloads are [`Bytes`] — Arc-backed shared buffers — so a record is
//! copied **once**, when the producer encodes it. Every later hop (log
//! storage, segment reads, batched fetches, consumer polls, retry
//! buffers, format decoding) clones the handle, not the bytes. The
//! batched read path hands records around as a [`RecordBatch`]: one
//! lock acquisition, one shared topic name, N shared payloads.

use crate::util::bytes::Bytes;
use crate::util::clock::TimestampMs;
use std::sync::Arc;

/// A record as produced to / stored in a partition log. `Clone` is O(1)
/// in payload size: key/value/header payloads are refcounted views.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub key: Option<Bytes>,
    pub value: Bytes,
    pub timestamp_ms: TimestampMs,
    pub headers: Vec<(String, Bytes)>,
}

impl Record {
    pub fn new(value: impl Into<Bytes>) -> Record {
        Record {
            key: None,
            value: value.into(),
            timestamp_ms: 0,
            headers: Vec::new(),
        }
    }

    pub fn with_key(key: impl Into<Bytes>, value: impl Into<Bytes>) -> Record {
        Record {
            key: Some(key.into()),
            value: value.into(),
            timestamp_ms: 0,
            headers: Vec::new(),
        }
    }

    pub fn header(mut self, k: &str, v: impl Into<Bytes>) -> Record {
        self.headers.push((k.to_string(), v.into()));
        self
    }

    /// Approximate on-log size in bytes (accounting for retention.bytes).
    pub fn size_bytes(&self) -> usize {
        let key = self.key.as_ref().map(|k| k.len()).unwrap_or(0);
        let headers: usize = self
            .headers
            .iter()
            .map(|(k, v)| k.len() + v.len())
            .sum();
        // 16 bytes fixed overhead (offset + timestamp on disk).
        16 + key + self.value.len() + headers
    }

    pub fn get_header(&self, key: &str) -> Option<&[u8]> {
        self.headers
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_slice())
    }

    /// Like [`Record::get_header`], but returns a shared handle on the
    /// header payload instead of a borrowed view.
    pub fn get_header_bytes(&self, key: &str) -> Option<Bytes> {
        self.headers
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.clone())
    }
}

/// A record as returned by a consumer: log position + payload. The
/// topic name is shared (`Arc<str>`), so flattening a batch into
/// per-record handles allocates nothing per record.
#[derive(Debug, Clone, PartialEq)]
pub struct ConsumedRecord {
    pub topic: Arc<str>,
    pub partition: u32,
    pub offset: u64,
    pub record: Record,
}

/// A batch of shared records read from one partition under a single
/// lock acquisition — the unit the fetch path moves between the log and
/// the coordinator. Payloads inside share their allocations with the
/// log's stored records (zero-copy).
#[derive(Debug, Clone)]
pub struct RecordBatch {
    pub topic: Arc<str>,
    pub partition: u32,
    /// `(offset, record)` pairs, offset-ascending.
    pub records: Vec<(u64, Record)>,
}

impl RecordBatch {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Offset of the first record in the batch.
    pub fn base_offset(&self) -> Option<u64> {
        self.records.first().map(|(o, _)| *o)
    }

    /// The position a consumer should advance to after this batch.
    pub fn next_offset(&self) -> Option<u64> {
        self.records.last().map(|(o, _)| o + 1)
    }

    /// Flatten into per-record handles (cheap: shares topic + payloads).
    pub fn into_consumed(self) -> Vec<ConsumedRecord> {
        let topic = self.topic;
        let partition = self.partition;
        self.records
            .into_iter()
            .map(|(offset, record)| ConsumedRecord {
                topic: topic.clone(),
                partition,
                offset,
                record,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_includes_all_parts() {
        let r = Record::with_key(vec![1, 2], vec![3, 4, 5]).header("h", &[9]);
        assert_eq!(r.size_bytes(), 16 + 2 + 3 + 1 + 1);
    }

    #[test]
    fn header_lookup() {
        let r = Record::new(Bytes::new())
            .header("fmt", b"avro")
            .header("x", b"1");
        assert_eq!(r.get_header("fmt"), Some(b"avro".as_slice()));
        assert_eq!(r.get_header("missing"), None);
        let shared = r.get_header_bytes("fmt").unwrap();
        assert!(Bytes::ptr_eq(&shared, &r.headers[0].1));
    }

    #[test]
    fn clone_shares_payloads() {
        let r = Record::with_key(vec![1; 64], vec![2; 1024]).header("h", &[3; 16]);
        let c = r.clone();
        assert!(Bytes::ptr_eq(&r.value, &c.value));
        assert!(Bytes::ptr_eq(r.key.as_ref().unwrap(), c.key.as_ref().unwrap()));
        assert!(Bytes::ptr_eq(&r.headers[0].1, &c.headers[0].1));
    }

    #[test]
    fn batch_flattens_sharing_topic_and_payloads() {
        let topic: Arc<str> = Arc::from("t");
        let rec = Record::new(vec![7u8; 128]);
        let batch = RecordBatch {
            topic: topic.clone(),
            partition: 3,
            records: vec![(10, rec.clone()), (11, rec.clone())],
        };
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.base_offset(), Some(10));
        assert_eq!(batch.next_offset(), Some(12));
        let consumed = batch.into_consumed();
        assert_eq!(consumed[1].offset, 11);
        assert_eq!(consumed[0].partition, 3);
        assert!(Arc::ptr_eq(&consumed[0].topic, &topic));
        assert!(Bytes::ptr_eq(&consumed[0].record.value, &rec.value));
    }

    #[test]
    fn empty_batch_has_no_offsets() {
        let batch = RecordBatch {
            topic: Arc::from("t"),
            partition: 0,
            records: Vec::new(),
        };
        assert!(batch.is_empty());
        assert_eq!(batch.base_offset(), None);
        assert_eq!(batch.next_offset(), None);
    }
}
