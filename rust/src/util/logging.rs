//! Minimal `log` backend (env_logger is not in the offline vendor set).
//!
//! Level via `KML_LOG` (error|warn|info|debug|trace, default warn).
//! Installed by the CLI and examples so pod warnings (bad control
//! messages, failed uploads, dropped inference requests) are visible.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let thread = std::thread::current();
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                thread.name().unwrap_or("?"),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent — later calls are no-ops).
pub fn init() {
    let level = match std::env::var("KML_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(match level {
        Level::Error => LevelFilter::Error,
        Level::Warn => LevelFilter::Warn,
        Level::Info => LevelFilter::Info,
        Level::Debug => LevelFilter::Debug,
        Level::Trace => LevelFilter::Trace,
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init(); // second call must not panic
        log::warn!("logging smoke test");
    }
}
