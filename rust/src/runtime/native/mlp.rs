//! The MLP compute core: dense forward pass, softmax-cross-entropy
//! backward pass, Glorot init — the pure-Rust twin of
//! `python/compile/model.py` (ReLU hidden layers, linear output,
//! mean sparse-categorical-cross-entropy, accuracy).
//!
//! Everything operates on flat row-major `f32` buffers (`rows × dim`),
//! the same layout [`crate::runtime::ModelParams`] stores and the same
//! `&[f32]` views the zero-copy record decoders hand the coordinator —
//! no tensor type, no reshapes, no copies beyond the activations
//! themselves.

use crate::runtime::meta::ArtifactMeta;
use crate::runtime::params::{ModelParams, ParamTensor};
use crate::util::Rng;
use anyhow::{bail, Result};

/// Architecture view the math runs over: `(fan_in, fan_out)` per layer,
/// hidden layers ReLU, output layer linear.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeMlp {
    pub input_dim: usize,
    pub classes: usize,
    pub layers: Vec<(usize, usize)>,
    pub seed: u64,
}

impl NativeMlp {
    /// Derive the layer chain from the meta spec and cross-check it
    /// against the declared parameter list (the artifact contract).
    pub fn from_meta(meta: &ArtifactMeta) -> Result<NativeMlp> {
        if meta.input_dim == 0 || meta.classes == 0 {
            bail!("native MLP needs input_dim > 0 and classes > 0");
        }
        let dims: Vec<usize> = std::iter::once(meta.input_dim)
            .chain(meta.hidden.iter().copied())
            .chain(std::iter::once(meta.classes))
            .collect();
        let layers: Vec<(usize, usize)> = dims.windows(2).map(|w| (w[0], w[1])).collect();
        let mlp = NativeMlp {
            input_dim: meta.input_dim,
            classes: meta.classes,
            layers,
            seed: meta.seed,
        };
        if meta.params.len() != 2 * mlp.layers.len() {
            bail!(
                "meta declares {} param tensors, architecture {:?} needs {}",
                meta.params.len(),
                dims,
                2 * mlp.layers.len()
            );
        }
        for (i, &(fan_in, fan_out)) in mlp.layers.iter().enumerate() {
            let (w, b) = (&meta.params[2 * i], &meta.params[2 * i + 1]);
            if w.shape != [fan_in, fan_out] || b.shape != [fan_out] {
                bail!(
                    "layer {} shape mismatch: meta has {}{:?}/{}{:?}, architecture wants [{fan_in},{fan_out}]/[{fan_out}]",
                    i + 1,
                    w.name,
                    w.shape,
                    b.name,
                    b.shape
                );
            }
        }
        Ok(mlp)
    }

    /// Glorot-uniform weights + zero biases, deterministic per seed —
    /// the native `init` artifact (same scheme as `model.py`'s
    /// `init_params`, seeded via [`crate::util::Rng`]).
    pub fn init(&self) -> ModelParams {
        let mut rng = Rng::new(self.seed);
        let mut tensors = Vec::with_capacity(2 * self.layers.len());
        for (i, &(fan_in, fan_out)) in self.layers.iter().enumerate() {
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let w = (0..fan_in * fan_out)
                .map(|_| rng.range_f64(-limit, limit) as f32)
                .collect();
            tensors.push(ParamTensor {
                name: format!("w{}", i + 1),
                shape: vec![fan_in, fan_out],
                data: w,
            });
            tensors.push(ParamTensor {
                name: format!("b{}", i + 1),
                shape: vec![fan_out],
                data: vec![0.0; fan_out],
            });
        }
        ModelParams { tensors }
    }

    /// Forward pass keeping every post-activation (needed by backward):
    /// returns `[a_0 = x, a_1, …, a_{L-1}, logits]` — `L+1` buffers.
    fn forward_all(&self, params: &ModelParams, x: &[f32], rows: usize) -> Vec<Vec<f32>> {
        let n_layers = self.layers.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(x.to_vec());
        for (li, &(fan_in, fan_out)) in self.layers.iter().enumerate() {
            let w = &params.tensors[2 * li].data;
            let b = &params.tensors[2 * li + 1].data;
            let a = &acts[li];
            let mut z = vec![0f32; rows * fan_out];
            for r in 0..rows {
                let zr = &mut z[r * fan_out..(r + 1) * fan_out];
                zr.copy_from_slice(b);
                let ar = &a[r * fan_in..(r + 1) * fan_in];
                for (k, &av) in ar.iter().enumerate() {
                    if av != 0.0 {
                        let wk = &w[k * fan_out..(k + 1) * fan_out];
                        for (zv, &wv) in zr.iter_mut().zip(wk) {
                            *zv += av * wv;
                        }
                    }
                }
            }
            if li < n_layers - 1 {
                for zv in z.iter_mut() {
                    if *zv < 0.0 {
                        *zv = 0.0;
                    }
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Logits for `rows` samples (`rows × classes`, row-major).
    pub fn logits(&self, params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
        self.forward_all(params, x, rows).pop().unwrap()
    }

    /// Class probabilities (numerically stable row-wise softmax).
    pub fn probs(&self, params: &ModelParams, x: &[f32], rows: usize) -> Vec<f32> {
        let mut logits = self.logits(params, x, rows);
        for row in logits.chunks_mut(self.classes) {
            softmax_row(row);
        }
        logits
    }

    /// Mean NLL + accuracy over one batch of `rows` labeled samples.
    pub fn loss_acc(&self, params: &ModelParams, x: &[f32], y: &[i32], rows: usize) -> (f32, f32) {
        let logits = self.logits(params, x, rows);
        loss_acc_of_logits(&logits, y, rows, self.classes)
    }

    /// Loss, accuracy and the full parameter gradient (softmax-CE
    /// backward pass). Gradients come back flat, in artifact order
    /// `[dw1, db1, dw2, db2, …]`, shapes matching `params`.
    pub fn loss_grad(
        &self,
        params: &ModelParams,
        x: &[f32],
        y: &[i32],
        rows: usize,
    ) -> (f32, f32, Vec<Vec<f32>>) {
        let n_layers = self.layers.len();
        let acts = self.forward_all(params, x, rows);
        let logits = &acts[n_layers];
        let (loss, acc) = loss_acc_of_logits(logits, y, rows, self.classes);

        // dz for the output layer: (softmax(logits) − onehot(y)) / rows.
        let mut dz = logits.clone();
        for (r, row) in dz.chunks_mut(self.classes).enumerate() {
            softmax_row(row);
            row[y[r] as usize] -= 1.0;
            for v in row.iter_mut() {
                *v /= rows as f32;
            }
        }

        let mut grads: Vec<Vec<f32>> =
            params.tensors.iter().map(|t| vec![0f32; t.numel()]).collect();
        for li in (0..n_layers).rev() {
            let (fan_in, fan_out) = self.layers[li];
            let a = &acts[li]; // input to this layer, rows × fan_in
            {
                let dw = &mut grads[2 * li];
                for r in 0..rows {
                    let dzr = &dz[r * fan_out..(r + 1) * fan_out];
                    let ar = &a[r * fan_in..(r + 1) * fan_in];
                    for (k, &av) in ar.iter().enumerate() {
                        if av != 0.0 {
                            let dwk = &mut dw[k * fan_out..(k + 1) * fan_out];
                            for (dwv, &dzv) in dwk.iter_mut().zip(dzr) {
                                *dwv += av * dzv;
                            }
                        }
                    }
                }
            }
            {
                let db = &mut grads[2 * li + 1];
                for r in 0..rows {
                    let dzr = &dz[r * fan_out..(r + 1) * fan_out];
                    for (dbv, &dzv) in db.iter_mut().zip(dzr) {
                        *dbv += dzv;
                    }
                }
            }
            if li > 0 {
                // da_{li-1} = dz · Wᵀ, then gate through the ReLU mask
                // (a_{li-1} > 0 ⟺ z_{li-1} > 0 since a = relu(z)).
                let w = &params.tensors[2 * li].data;
                let mut da = vec![0f32; rows * fan_in];
                for r in 0..rows {
                    let dzr = &dz[r * fan_out..(r + 1) * fan_out];
                    let dar = &mut da[r * fan_in..(r + 1) * fan_in];
                    for (k, dav) in dar.iter_mut().enumerate() {
                        let wk = &w[k * fan_out..(k + 1) * fan_out];
                        let mut s = 0f32;
                        for (&wv, &dzv) in wk.iter().zip(dzr) {
                            s += wv * dzv;
                        }
                        *dav = s;
                    }
                }
                for (dav, &av) in da.iter_mut().zip(&acts[li]) {
                    if av <= 0.0 {
                        *dav = 0.0;
                    }
                }
                dz = da;
            }
        }
        (loss, acc, grads)
    }
}

/// In-place stable softmax over one row.
fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Mean sparse-categorical cross-entropy + accuracy from raw logits.
/// Loss accumulates in f64 (the finite-difference gradient check in
/// `rust/tests/native_engine.rs` leans on that headroom).
fn loss_acc_of_logits(logits: &[f32], y: &[i32], rows: usize, classes: usize) -> (f32, f32) {
    let mut nll_sum = 0f64;
    let mut correct = 0usize;
    for (r, row) in logits.chunks(classes).enumerate() {
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = mx as f64
            + row
                .iter()
                .map(|&v| ((v - mx) as f64).exp())
                .sum::<f64>()
                .ln();
        let label = y[r] as usize;
        nll_sum += lse - row[label] as f64;
        // First-max argmax, like jnp.argmax.
        let mut arg = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = c;
            }
        }
        if arg == label {
            correct += 1;
        }
    }
    ((nll_sum / rows as f64) as f32, correct as f32 / rows as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny() -> (NativeMlp, ModelParams) {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 3, &[4], 2, 4, 0.01, 9);
        let mlp = NativeMlp::from_meta(&meta).unwrap();
        let params = mlp.init();
        (mlp, params)
    }

    #[test]
    fn from_meta_checks_param_contract() {
        let mut meta = ArtifactMeta::synthesize(PathBuf::new(), 3, &[4], 2, 4, 0.01, 9);
        assert!(NativeMlp::from_meta(&meta).is_ok());
        meta.params[0].shape = vec![3, 5]; // contradicts hidden=[4]
        assert!(NativeMlp::from_meta(&meta).is_err());
        meta.params.pop();
        assert!(NativeMlp::from_meta(&meta).is_err());
    }

    #[test]
    fn init_is_deterministic_glorot() {
        let (mlp, p1) = tiny();
        let p2 = mlp.init();
        assert_eq!(p1, p2);
        let limit = (6.0f64 / (3 + 4) as f64).sqrt() as f32;
        assert!(p1.tensors[0].data.iter().all(|v| v.abs() <= limit));
        assert!(p1.tensors[0].data.iter().any(|&v| v != 0.0));
        assert!(p1.tensors[1].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn probs_are_a_distribution_and_match_single_row() {
        let (mlp, params) = tiny();
        let x: Vec<f32> = (0..4 * 3).map(|i| (i as f32 * 0.37).sin()).collect();
        let probs = mlp.probs(&params, &x, 4);
        assert_eq!(probs.len(), 4 * 2);
        for row in probs.chunks(2) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Row-wise compute ⟹ batched == single, bit for bit.
        for r in 0..4 {
            let single = mlp.probs(&params, &x[r * 3..(r + 1) * 3], 1);
            assert_eq!(&probs[r * 2..(r + 1) * 2], &single[..]);
        }
    }

    #[test]
    fn loss_of_uniform_logits_is_ln_classes() {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 2, &[], 4, 2, 0.01, 1);
        let mlp = NativeMlp::from_meta(&meta).unwrap();
        // Zero weights + zero biases → uniform logits → loss = ln(4).
        let mut params = mlp.init();
        for t in &mut params.tensors {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        let (loss, _) = mlp.loss_acc(&params, &[1.0, 2.0, -1.0, 0.5], &[0, 3], 2);
        assert!((loss - (4f32).ln()).abs() < 1e-6, "loss {loss}");
    }

    #[test]
    fn grads_match_shapes_and_bias_grad_sums_dz() {
        let (mlp, params) = tiny();
        let x: Vec<f32> = (0..4 * 3).map(|i| (i as f32 * 0.11).cos()).collect();
        let y = [0i32, 1, 1, 0];
        let (loss, acc, grads) = mlp.loss_grad(&params, &x, &y, 4);
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(grads.len(), params.tensors.len());
        for (g, t) in grads.iter().zip(&params.tensors) {
            assert_eq!(g.len(), t.numel());
        }
        // Output-layer dz rows sum to 0 (softmax − onehot), so the
        // output bias gradient must sum to ~0 as well.
        let db_out: f32 = grads[3].iter().sum();
        assert!(db_out.abs() < 1e-5, "db_out {db_out}");
    }
}
