//! First-fit bin-packing scheduler over the node pool.
//!
//! Kubernetes' scheduler is vastly richer; Kafka-ML only needs requests/
//! capacity accounting so that (a) pods queue as `Pending` when the
//! cluster is full — observable backpressure — and (b) the bench can
//! model a laptop-sized cluster (the paper's testbed is a single
//! MacBook Pro).

use super::resources::NodeSpec;
use std::collections::HashMap;

#[derive(Debug)]
struct NodeState {
    spec: NodeSpec,
    used_cpu: u32,
    used_mem: u32,
}

#[derive(Debug, Default)]
pub struct Scheduler {
    nodes: Vec<NodeState>,
    /// pod name -> node index (for release on pod exit).
    placements: HashMap<String, usize>,
}

impl Scheduler {
    pub fn new(nodes: Vec<NodeSpec>) -> Scheduler {
        Scheduler {
            nodes: nodes
                .into_iter()
                .map(|spec| NodeState { spec, used_cpu: 0, used_mem: 0 })
                .collect(),
            placements: HashMap::new(),
        }
    }

    /// Single generous node — the paper's laptop testbed.
    pub fn single_node() -> Scheduler {
        Scheduler::new(vec![NodeSpec::new("node-0", 16_000, 16_384)])
    }

    /// Try to place a pod; returns the node name on success.
    pub fn schedule(&mut self, pod_name: &str, cpu_milli: u32, memory_mb: u32) -> Option<String> {
        for (i, n) in self.nodes.iter_mut().enumerate() {
            let cpu_ok = n.used_cpu + cpu_milli <= n.spec.cpu_milli;
            let mem_ok = n.used_mem + memory_mb <= n.spec.memory_mb;
            if cpu_ok && mem_ok {
                n.used_cpu += cpu_milli;
                n.used_mem += memory_mb;
                self.placements.insert(pod_name.to_string(), i);
                return Some(n.spec.name.clone());
            }
        }
        None
    }

    /// Release a pod's resources (pod reached a terminal phase).
    pub fn release(&mut self, pod_name: &str, cpu_milli: u32, memory_mb: u32) {
        if let Some(i) = self.placements.remove(pod_name) {
            let n = &mut self.nodes[i];
            n.used_cpu = n.used_cpu.saturating_sub(cpu_milli);
            n.used_mem = n.used_mem.saturating_sub(memory_mb);
        }
    }

    pub fn node_of(&self, pod_name: &str) -> Option<&str> {
        self.placements
            .get(pod_name)
            .map(|&i| self.nodes[i].spec.name.as_str())
    }

    /// (used_cpu, capacity_cpu) across all nodes.
    pub fn cpu_utilization(&self) -> (u32, u32) {
        let used = self.nodes.iter().map(|n| n.used_cpu).sum();
        let cap = self.nodes.iter().map(|n| n.spec.cpu_milli).sum();
        (used, cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_small_nodes() -> Scheduler {
        Scheduler::new(vec![
            NodeSpec::new("n0", 1000, 1024),
            NodeSpec::new("n1", 1000, 1024),
        ])
    }

    #[test]
    fn first_fit_fills_then_overflows() {
        let mut s = two_small_nodes();
        assert_eq!(s.schedule("a", 600, 512).unwrap(), "n0");
        assert_eq!(s.schedule("b", 600, 512).unwrap(), "n1"); // n0 full on cpu
        assert_eq!(s.schedule("c", 600, 512), None); // cluster full
    }

    #[test]
    fn memory_constrains_too() {
        let mut s = two_small_nodes();
        assert!(s.schedule("a", 100, 1024).is_some());
        assert_eq!(s.schedule("b", 100, 1024).unwrap(), "n1");
        assert_eq!(s.schedule("c", 100, 1), None);
    }

    #[test]
    fn release_frees_capacity() {
        let mut s = two_small_nodes();
        s.schedule("a", 1000, 1024).unwrap();
        s.schedule("b", 1000, 1024).unwrap();
        assert!(s.schedule("c", 500, 100).is_none());
        s.release("a", 1000, 1024);
        assert_eq!(s.schedule("c", 500, 100).unwrap(), "n0");
    }

    #[test]
    fn node_of_tracks_placements() {
        let mut s = two_small_nodes();
        s.schedule("a", 100, 100).unwrap();
        assert_eq!(s.node_of("a"), Some("n0"));
        assert_eq!(s.node_of("zzz"), None);
    }

    #[test]
    fn utilization_accounting() {
        let mut s = two_small_nodes();
        s.schedule("a", 300, 100).unwrap();
        s.schedule("b", 700, 100).unwrap();
        assert_eq!(s.cpu_utilization(), (1000, 2000));
    }
}
