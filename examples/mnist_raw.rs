//! RAW-format (image) pipeline: §III-D's other data format — "suitable
//! for single-input data streams that may request a reshape, like
//! images". An 8×8 synthetic image dataset is streamed as RAW **u8**
//! tensors (quantized like camera frames), trained on a model compiled
//! for 64 inputs, and served.
//!
//! Needs the second artifact set:
//! ```sh
//! make artifacts          # builds artifacts/ AND artifacts/mnist/
//! cargo run --release --example mnist_raw
//! ```

use kafka_ml::broker::ClientLocality;
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::json::Json;
use kafka_ml::ml::mnist_like_dataset;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let kml = KafkaMl::start(KafkaMlConfig {
        artifact_dir: "artifacts/mnist".to_string(),
        ..Default::default()
    })?;

    // The image model: 64 inputs (8×8), its own AOT artifact set.
    let model = kml.create_model("mnist-mlp")?;
    let conf = kml.create_configuration("mnist", &[model])?;
    let dep = kml.deploy_training(
        conf,
        &TrainParams { batch_size: 16, epochs: 8, shuffle: true, seed: 9 },
    )?;

    // RAW u8 images: the producer library quantizes [0,1] floats to u8
    // exactly like a camera byte stream; training jobs de-quantize.
    let ds = mnist_like_dataset(320, 8, 7);
    let raw_u8 = Json::obj(vec![
        ("dtype", Json::str("u8")),
        (
            "shape",
            Json::arr(vec![Json::from(8u64), Json::from(8u64)]),
        ),
    ]);
    kml.send_stream(
        dep.id,
        &ds.samples,
        "mnist-frames",
        "RAW",
        &raw_u8,
        0.125,
        ClientLocality::External,
    )?;

    let results = kml.wait_training(&dep, Duration::from_secs(900))?;
    let r = &results[0];
    println!(
        "trained on 8x8 frames: loss {:.4} -> acc {:.3} (val acc {:.3})",
        r.metrics.loss,
        r.metrics.accuracy,
        r.metrics.val_accuracy.unwrap_or(f64::NAN)
    );

    // Serve it and classify fresh frames.
    let inf = kml.deploy_inference(r.id, 2, "frames-in", "frames-out")?;
    let mut client = kml.inference_client(&inf, ClientLocality::External)?;
    let test = mnist_like_dataset(50, 8, 77);
    let mut correct = 0;
    for s in &test.samples {
        let p = client.request(&s.features, Duration::from_secs(10))?;
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    println!("inference on 50 fresh frames: {correct}/50 correct");
    // The quadrant task is easy — a trained model must beat chance hard.
    assert!(correct > 25, "expected >25/50 on the quadrant task");

    kml.stop_inference(inf.id)?;
    kml.shutdown();
    Ok(())
}
