//! The inference replica — Algorithm 2 of the paper (§IV-D) — and the
//! request/response client that feeds it (§III-F).
//!
//! ```text
//! model <- downloadTrainedModelFromBackend(model_url)
//! deserializer <- getDeserializer(input_configuration)
//! while True:
//!   stream <- readStreams(input_topic)
//!   data <- decode(deserializer, stream)
//!   predictions <- predict(model, data)
//!   sendToKafka(predictions, output_topic)
//! ```
//!
//! Replicas join one consumer group per inference deployment, so the
//! broker's group coordinator spreads input partitions across them —
//! load balancing + fault tolerance exactly as §IV-D describes.
//! Request/response correlation rides on a record *header*
//! (`kafka-ml-request-id`) — the record key stays reserved for the
//! formats' label-in-key convention; the replica copies the header onto
//! the prediction it produces.

use crate::broker::{
    Assignor, BrokerHandle, BrokerTransport, ClientLocality, Consumer, Producer, ProducerConfig,
    Record,
};
use crate::exec::CancelToken;
use crate::formats::registry;
use crate::json::Json;
use crate::registry::BackendClient;
use crate::runtime::{BackendSelect, Engine};
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// Header carrying the request correlation id end-to-end.
pub const REQUEST_ID_HEADER: &str = "kafka-ml-request-id";

#[derive(Debug, Clone)]
pub struct InferenceReplicaConfig {
    pub inference_id: u64,
    pub result_id: u64,
    pub artifact_dir: String,
    pub backend_url: String,
    pub input_topic: String,
    pub output_topic: String,
    pub input_format: String,
    pub input_config: Json,
    pub locality: ClientLocality,
    /// Max records pulled per poll (micro-batching across requests).
    pub max_poll: usize,
    /// Execution backend for the model (`--backend` knob).
    pub backend: BackendSelect,
    /// API key for the back-end (`--require-auth` platforms).
    pub api_key: Option<String>,
}

impl InferenceReplicaConfig {
    pub fn group_id(&self) -> String {
        format!("inference-{}", self.inference_id)
    }
}

/// Run one inference replica until cancelled (Algorithm 2). `member_id`
/// distinguishes replicas inside the consumer group. Runs identically
/// in-process and against a remote broker over the wire
/// (`kafka-ml infer --broker`) — the paper's replica-pods topology.
pub fn run_inference_replica(
    broker: &BrokerHandle,
    config: &InferenceReplicaConfig,
    member_id: &str,
    cancel: &CancelToken,
) -> Result<()> {
    // downloadTrainedModelFromBackend
    let backend = BackendClient::new_with_key(&config.backend_url, config.api_key.as_deref());
    let params_host = backend.download_model(config.result_id)?;
    let engine = Engine::load_with(&config.artifact_dir, config.backend)?;
    log::info!("inference replica {member_id} running on the '{}' backend", engine.backend_name());
    let params = engine.inference_params(&params_host)?;
    // getDeserializer(input_configuration)
    let format = registry(&config.input_format, &config.input_config)?;

    broker.create_topic(&config.input_topic, 0)?;
    broker.create_topic(&config.output_topic, 0)?;
    let mut consumer = Consumer::new(broker.clone(), config.locality);
    consumer.subscribe(
        &config.group_id(),
        member_id,
        &[config.input_topic.clone()],
        Assignor::RoundRobin,
    )?;
    let mut producer = Producer::new(
        broker.clone(),
        ProducerConfig {
            batch_size: 1, // predictions leave immediately (latency path)
            locality: config.locality,
            ..Default::default()
        },
    );

    let classes = engine.meta().classes;
    let features = engine.meta().input_dim;
    let mut x_buf: Vec<f32> = Vec::new();
    while !cancel.is_cancelled() {
        // Liveness is handled inside the blocking poll: it heartbeats
        // after every wait round, throttle-heartbeats on the saturated
        // data path, and rejoins with the original subscription when
        // evicted — an extra heartbeat round trip here would just tax
        // the remote latency path.
        //
        // Batched fetch (zero-copy): requests arrive as shared-payload
        // batches; decoding reads `&[u8]` views of the log's buffers.
        // When idle the replica parks across its assigned partitions and
        // is pushed awake by the next request (or a group rebalance);
        // the slice bounds cancellation latency, not wakeup latency.
        let batches = consumer.poll_batches_wait(config.max_poll, Duration::from_millis(25))?;
        if batches.is_empty() {
            continue;
        }
        // Micro-batch all pending requests through one predict call.
        x_buf.clear();
        let mut keys = Vec::with_capacity(batches.iter().map(|b| b.len()).sum());
        for (_, record) in batches.iter().flat_map(|b| &b.records) {
            let sample = format.decode(record)?;
            if sample.features.len() != features {
                log::warn!(
                    "inference request with {} features (model wants {features}); dropping",
                    sample.features.len()
                );
                continue;
            }
            x_buf.extend_from_slice(&sample.features);
            keys.push(record.get_header_bytes(REQUEST_ID_HEADER));
        }
        if keys.is_empty() {
            continue;
        }
        let rows = keys.len();
        let probs = engine.predict(&params, &x_buf, rows)?;
        let labels = engine.classify(&probs);
        for (i, key) in keys.into_iter().enumerate() {
            let row = &probs[i * classes..(i + 1) * classes];
            let payload = Json::obj(vec![
                (
                    "probs",
                    Json::arr(row.iter().map(|&p| Json::num(p as f64)).collect()),
                ),
                ("class", Json::from(labels[i])),
            ]);
            let mut rec = Record::new(crate::json::to_string(&payload).into_bytes());
            if let Some(k) = key {
                // Shares the request-id allocation with the request.
                rec = rec.header(REQUEST_ID_HEADER, k);
            }
            producer.send_to(&config.output_topic, 0, rec)?;
        }
        consumer.commit()?;
        // Platform metric; lands on the broker's registry whichever
        // transport carried it.
        broker.add_metric("kafka_ml.inference.predictions", rows as u64);
    }
    consumer.leave();
    Ok(())
}

/// A prediction as returned to clients.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub probs: Vec<f32>,
    pub class: usize,
}

impl Prediction {
    pub fn decode(bytes: &[u8]) -> Result<Prediction> {
        let j = crate::json::parse(std::str::from_utf8(bytes)?)
            .map_err(|e| anyhow!("prediction payload: {e}"))?;
        Ok(Prediction {
            probs: j
                .get("probs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as f32))
                .collect(),
            class: j.req_u64("class")? as usize,
        })
    }
}

/// Client-side request/response over the input/output topics (§III-F:
/// "send encoded data streams to the input topic, and inference results
/// will be immediately sent to the output topic"). Transport-agnostic:
/// hand it an in-process cluster or a [`crate::broker::RemoteBroker`].
pub struct InferenceClient {
    broker: BrokerHandle,
    input_topic: String,
    output_topic: String,
    format: Box<dyn crate::formats::DataFormat>,
    producer: Producer,
    consumer: Consumer,
    next_request: u64,
    /// Client id namespaces request keys across concurrent clients.
    client_id: u64,
    /// Predictions read while awaiting a different key (out-of-order
    /// arrivals across replicas) — held until their key is awaited.
    pending: std::collections::HashMap<Vec<u8>, Prediction>,
}

impl InferenceClient {
    pub fn new(
        broker: BrokerHandle,
        input_topic: &str,
        output_topic: &str,
        input_format: &str,
        input_config: &Json,
        locality: ClientLocality,
    ) -> Result<InferenceClient> {
        let format = registry(input_format, input_config)?;
        broker.create_topic(input_topic, 0)?;
        broker.create_topic(output_topic, 0)?;
        let producer = Producer::new(
            broker.clone(),
            ProducerConfig { batch_size: 1, locality, ..Default::default() },
        );
        let mut consumer = Consumer::new(broker.clone(), locality);
        consumer.assign(vec![(output_topic.to_string(), 0)]);
        // Start reading at the current end: old predictions are not ours.
        let (_, latest) = broker.offsets(output_topic, 0)?;
        consumer.seek((output_topic.to_string(), 0), latest);
        let client_id = broker.alloc_producer_id()?;
        Ok(InferenceClient {
            broker,
            input_topic: input_topic.to_string(),
            output_topic: output_topic.to_string(),
            format,
            producer,
            consumer,
            next_request: 0,
            client_id,
            pending: std::collections::HashMap::new(),
        })
    }

    fn fresh_key(&mut self) -> Vec<u8> {
        self.next_request += 1;
        format!("req-{}-{}", self.client_id, self.next_request).into_bytes()
    }

    /// Fire one request without waiting (throughput path).
    pub fn send(&mut self, features: &[f32]) -> Result<Vec<u8>> {
        let key = self.fresh_key();
        let rec = self
            .format
            .encode(features, None)?
            .header(REQUEST_ID_HEADER, &key);
        self.producer.send(&self.input_topic, rec)?;
        Ok(key)
    }

    /// Request + block for the correlated prediction (latency path —
    /// what Table II times).
    pub fn request(&mut self, features: &[f32], timeout: Duration) -> Result<Prediction> {
        let key = self.send(features)?;
        self.await_key(&key, timeout)
    }

    /// Wait for the prediction correlated with `key`. Predictions for
    /// *other* outstanding keys seen along the way are buffered, so any
    /// await order works (replicas may answer out of order).
    pub fn await_key(&mut self, key: &[u8], timeout: Duration) -> Result<Prediction> {
        if let Some(p) = self.pending.remove(key) {
            return Ok(p);
        }
        let deadline = Instant::now() + timeout;
        loop {
            // Park until the output topic has records (any prediction
            // wakes us — replicas may answer out of order). Buffer the
            // WHOLE poll batch before answering: the consumer position
            // has already advanced past every returned record, so
            // anything not kept here would be lost.
            let remaining = deadline.saturating_duration_since(Instant::now());
            for rec in self.consumer.poll_wait(64, remaining)? {
                let Some(rec_key) = rec.record.get_header(REQUEST_ID_HEADER) else {
                    continue;
                };
                if let Ok(p) = Prediction::decode(&rec.record.value) {
                    self.pending.insert(rec_key.to_vec(), p);
                }
            }
            if let Some(p) = self.pending.remove(key) {
                return Ok(p);
            }
            if Instant::now() >= deadline {
                return Err(anyhow!(
                    "timed out waiting for prediction on {}",
                    self.output_topic
                ));
            }
        }
    }

    pub fn broker(&self) -> &BrokerHandle {
        &self.broker
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_payload_roundtrip() {
        let j = Json::obj(vec![
            (
                "probs",
                Json::arr(vec![Json::num(0.1), Json::num(0.7), Json::num(0.2)]),
            ),
            ("class", Json::from(1u64)),
        ]);
        let p = Prediction::decode(crate::json::to_string(&j).as_bytes()).unwrap();
        assert_eq!(p.class, 1);
        assert_eq!(p.probs.len(), 3);
        assert!((p.probs[1] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn prediction_rejects_garbage() {
        assert!(Prediction::decode(b"junk").is_err());
        assert!(Prediction::decode(b"{}").is_err());
    }

    #[test]
    fn group_id_is_per_deployment() {
        let cfg = InferenceReplicaConfig {
            inference_id: 12,
            result_id: 1,
            artifact_dir: String::new(),
            backend_url: String::new(),
            input_topic: "in".into(),
            output_topic: "out".into(),
            input_format: "RAW".into(),
            input_config: Json::Null,
            locality: ClientLocality::InCluster,
            max_poll: 16,
            backend: BackendSelect::Auto,
            api_key: None,
        };
        assert_eq!(cfg.group_id(), "inference-12");
    }

    // Full replica tests (with a real Engine) are in
    // rust/tests/pipeline_integration.rs.
}
