//! Quickstart: the smallest complete Kafka-ML pipeline (Fig 1, A–F).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use kafka_ml::broker::ClientLocality;
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // Boot the platform: broker cluster + REST back-end + orchestrator
    // (+ the control-logger pod).
    let kml = KafkaMl::start(KafkaMlConfig::default())?;
    println!("platform up — back-end at {}", kml.backend_url());

    // A/B: define the model (AOT artifacts) and group it in a configuration.
    let model = kml.create_model("quickstart-mlp")?;
    let conf = kml.create_configuration("quickstart", &[model])?;

    // C: deploy for training — a Job now blocks on the control topic.
    let dep = kml.deploy_training(conf, &TrainParams { epochs: 5, ..Default::default() })?;

    // D: stream the data (RAW format) + control message.
    let data = hcopd_dataset(100, 8, 1);
    let raw = Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ]);
    kml.send_stream(
        dep.id,
        &data.samples,
        "quickstart-data",
        "RAW",
        &raw,
        0.1,
        ClientLocality::External,
    )?;

    // E: wait for the trained result, then deploy it for inference.
    let results = kml.wait_training(&dep, Duration::from_secs(300))?;
    let result = &results[0];
    println!(
        "trained: loss={:.4} accuracy={:.3}",
        result.metrics.loss, result.metrics.accuracy
    );
    let inf = kml.deploy_inference(result.id, 1, "qs-in", "qs-out")?;

    // F: stream a value in, get the prediction out.
    let mut client = kml.inference_client(&inf, ClientLocality::External)?;
    let probe = &data.samples[0];
    let pred = client.request(&probe.features, Duration::from_secs(10))?;
    println!(
        "prediction: class {} (probs {:?}) — true label {}",
        pred.class,
        pred.probs,
        probe.label.unwrap()
    );

    kml.stop_inference(inf.id)?;
    kml.shutdown();
    Ok(())
}
