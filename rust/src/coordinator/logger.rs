//! The control logger (§IV-E): consumes every control message from the
//! control topic and forwards it to the back-end, which uses the log to
//! (1) re-send streams to other deployments without re-streaming (§V)
//! and (2) auto-configure inference input formats.

use super::control::{ControlMessage, CONTROL_TOPIC};
use crate::broker::{ClientLocality, ClusterHandle, Consumer};
use crate::exec::CancelToken;
use crate::registry::{BackendClient, ControlLogEntry};
use anyhow::Result;
use std::time::Duration;

pub fn entry_from_message(msg: &ControlMessage, now_ms: u64) -> ControlLogEntry {
    ControlLogEntry {
        deployment_id: msg.deployment_id,
        topic: msg.stream.topic.clone(),
        partition: msg.stream.partition,
        offset: msg.stream.offset,
        length: msg.stream.length,
        input_format: msg.input_format.clone(),
        input_config: msg.input_config.clone(),
        validation_rate: msg.validation_rate,
        total_msg: msg.total_msg,
        logged_ms: now_ms,
    }
}

/// Run the control logger until cancelled. Designed to run as an
/// orchestrator-managed pod (one replica is enough; offsets are
/// committed under the `control-logger` group so a replacement resumes).
pub fn run_control_logger(
    cluster: &ClusterHandle,
    backend_url: &str,
    api_key: Option<&str>,
    locality: ClientLocality,
    cancel: &CancelToken,
) -> Result<()> {
    let backend = BackendClient::new_with_key(backend_url, api_key);
    cluster.topic_or_create(CONTROL_TOPIC);
    let mut consumer = Consumer::new(cluster.clone(), locality);
    consumer.subscribe(
        "control-logger",
        "logger-0",
        &[CONTROL_TOPIC.to_string()],
        crate::broker::Assignor::Range,
    )?;
    while !cancel.is_cancelled() {
        // Blocking long-poll: the logger parks on the control partition
        // and is woken the instant a control message is produced. The
        // short slice only bounds how long cancellation can go unseen.
        let recs = consumer.poll_wait(64, Duration::from_millis(25))?;
        if recs.is_empty() {
            continue;
        }
        for rec in recs {
            match ControlMessage::decode(&rec.record.value) {
                Ok(msg) => {
                    let entry = entry_from_message(&msg, cluster.clock().now_ms());
                    if let Err(e) = backend.log_control(&entry) {
                        log::warn!("control logger: back-end rejected entry: {e}");
                    }
                }
                Err(e) => log::warn!("control logger: bad message at {}: {e}", rec.offset),
            }
        }
        consumer.commit()?;
    }
    consumer.leave();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::control::StreamRef;
    use crate::json::Json;

    #[test]
    fn entry_copies_all_fields() {
        let msg = ControlMessage {
            deployment_id: 9,
            stream: StreamRef::new("data", 1, 5, 100),
            input_format: "AVRO".into(),
            input_config: Json::obj(vec![("k", Json::num(2.0))]),
            validation_rate: 0.25,
            total_msg: 100,
        };
        let e = entry_from_message(&msg, 1234);
        assert_eq!(e.deployment_id, 9);
        assert_eq!(e.topic, "data");
        assert_eq!(e.partition, 1);
        assert_eq!(e.offset, 5);
        assert_eq!(e.length, 100);
        assert_eq!(e.input_format, "AVRO");
        assert_eq!(e.validation_rate, 0.25);
        assert_eq!(e.logged_ms, 1234);
    }

    // End-to-end logger behaviour is covered by
    // rust/tests/pipeline_integration.rs (needs the REST back-end).
}
