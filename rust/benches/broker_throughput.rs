//! Broker ablations (§II's dispatch-rate claims): message-set batching,
//! partition-parallel consumption, fetch sizing and the zero-copy
//! consume path.
//!
//! * batching — §II credits Kafka's rate to "message set abstractions:
//!   messages are grouped together amortizing the overhead of the
//!   network round trip". Sweep producer batch size with a calibrated
//!   in-cluster link and watch records/s.
//! * partitions — multi-consumer parallel fetch across 1/2/4 partitions.
//! * fetch size — single-consumer poll batching.
//! * payload size — consume throughput at 64 B / 1 KiB / 16 KiB
//!   payloads. This is the zero-copy dividend: since records travel as
//!   shared `Bytes`, consume cost is near-independent of payload size.
//! * consumer wakeup latency — produce→deliver latency to a **parked**
//!   consumer on the event-driven `poll_wait` path vs the 1 ms
//!   sleep-poll loop it replaced, plus the fetch-request rate an *idle*
//!   consumer burns under each discipline.
//! * pipelined produce — the producer's in-flight window over loopback
//!   TCP (1 vs 5 vs 16 batches in flight on one multiplexed
//!   connection): records/s and p99 submit-to-ack per batch.
//! * cluster failover — produce latency through a 3-broker cluster at
//!   `acks=replicated`, steady state vs with the partition leader
//!   SIGKILLed mid-stream: the p99/max gap is the failover stall
//!   (heartbeat detection + promotion + client re-route).
//!
//! Results are also written machine-readably to
//! `BENCH_broker_throughput.json` (repo root) via `benchkit::Report` so
//! successive PRs can diff the perf trajectory.

use kafka_ml::benchkit::{Bench, Report, Table};
use kafka_ml::broker::{
    BrokerConfig, BrokerHandle, BrokerServer, BrokerTransport, ClientLocality, Cluster,
    ClusterHandle, Consumer, LogConfig, NetProfile, ProduceHandle, ProduceOutcome, Producer,
    ProducerConfig, Record, RemoteBroker, StorageMode,
};
use kafka_ml::util::Bytes;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

const REPORT_PATH: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/../BENCH_broker_throughput.json"
);

fn main() -> anyhow::Result<()> {
    let mut report = Report::new("broker_throughput");
    let records = 20_000usize;
    let payload = Bytes::from_vec(vec![7u8; 64]);

    // ---- producer batching sweep -----------------------------------------
    let mut t = Table::new(
        "Producer message-set batching (20k x 64B records, in-cluster 250µs/leg)",
        &["batch size", "wall (s)", "records/s", "network round-trips"],
    );
    for batch in [1usize, 8, 64, 256] {
        let c = Cluster::new(BrokerConfig {
            net: NetProfile::calibrated(),
            ..Default::default()
        });
        c.create_topic("bt", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig {
                batch_size: batch,
                locality: ClientLocality::InCluster,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        for _ in 0..records {
            p.send_to("bt", 0, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let wall = t0.elapsed();
        let rps = records as f64 / wall.as_secs_f64();
        t.row(&[
            batch.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{rps:.0}"),
            c.metrics.counter("broker.produce.batches").get().to_string(),
        ]);
        report.entry(
            "producer_batching",
            &[("batch_size", batch as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", wall.as_secs_f64())],
        );
    }
    t.print();

    // ---- consumer parallelism across partitions ------------------------------
    let mut t = Table::new(
        "Partition-parallel consumption (80k x 64B records, no simulated net)",
        &["partitions/consumers", "wall (s)", "records/s"],
    );
    let total = 80_000usize;
    for parts in [1u32, 2, 4] {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("pt", parts);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 512, ..Default::default() },
        );
        for i in 0..total {
            p.send_to("pt", i as u32 % parts, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..parts)
            .map(|pi| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let mut cons = Consumer::new(c, ClientLocality::InCluster);
                    cons.assign(vec![("pt".to_string(), pi)]);
                    let mut got = 0usize;
                    loop {
                        let n = cons.poll(2048).unwrap().len();
                        if n == 0 {
                            break;
                        }
                        got += n;
                    }
                    got
                })
            })
            .collect();
        let got: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(got, total);
        let wall = t0.elapsed();
        let rps = total as f64 / wall.as_secs_f64();
        t.row(&[
            parts.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{rps:.0}"),
        ]);
        report.entry(
            "partition_parallelism",
            &[("partitions", parts as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", wall.as_secs_f64())],
        );
    }
    t.print();

    // ---- fetch size sweep (batched zero-copy reads) ---------------------------
    let mut t = Table::new(
        "Fetch size sweep (80k records, single consumer)",
        &["max poll", "wall (s)", "records/s"],
    );
    let c = Cluster::new(BrokerConfig::default());
    c.create_topic("ft", 1);
    let mut p = Producer::new(
        c.clone(),
        ProducerConfig { batch_size: 512, ..Default::default() },
    );
    for _ in 0..total {
        p.send_to("ft", 0, Record::new(payload.clone()))?;
    }
    p.flush()?;
    let bench = Bench::new(1, 3);
    for max_poll in [16usize, 256, 4096] {
        let stats = bench.run(|| {
            let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
            cons.assign(vec![("ft".to_string(), 0)]);
            let mut got = 0usize;
            while got < total {
                got += cons.poll(max_poll).unwrap().len();
            }
        });
        let rps = total as f64 / stats.mean_secs();
        t.row(&[
            max_poll.to_string(),
            format!("{:.3}", stats.mean_secs()),
            format!("{rps:.0}"),
        ]);
        report.entry(
            "fetch_size",
            &[("max_poll", max_poll as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", stats.mean_secs())],
        );
    }
    t.print();

    // ---- payload size sweep (the zero-copy dividend) --------------------------
    // Shared-`Bytes` payloads mean the consume path never copies record
    // bodies; throughput in records/s should stay near-flat from 64 B
    // to 16 KiB, and MiB/s should scale with payload size.
    let mut t = Table::new(
        "Payload size sweep (20k records, single consumer, max_poll 1024)",
        &["payload", "wall (s)", "records/s", "MiB/s"],
    );
    for size in [64usize, 1024, 16 * 1024] {
        let n = 20_000usize;
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("ps", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 512, ..Default::default() },
        );
        let body = Bytes::from_vec(vec![42u8; size]);
        for _ in 0..n {
            p.send_to("ps", 0, Record::new(body.clone()))?;
        }
        p.flush()?;
        let stats = bench.run(|| {
            let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
            cons.assign(vec![("ps".to_string(), 0)]);
            let mut got = 0usize;
            while got < n {
                got += cons.poll(1024).unwrap().len();
            }
        });
        let rps = n as f64 / stats.mean_secs();
        let mibs = rps * size as f64 / (1024.0 * 1024.0);
        t.row(&[
            kafka_ml::util::human_bytes(size as u64),
            format!("{:.3}", stats.mean_secs()),
            format!("{rps:.0}"),
            format!("{mibs:.1}"),
        ]);
        report.entry(
            "payload_size",
            &[("payload_bytes", size as f64), ("max_poll", 1024.0)],
            &[
                ("records_per_s", rps),
                ("mib_per_s", mibs),
                ("wall_s", stats.mean_secs()),
            ],
        );
    }
    t.print();

    // ---- parked-consumer wakeup latency ---------------------------------------
    // What the notify subsystem buys: a parked consumer reacts to a
    // produce in condvar time, while the old loop paid up to a full
    // sleep quantum per delivery — and kept issuing fetch requests the
    // whole time it was idle.
    let mut t = Table::new(
        "Parked-consumer wakeup (200 one-record deliveries + 400ms idle window)",
        &["consume loop", "mean (µs)", "p50 (µs)", "p99 (µs)", "idle fetches/s"],
    );
    for event_driven in [true, false] {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("wl", 1);
        let lats = wakeup_latencies(&c, "wl", 200, event_driven);
        let idle_rate = idle_fetch_rate(event_driven);
        let us = |d: Duration| d.as_secs_f64() * 1e6;
        let mean = us(lats.iter().sum::<Duration>() / lats.len() as u32);
        let p50 = us(lats[lats.len() / 2]);
        let p99 = us(lats[lats.len() * 99 / 100]);
        t.row(&[
            if event_driven { "event (poll_wait)" } else { "sleep-poll 1ms" }.to_string(),
            format!("{mean:.1}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{idle_rate:.1}"),
        ]);
        report.entry(
            "consumer_wakeup_latency",
            &[("event_driven", if event_driven { 1.0 } else { 0.0 })],
            &[
                ("mean_us", mean),
                ("p50_us", p50),
                ("p99_us", p99),
                ("idle_fetches_per_s", idle_rate),
            ],
        );
    }
    t.print();

    // ---- tiered storage: sealed (cold/warm) vs in-memory fetch ---------------
    // The disk-tier dividend check: a cold fetch pays one file read per
    // sealed segment, a warm fetch decodes from the resident LRU
    // buffers, and both must stay within sight of the pure in-memory
    // path because record payloads are never copied — only sliced.
    let mut t = Table::new(
        "Tiered segment storage (20k x 1KiB, 256KiB segments): fetch source",
        &["source", "wall (s)", "records/s", "MiB/s"],
    );
    let n = 20_000usize;
    let body = Bytes::from_vec(vec![7u8; 1024]);
    let data_dir = std::env::temp_dir().join(format!("kafka-ml-tiered-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&data_dir);
    let tiered = BrokerConfig {
        log: LogConfig {
            segment_bytes: 256 * 1024,
            retention_ms: None,
            storage: StorageMode::Tiered {
                data_dir: data_dir.clone(),
            },
            ..LogConfig::default()
        },
        ..Default::default()
    };
    let in_memory = BrokerConfig {
        log: LogConfig {
            segment_bytes: 256 * 1024,
            retention_ms: None,
            ..LogConfig::default()
        },
        ..Default::default()
    };

    let fill = |c: &ClusterHandle| -> anyhow::Result<()> {
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig {
                batch_size: 512,
                ..Default::default()
            },
        );
        for _ in 0..n {
            p.send_to("ts", 0, Record::new(body.clone()))?;
        }
        p.flush()
    };
    let consume_once = |c: &ClusterHandle| -> Duration {
        let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
        cons.assign(vec![("ts".to_string(), 0)]);
        let t0 = Instant::now();
        let mut got = 0usize;
        while got < n {
            got += cons.poll(2048).unwrap().len();
        }
        t0.elapsed()
    };

    // In-memory baseline.
    let c = Cluster::new(in_memory);
    c.create_topic("ts", 1);
    fill(&c)?;
    let mem_wall = consume_once(&c);
    drop(c);
    // Tiered: produce, seal everything, restart, then read cold + warm.
    {
        let c = Cluster::new(tiered.clone());
        c.create_topic("ts", 1);
        fill(&c)?;
        c.flush_storage()?;
    }
    let c = Cluster::new(tiered.clone());
    let cold_wall = consume_once(&c); // maps every sealed file
    let warm_wall = consume_once(&c); // served from resident buffers
    drop(c);
    // Cold time-to-first-record: another fresh restart, one poll(1) —
    // the latency a lagging consumer pays before its first sealed byte.
    // With mmap residency this is one mmap(2) + page fault, not a full
    // segment read into a fresh allocation.
    let c = Cluster::new(tiered);
    let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
    cons.assign(vec![("ts".to_string(), 0)]);
    let t0 = Instant::now();
    let first = cons.poll(1)?;
    let first_record_us = t0.elapsed().as_secs_f64() * 1e6;
    assert_eq!(first.len(), 1);
    drop(cons);
    drop(c);
    let _ = std::fs::remove_dir_all(&data_dir);

    for (source, mode, wall) in [
        ("in-memory", 0.0, mem_wall),
        ("sealed cold (post-restart)", 1.0, cold_wall),
        ("sealed warm (resident)", 2.0, warm_wall),
    ] {
        let rps = n as f64 / wall.as_secs_f64();
        let mibs = rps * 1024.0 / (1024.0 * 1024.0);
        t.row(&[
            source.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{rps:.0}"),
            format!("{mibs:.1}"),
        ]);
        report.entry(
            "tiered_fetch",
            // mode: 0 = in-memory, 1 = sealed cold, 2 = sealed warm,
            // 3 = cold time-to-first-record (see below)
            &[("mode", mode), ("payload_bytes", 1024.0)],
            &[("records_per_s", rps), ("wall_s", wall.as_secs_f64())],
        );
    }
    t.print();
    println!("  cold time-to-first-record: {first_record_us:.1} µs");
    report.entry(
        "tiered_fetch",
        &[("mode", 3.0), ("payload_bytes", 1024.0)],
        &[("first_record_us", first_record_us)],
    );

    // ---- remote vs in-process transport ---------------------------------------
    // The cost of the real wire: one single-record produce + one fetch
    // through the same BrokerTransport API, in-process (direct calls)
    // vs over a loopback TCP socket (frame encode + CRC + syscalls).
    // The epoll-reactor server serves this path; the c10k case below
    // measures its scaling under connection load.
    let mut t = Table::new(
        "Transport round trip (1k x [produce 64B + fetch], loopback TCP vs in-process)",
        &["transport", "p50 (µs)", "p99 (µs)", "round trips/s"],
    );
    let rt_iters = 1000usize;
    for remote in [false, true] {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("rt", 1);
        let mut server = None;
        let handle: BrokerHandle = if remote {
            let s = BrokerServer::start("127.0.0.1:0", c.clone())?;
            let h: BrokerHandle = RemoteBroker::connect(&s.addr().to_string())?;
            server = Some(s);
            h
        } else {
            c.clone()
        };
        let body = Bytes::from_vec(vec![5u8; 64]);
        // Warmup (connection pool, allocator, branch predictors).
        for i in 0..50 {
            handle.produce("rt", 0, &[Record::new(body.clone())], ClientLocality::Remote, None)?;
            handle.fetch_batch("rt", 0, i as u64, 1, ClientLocality::Remote)?;
        }
        let mut lats = Vec::with_capacity(rt_iters);
        let t0 = Instant::now();
        for i in 0..rt_iters {
            let it0 = Instant::now();
            handle.produce("rt", 0, &[Record::new(body.clone())], ClientLocality::Remote, None)?;
            let got =
                handle.fetch_batch("rt", 0, (50 + i) as u64, 1, ClientLocality::Remote)?;
            assert_eq!(got.len(), 1);
            lats.push(it0.elapsed());
        }
        let wall = t0.elapsed();
        lats.sort();
        let us = |d: Duration| d.as_secs_f64() * 1e6;
        let p50 = us(lats[lats.len() / 2]);
        let p99 = us(lats[lats.len() * 99 / 100]);
        let ops = rt_iters as f64 / wall.as_secs_f64();
        t.row(&[
            if remote { "remote (loopback TCP)" } else { "in-process" }.to_string(),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{ops:.0}"),
        ]);
        report.entry(
            "remote_vs_inprocess",
            &[("remote", if remote { 1.0 } else { 0.0 }), ("payload_bytes", 64.0)],
            &[("p50_us", p50), ("p99_us", p99), ("round_trips_per_s", ops)],
        );
        if let Some(s) = server {
            s.shutdown();
        }
    }
    t.print();

    // ---- native training-step latency -----------------------------------------
    // The pure-Rust backend is the engine every artifact-less training
    // Job runs on, so its per-step latency is a platform number worth
    // tracking: one dense forward + softmax-CE backward + Adam update.
    // Two shapes: the default spec (8 → 16 → 4, batch 10), where the
    // scratch arena's zero-allocation steady state is the lever, and a
    // wider one (64 → 128 → 10, batch 32) where the cache-blocked
    // kernels themselves carry the win.
    let mut t = Table::new(
        "Native backend train_step (2000 steps)",
        &["config", "steps/s", "µs/step", "final loss"],
    );
    {
        use kafka_ml::runtime::{BackendSelect, Engine};
        let engine = Engine::load_with("artifacts", BackendSelect::Native)?;
        let meta = engine.meta();
        let ds = kafka_ml::ml::separable_dataset(meta.batch, meta.input_dim, meta.classes, 12);
        let mut x = Vec::with_capacity(meta.batch * meta.input_dim);
        let mut y = Vec::with_capacity(meta.batch);
        for s in &ds.samples {
            x.extend_from_slice(&s.features);
            y.push(s.label.unwrap());
        }
        let mut state = engine.train_state(&engine.init_params()?)?;
        for _ in 0..100 {
            engine.train_step(&mut state, &x, &y)?; // warmup (page-in, branch warm)
        }
        let steps = 2000usize;
        let t0 = Instant::now();
        let mut loss = 0f32;
        for _ in 0..steps {
            loss = engine.train_step(&mut state, &x, &y)?.0;
        }
        let wall = t0.elapsed();
        let sps = steps as f64 / wall.as_secs_f64();
        let us = wall.as_secs_f64() * 1e6 / steps as f64;
        t.row(&[
            format!("8→16→4 b10 ({})", engine.backend_name()),
            format!("{sps:.0}"),
            format!("{us:.2}"),
            format!("{loss:.5}"),
        ]);
        report.entry(
            "native_train_step",
            &[
                ("batch", meta.batch as f64),
                ("weights", meta.total_weights() as f64),
            ],
            &[("steps_per_s", sps), ("us_per_step", us)],
        );
    }
    {
        use kafka_ml::runtime::native::NativeBackend;
        use kafka_ml::runtime::{ArtifactMeta, Backend, TrainState};
        let meta =
            ArtifactMeta::synthesize(std::path::PathBuf::new(), 64, &[128], 10, 32, 0.01, 5);
        let backend = NativeBackend::new(&meta)?;
        let ds = kafka_ml::ml::separable_dataset(meta.batch, meta.input_dim, meta.classes, 13);
        let mut x = Vec::with_capacity(meta.batch * meta.input_dim);
        let mut y = Vec::with_capacity(meta.batch);
        for s in &ds.samples {
            x.extend_from_slice(&s.features);
            y.push(s.label.unwrap());
        }
        let mut state = TrainState::new(backend.init_params()?);
        for _ in 0..100 {
            state.t += 1;
            backend.train_step(&mut state, &x, &y)?;
        }
        let steps = 2000usize;
        let t0 = Instant::now();
        let mut loss = 0f32;
        for _ in 0..steps {
            state.t += 1;
            loss = backend.train_step(&mut state, &x, &y)?.0;
        }
        let wall = t0.elapsed();
        let sps = steps as f64 / wall.as_secs_f64();
        let us = wall.as_secs_f64() * 1e6 / steps as f64;
        t.row(&[
            "64→128→10 b32 (native)".to_string(),
            format!("{sps:.0}"),
            format!("{us:.2}"),
            format!("{loss:.5}"),
        ]);
        report.entry(
            "native_train_step",
            &[
                ("batch", meta.batch as f64),
                ("weights", meta.total_weights() as f64),
            ],
            &[("steps_per_s", sps), ("us_per_step", us)],
        );
    }
    t.print();

    // ---- C10K: thousands of idle parked long-polls ----------------------------
    // The reactor rewrite's whole point. N idle consumers sit parked in
    // server-side long-polls on a partition that never receives data,
    // while one probe consumer long-polls a live partition and measures
    // produce→wake latency. Two servers over identical raw-socket
    // traffic: a thread-per-connection accept loop (the pre-reactor
    // design, reconstructed in ~40 lines below) vs the real epoll
    // `BrokerServer`. What the reactor must show: per-idle-connection
    // memory down ≥10× (connection state, not a thread stack) and a flat
    // thread count, at no produce→wake latency cost.
    {
        use kafka_ml::broker::notify::WaitSet;
        use kafka_ml::broker::wire::codec::{self as wire, OpCode};
        use std::io::Write;
        use std::net::{SocketAddr, TcpListener, TcpStream};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        let conns: usize = std::env::var("KAFKA_ML_C10K_CONNS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(500);
        let probe_rounds = 50usize;
        let mut t = Table::new(
            &format!("C10K long-poll: {conns} idle parked consumers + active probe"),
            &["server", "p50 wake (µs)", "p99 wake (µs)", "threads +", "RSS/conn (KiB)"],
        );

        // One FetchWait request frame: no group, a single
        // (topic, partition 0, position) assignment.
        let fetch_wait = |corr: u64, topic: &str, pos: u64, timeout_ms: u64| -> Vec<u8> {
            let mut p = Vec::new();
            wire::put_u64(&mut p, timeout_ms);
            wire::put_opt::<()>(&mut p, None, |_, _| {});
            wire::put_u32(&mut p, 1);
            wire::put_str(&mut p, topic);
            wire::put_u32(&mut p, 0);
            wire::put_u64(&mut p, pos);
            wire::encode_request(corr, OpCode::FetchWait, &p)
        };

        // The legacy arm: accept loop + one handler thread per
        // connection, each parking in the broker's blocking long-poll —
        // the design `BrokerServer` used before the reactor.
        let start_legacy = |cluster: ClusterHandle,
                           stop: Arc<AtomicBool>,
                           shutdown: Arc<WaitSet>|
         -> anyhow::Result<(SocketAddr, std::thread::JoinHandle<()>)> {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            let accept = std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    let Ok(mut s) = stream else { continue };
                    let cluster = cluster.clone();
                    let stop = stop.clone();
                    let shutdown = shutdown.clone();
                    std::thread::spawn(move || {
                        while let Ok(body) = wire::read_frame(&mut s) {
                            let mut r = wire::Reader::new(body);
                            let (Ok(corr), Ok(_op)) = (r.u64(), r.u8()) else { return };
                            let Ok(timeout_ms) = r.u64() else { return };
                            let Ok(group) = r.opt(|r| Ok((r.str()?, r.u64()?))) else { return };
                            let Ok(n) = r.u32() else { return };
                            let mut asn = Vec::with_capacity(n as usize);
                            for _ in 0..n {
                                let (Ok(t), Ok(p), Ok(pos)) = (r.str(), r.u32(), r.u64()) else {
                                    return;
                                };
                                asn.push(((t, p), pos));
                            }
                            let deadline =
                                Instant::now() + Duration::from_millis(timeout_ms.min(600_000));
                            let woken = cluster.wait_for_data_cancellable(
                                &asn,
                                group.as_ref().map(|(g, gen)| (g.as_str(), *gen)),
                                deadline,
                                Some(&shutdown),
                                || stop.load(Ordering::SeqCst),
                            );
                            let mut payload = Vec::new();
                            wire::put_bool(&mut payload, woken);
                            let resp = wire::encode_response(corr, Ok(&payload));
                            if s.write_all(&resp).is_err() {
                                return;
                            }
                        }
                    });
                }
            });
            Ok((addr, accept))
        };

        for reactor_arm in [false, true] {
            let cluster = Cluster::new(BrokerConfig::default());
            cluster.create_topic("idle", 1);
            cluster.create_topic("probe", 1);
            let stop = Arc::new(AtomicBool::new(false));
            let legacy_shutdown = Arc::new(WaitSet::new());
            let mut real_server: Option<BrokerServer> = None;
            let mut legacy_accept: Option<std::thread::JoinHandle<()>> = None;
            let addr: SocketAddr = if reactor_arm {
                let s = BrokerServer::start("127.0.0.1:0", cluster.clone())?;
                let a = s.addr();
                real_server = Some(s);
                a
            } else {
                let (a, h) =
                    start_legacy(cluster.clone(), stop.clone(), legacy_shutdown.clone())?;
                legacy_accept = Some(h);
                a
            };

            let threads_before = kafka_ml::benchkit::proc_threads().unwrap_or(0);
            let rss_before = kafka_ml::benchkit::proc_rss_kb().unwrap_or(0);

            // Park the idle fleet and wait until every one is registered.
            let idle_set = cluster.topic("idle").unwrap().wait_set(0).unwrap().clone();
            let mut fleet: Vec<TcpStream> = Vec::with_capacity(conns);
            for i in 0..conns {
                let mut s = TcpStream::connect(addr)?;
                s.set_read_timeout(Some(Duration::from_secs(30)))?;
                s.write_all(&fetch_wait(i as u64, "idle", 0, 300_000))?;
                fleet.push(s);
            }
            let deadline = Instant::now() + Duration::from_secs(30);
            while idle_set.len() < conns && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            assert_eq!(idle_set.len(), conns, "idle fleet failed to park");

            let threads_delta =
                kafka_ml::benchkit::proc_threads().unwrap_or(0).saturating_sub(threads_before);
            let rss_per_conn_kb = kafka_ml::benchkit::proc_rss_kb()
                .unwrap_or(0)
                .saturating_sub(rss_before) as f64
                / conns as f64;

            // Probe: produce→wake latency through a parked long-poll,
            // with the whole idle fleet parked alongside.
            let mut probe = TcpStream::connect(addr)?;
            probe.set_read_timeout(Some(Duration::from_secs(10)))?;
            let mut lats: Vec<Duration> = Vec::with_capacity(probe_rounds);
            for round in 0..probe_rounds {
                probe.write_all(&fetch_wait(round as u64, "probe", round as u64, 10_000))?;
                // Let the wait cross the wire and park server-side.
                std::thread::sleep(Duration::from_millis(2));
                let t0 = Instant::now();
                cluster.produce(
                    "probe",
                    0,
                    &[Record::new(vec![round as u8])],
                    ClientLocality::InCluster,
                    None,
                )?;
                let body = wire::read_frame(&mut probe)?;
                let lat = t0.elapsed();
                let mut r = wire::Reader::new(body);
                assert_eq!(r.u64()?, round as u64);
                assert_eq!(r.u8()?, wire::STATUS_OK);
                assert!(r.bool()?);
                lats.push(lat);
            }
            lats.sort();
            let us = |d: Duration| d.as_secs_f64() * 1e6;
            let p50 = us(lats[lats.len() / 2]);
            let p99 = us(lats[lats.len() * 99 / 100]);

            t.row(&[
                if reactor_arm { "epoll reactor" } else { "thread-per-connection" }.to_string(),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                threads_delta.to_string(),
                format!("{rss_per_conn_kb:.1}"),
            ]);
            report.entry(
                "c10k_longpoll",
                &[
                    ("reactor", if reactor_arm { 1.0 } else { 0.0 }),
                    ("connections", conns as f64),
                ],
                &[
                    ("p50_wake_us", p50),
                    ("p99_wake_us", p99),
                    ("threads_delta", threads_delta as f64),
                    ("rss_per_conn_kb", rss_per_conn_kb),
                ],
            );

            // Teardown, and let the process settle so the next arm's
            // before-measurements are clean.
            drop(probe);
            drop(fleet);
            if let Some(s) = real_server.take() {
                s.shutdown();
            }
            if let Some(h) = legacy_accept.take() {
                stop.store(true, Ordering::SeqCst);
                legacy_shutdown.notify_all(); // unparks every handler thread
                let _ = TcpStream::connect(addr); // unblocks the accept loop
                h.join().ok();
            }
            let settle = Instant::now() + Duration::from_secs(30);
            while kafka_ml::benchkit::proc_threads().unwrap_or(0) > threads_before
                && Instant::now() < settle
            {
                std::thread::sleep(Duration::from_millis(20));
            }
        }
        t.print();
    }

    // ---- pipelined produce window over the wire -------------------------------
    // What the in-flight window buys on the real socket path: window 1
    // replays the old submit-and-wait discipline (one round trip per
    // batch, latency-bound), 5 is the producer default, 16 shows the
    // saturation plateau. Single-record 64 B batches are the worst case
    // for pipelining — the round trip IS the cost, so the window is the
    // whole lever. p99 is submit-to-reaped-ack per batch.
    {
        let mut t = Table::new(
            "Pipelined produce window (2k x 64B single-record batches, loopback TCP)",
            &["window", "wall (s)", "records/s", "p99 batch (µs)"],
        );
        let batches = 2_000usize;
        for window in [1usize, 5, 16] {
            let cluster = Cluster::new(BrokerConfig::default());
            cluster.create_topic("pw", 1);
            let server = BrokerServer::start("127.0.0.1:0", cluster.clone())?;
            let remote = RemoteBroker::connect(&server.addr().to_string())?;
            let body = Bytes::from_vec(vec![9u8; 64]);
            // Warmup: connection, allocator, server-side topic state.
            for _ in 0..50 {
                let rec = [Record::new(body.clone())];
                remote.produce("pw", 0, &rec, ClientLocality::Remote, None)?;
            }
            let mut inflight: VecDeque<(Instant, Box<dyn ProduceHandle>)> =
                VecDeque::with_capacity(window);
            let mut lats: Vec<Duration> = Vec::with_capacity(batches);
            let reap = |q: &mut VecDeque<(Instant, Box<dyn ProduceHandle>)>,
                            lats: &mut Vec<Duration>|
             -> anyhow::Result<()> {
                let (submitted, mut h) = q.pop_front().expect("reap on empty window");
                match h.wait() {
                    ProduceOutcome::Acked(_) => {
                        lats.push(submitted.elapsed());
                        Ok(())
                    }
                    other => anyhow::bail!("pipelined produce failed: {other:?}"),
                }
            };
            let t0 = Instant::now();
            for _ in 0..batches {
                while inflight.len() >= window {
                    reap(&mut inflight, &mut lats)?;
                }
                let epoch = inflight.back().map(|(_, h)| h.epoch());
                let h = remote.produce_submit(
                    "pw",
                    0,
                    &[Record::new(body.clone())],
                    ClientLocality::Remote,
                    None,
                    epoch,
                );
                inflight.push_back((Instant::now(), h));
            }
            while !inflight.is_empty() {
                reap(&mut inflight, &mut lats)?;
            }
            let wall = t0.elapsed();
            assert_eq!(lats.len(), batches);
            lats.sort();
            let rps = batches as f64 / wall.as_secs_f64();
            let p99 = lats[lats.len() * 99 / 100].as_secs_f64() * 1e6;
            t.row(&[
                window.to_string(),
                format!("{:.3}", wall.as_secs_f64()),
                format!("{rps:.0}"),
                format!("{p99:.1}"),
            ]);
            report.entry(
                "pipelined_produce",
                &[("window", window as f64), ("payload_bytes", 64.0)],
                &[("records_per_s", rps), ("p99_us", p99)],
            );
            server.shutdown();
        }
        t.print();
    }

    // ---- produce latency through a forced leader failover ---------------------
    // The cost of the availability story: a 3-broker cluster at
    // acks=replicated, measured as per-record submit-to-ack latency on a
    // routed client. The steady-state arm prices replication gating; the
    // failover arm SIGKILLs the partition leader mid-stream and keeps
    // producing — the stalled records span heartbeat detection (3 x 25 ms
    // here), follower promotion and the client's metadata refresh, so
    // max/p99 bound the unavailability window seen by a producer.
    {
        use kafka_ml::broker::{AckMode, ClusterCtl, PeerConnector, ReplicaPuller};
        use kafka_ml::orchestrator::ClusterSupervisor;

        let mut t = Table::new(
            "Produce through a forced leader failover (3 brokers, acks=replicated, 64B records)",
            &["phase", "records", "p50 (µs)", "p99 (µs)", "max (ms)"],
        );
        let cfg = BrokerConfig { ack_mode: AckMode::Replicated, ..Default::default() };
        let cores: Vec<ClusterHandle> = (0..3).map(|_| Cluster::new(cfg.clone())).collect();
        let mut servers: Vec<Option<BrokerServer>> = cores
            .iter()
            .map(|c| Some(BrokerServer::start("127.0.0.1:0", c.clone()).unwrap()))
            .collect();
        let roster: Vec<(u32, String)> = servers
            .iter()
            .enumerate()
            .map(|(i, s)| (i as u32, s.as_ref().unwrap().addr().to_string()))
            .collect();
        let mut ctls = Vec::new();
        let mut pullers = Vec::new();
        let mut supervisors = Vec::new();
        for (i, cluster) in cores.iter().enumerate() {
            let ctl = ClusterCtl::new(i as u32, roster.clone());
            cluster.attach_clusterctl(
                ctl.clone(),
                PeerConnector::new(|addr| {
                    Ok(RemoteBroker::connect_peer(addr, None)? as BrokerHandle)
                }),
            );
            pullers.push(Some(ReplicaPuller::start(
                cluster.clone(),
                ctl.clone(),
                Duration::from_millis(2),
            )));
            supervisors.push(Some(ClusterSupervisor::start(
                cluster.clone(),
                ctl.clone(),
                Duration::from_millis(25),
                3,
            )));
            ctls.push(ctl);
        }
        // Rendezvous placement is deterministic per name: pick a topic
        // whose partition 0 is not led by broker 0, so the client's
        // bootstrap broker survives the kill.
        let view = ctls[0].view();
        let (topic, leader) = (0..32)
            .map(|i| format!("fo-{i}"))
            .find_map(|n| {
                let l = view.leader_of(&n, 0).unwrap();
                (l != 0).then_some((n, l))
            })
            .expect("no candidate topic avoids broker 0 as leader");
        let client: BrokerHandle = RemoteBroker::connect(&roster[0].1)?;
        client.create_topic(&topic, 1)?;
        let body = Bytes::from_vec(vec![3u8; 64]);
        let produce_n = |n: usize| -> anyhow::Result<Vec<Duration>> {
            let mut lats = Vec::with_capacity(n);
            for _ in 0..n {
                let rec = [Record::new(body.clone())];
                let t0 = Instant::now();
                client.produce(&topic, 0, &rec, ClientLocality::Remote, None)?;
                lats.push(t0.elapsed());
            }
            Ok(lats)
        };
        let n = 400usize;
        for (failover, label) in [(false, "steady state"), (true, "leader killed mid-stream")] {
            if failover {
                supervisors[leader as usize].take();
                pullers[leader as usize].take();
                if let Some(s) = servers[leader as usize].take() {
                    s.shutdown();
                }
            }
            let mut lats = produce_n(n)?;
            lats.sort();
            let us = |d: Duration| d.as_secs_f64() * 1e6;
            let p50 = us(lats[lats.len() / 2]);
            let p99 = us(lats[lats.len() * 99 / 100]);
            let max_ms = lats[lats.len() - 1].as_secs_f64() * 1e3;
            t.row(&[
                label.to_string(),
                n.to_string(),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                format!("{max_ms:.1}"),
            ]);
            report.entry(
                "cluster_failover",
                &[
                    ("failover", if failover { 1.0 } else { 0.0 }),
                    ("records", n as f64),
                ],
                &[("p50_us", p50), ("p99_us", p99), ("max_ms", max_ms)],
            );
        }
        // At-least-once across the retry path: nothing acked may be
        // missing from the promoted leader's log (duplicates are fine).
        let survived = client
            .fetch_batch(&topic, 0, 0, 10_000, ClientLocality::Remote)?
            .len();
        assert!(survived >= 2 * n, "acked records lost in failover: {survived} < {}", 2 * n);
        t.print();
        // Stop the heartbeat/pull threads before the servers go away so
        // teardown doesn't read as another round of failovers.
        supervisors.clear();
        pullers.clear();
        for s in servers.iter_mut().filter_map(|s| s.take()) {
            s.shutdown();
        }
    }

    report.save(REPORT_PATH)?;
    println!("\nwrote {REPORT_PATH} ({} entries)", report.len());
    Ok(())
}

/// Produce→deliver latency to a parked consumer, sorted ascending.
/// `event_driven` parks in `poll_wait`; the comparison arm replays the
/// pre-notify discipline (poll, sleep 1 ms, repeat).
fn wakeup_latencies(
    c: &ClusterHandle,
    topic: &str,
    iters: usize,
    event_driven: bool,
) -> Vec<Duration> {
    let (tx, rx) = kafka_ml::exec::unbounded::<Instant>();
    let c2 = c.clone();
    let topic2 = topic.to_string();
    let h = std::thread::spawn(move || {
        let mut cons = Consumer::new(c2, ClientLocality::InCluster);
        cons.assign(vec![(topic2, 0)]);
        for _ in 0..iters {
            loop {
                let recs = if event_driven {
                    cons.poll_wait(16, Duration::from_secs(10)).unwrap()
                } else {
                    let recs = cons.poll(16).unwrap();
                    if recs.is_empty() {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    recs
                };
                if !recs.is_empty() {
                    break;
                }
            }
            tx.send(Instant::now()).unwrap();
        }
    });
    let mut lats = Vec::with_capacity(iters);
    for i in 0..iters {
        // Let the consumer reach its park/sleep before producing.
        std::thread::sleep(Duration::from_millis(2));
        let t0 = Instant::now();
        c.produce(
            topic,
            0,
            &[Record::new(vec![i as u8])],
            ClientLocality::InCluster,
            None,
        )
        .unwrap();
        lats.push(rx.recv().unwrap().duration_since(t0));
    }
    h.join().unwrap();
    lats.sort();
    lats
}

/// Fetch requests per second an *idle* consumer issues to the broker.
fn idle_fetch_rate(event_driven: bool) -> f64 {
    let window = Duration::from_millis(400);
    let c = Cluster::new(BrokerConfig::default());
    c.create_topic("idle", 1);
    let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
    cons.assign(vec![("idle".into(), 0)]);
    let t0 = Instant::now();
    if event_driven {
        cons.poll_wait(16, window).unwrap();
    } else {
        while t0.elapsed() < window {
            if cons.poll(16).unwrap().is_empty() {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    c.metrics.counter("broker.fetch.requests").get() as f64 / t0.elapsed().as_secs_f64()
}
