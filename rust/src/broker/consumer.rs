//! Consumer: manual-assign or group-managed, with seek/poll/commit.
//!
//! Two usage modes, matching how Kafka-ML's components consume:
//!
//! * **manual assignment + seek** — training jobs read an exact
//!   `[topic:partition:offset:length]` window named by a control message
//!   (§V), so they `assign` + `seek` and poll a bounded range;
//! * **consumer group** — inference replicas `subscribe` to the input
//!   topic in a shared group; the broker's coordinator spreads
//!   partitions across replicas and rebalances on failure (§IV-D).
//!
//! The consumer talks to the broker through a [`BrokerTransport`]
//! handle, so the same code runs in-process (`Arc<Cluster>` coerces)
//! and against a remote broker over the TCP wire protocol.

use super::group::Assignor;
use super::net::ClientLocality;
use super::record::{ConsumedRecord, RecordBatch};
use super::transport::BrokerTransport;
use super::TopicPartition;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How often a *saturated* blocking poll (one that keeps finding data
/// and therefore never parks) still heartbeats. Idle polls heartbeat
/// after every wait round instead — the broker caps those rounds below
/// the session timeout. Group session timeouts are expected to be well
/// above this (Kafka's defaults: 3 s heartbeat / 45 s session).
const BUSY_HEARTBEAT_EVERY: Duration = Duration::from_millis(500);

pub struct Consumer {
    broker: Arc<dyn BrokerTransport>,
    locality: ClientLocality,
    group: Option<(String, String)>, // (group_id, member_id)
    /// What `subscribe` was called with, so a member evicted while
    /// parked in a blocking poll can rejoin (Kafka clients re-run the
    /// join protocol on session expiry).
    subscription: Option<(Vec<String>, Assignor)>,
    generation: u64,
    assigned: Vec<TopicPartition>,
    positions: HashMap<TopicPartition, u64>,
    next_assigned_idx: usize,
    /// When this member last proved liveness (join or heartbeat) —
    /// drives the saturated-poll heartbeat throttle.
    last_heartbeat: Instant,
}

impl Consumer {
    pub fn new(broker: Arc<dyn BrokerTransport>, locality: ClientLocality) -> Consumer {
        Consumer {
            broker,
            locality,
            group: None,
            subscription: None,
            generation: 0,
            assigned: Vec::new(),
            positions: HashMap::new(),
            next_assigned_idx: 0,
            last_heartbeat: Instant::now(),
        }
    }

    // ---- manual assignment -------------------------------------------------

    /// Manually assign partitions (no group management).
    pub fn assign(&mut self, tps: Vec<TopicPartition>) {
        self.assigned = tps;
        for tp in &self.assigned {
            self.positions.entry(tp.clone()).or_insert(0);
        }
    }

    /// Position the cursor of one partition.
    pub fn seek(&mut self, tp: TopicPartition, offset: u64) {
        self.positions.insert(tp, offset);
    }

    pub fn position(&self, tp: &TopicPartition) -> u64 {
        self.positions.get(tp).copied().unwrap_or(0)
    }

    pub fn assigned(&self) -> &[TopicPartition] {
        &self.assigned
    }

    // ---- group management -----------------------------------------------------

    /// Join `group_id` subscribed to `topics`; positions resume from the
    /// group's committed offsets (or earliest). Fallible: on the remote
    /// transport the join is a network round trip.
    pub fn subscribe(
        &mut self,
        group_id: &str,
        member_id: &str,
        topics: &[String],
        assignor: Assignor,
    ) -> Result<()> {
        let membership = self
            .broker
            .join_group(group_id, member_id, topics, assignor)?;
        self.group = Some((group_id.to_string(), member_id.to_string()));
        self.subscription = Some((topics.to_vec(), assignor));
        self.generation = membership.generation;
        self.last_heartbeat = Instant::now();
        self.apply_assignment(membership.assigned)
    }

    /// Heartbeat; on a generation change the assignment is refreshed.
    /// Returns `Ok(false)` if this member was evicted from the group.
    pub fn poll_heartbeat(&mut self) -> Result<bool> {
        let Some((gid, mid)) = self.group.clone() else {
            return Ok(true);
        };
        match self.broker.heartbeat(&gid, &mid)? {
            Some(m) => {
                self.last_heartbeat = Instant::now();
                if m.generation != self.generation {
                    self.generation = m.generation;
                    self.apply_assignment(m.assigned)?;
                }
                Ok(true)
            }
            None => Ok(false),
        }
    }

    fn apply_assignment(&mut self, assigned: Vec<TopicPartition>) -> Result<()> {
        self.assigned = assigned;
        self.next_assigned_idx = 0;
        let gid = self.group.as_ref().map(|(g, _)| g.clone());
        for tp in &self.assigned {
            let start = match &gid {
                Some(g) => self.broker.committed_offset(g, tp)?.unwrap_or(0),
                None => 0,
            };
            // Keep an existing local position if it is ahead (we may have
            // polled past the last commit).
            let e = self.positions.entry(tp.clone()).or_insert(start);
            *e = (*e).max(start);
        }
        Ok(())
    }

    pub fn leave(&mut self) {
        if let Some((gid, mid)) = self.group.take() {
            // Best-effort: a broker we cannot reach will expire us via
            // the session timeout anyway.
            if let Err(e) = self.broker.leave_group(&gid, &mid) {
                log::debug!("leave_group({gid}, {mid}): {e:#}");
            }
        }
        self.subscription = None;
        self.assigned.clear();
    }

    // ---- polling ---------------------------------------------------------------

    /// Poll up to `max` records across assigned partitions as shared
    /// [`RecordBatch`]es (round-robin fairness between partitions),
    /// advancing local positions. This is the zero-copy poll path: one
    /// partition-lock round trip per *batch* and no per-record
    /// allocation — the coordinator decodes straight from the batches'
    /// `&[u8]` views. Empty batches are omitted.
    pub fn poll_batches(&mut self, max: usize) -> Result<Vec<RecordBatch>> {
        let mut out = Vec::new();
        if self.assigned.is_empty() {
            return Ok(out);
        }
        let n = self.assigned.len();
        let mut got = 0usize;
        for i in 0..n {
            if got >= max {
                break;
            }
            let tp = self.assigned[(self.next_assigned_idx + i) % n].clone();
            let pos = self.position(&tp);
            let batch =
                self.broker
                    .fetch_batch(&tp.0, tp.1, pos, max - got, self.locality)?;
            if let Some(next) = batch.next_offset() {
                self.positions.insert(tp.clone(), next);
            }
            if !batch.is_empty() {
                got += batch.len();
                out.push(batch);
            }
        }
        self.next_assigned_idx = (self.next_assigned_idx + 1) % n;
        Ok(out)
    }

    /// Poll up to `max` records across assigned partitions (round-robin
    /// fairness between them), advancing local positions. Flattens
    /// [`Consumer::poll_batches`]; the per-record handles still share
    /// the log's payload allocations.
    pub fn poll(&mut self, max: usize) -> Result<Vec<ConsumedRecord>> {
        Ok(flatten(self.poll_batches(max)?))
    }

    /// Blocking long-poll: like [`Consumer::poll_batches`], but when
    /// nothing is ready the calling thread **parks** on one waiter
    /// registered across every assigned partition (and the group's
    /// rebalance wait-set) until a produce or rebalance wakes it, or
    /// `timeout` passes. No sleep-poll loop: an idle consumer costs
    /// zero CPU and reacts to a produce in condvar-wakeup time rather
    /// than a sleep quantum. On the remote transport the park happens
    /// server-side; the wire just carries the deadline and the wakeup.
    ///
    /// Liveness while parked: the broker caps each group wait round
    /// well below the session timeout, and the consumer heartbeats
    /// after **every** round (woken or quiet) — so a member parked on
    /// an idle topic for many session lengths is never wrongfully
    /// expired. A member that *was* evicted (e.g. a long network
    /// partition) rejoins with its original subscription, as Kafka
    /// clients do.
    pub fn poll_batches_wait(
        &mut self,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<RecordBatch>> {
        let deadline = Instant::now() + timeout;
        loop {
            let batches = self.poll_batches(max)?;
            if !batches.is_empty() {
                // A member that always finds data never reaches the
                // wait-round heartbeat below — throttle-heartbeat on
                // the data path too, or a saturated consumer would be
                // wrongfully expired after one session timeout. Never
                // at the cost of the fetched records, though: positions
                // already advanced past them, so heartbeat trouble is
                // logged (and retried next round), not propagated.
                if self.group.is_some() && self.last_heartbeat.elapsed() >= BUSY_HEARTBEAT_EVERY {
                    match self.poll_heartbeat() {
                        Ok(true) => {}
                        Ok(false) => {
                            // Evicted: rejoin with the original
                            // subscription, as the parked path does.
                            if let (Some((gid, mid)), Some((topics, assignor))) =
                                (self.group.clone(), self.subscription.clone())
                            {
                                if let Err(e) = self.subscribe(&gid, &mid, &topics, assignor) {
                                    log::debug!("rejoin after eviction failed: {e:#}");
                                }
                            }
                        }
                        Err(e) => log::debug!("deferring busy-path heartbeat: {e:#}"),
                    }
                }
                return Ok(batches);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(batches);
            }
            let assignments: Vec<(TopicPartition, u64)> = self
                .assigned
                .iter()
                .map(|tp| (tp.clone(), self.position(tp)))
                .collect();
            let group = self.group.clone();
            // A false return is a quiet timeout of this wait *round*
            // (the broker caps group waits below the session timeout,
            // and may cap a round when part of the assignment is not
            // registrable yet); the loop re-polls and the deadline
            // check above ends the long-poll — that final poll also
            // closes the race with a produce landing exactly at the
            // deadline.
            let _woken = self.broker.wait_for_data(
                &assignments,
                group.as_ref().map(|(gid, _)| (gid.as_str(), self.generation)),
                deadline - now,
            )?;
            if self.group.is_some() && !self.poll_heartbeat()? {
                // Evicted while parked (session expiry): rejoin with the
                // original subscription, as Kafka clients do — this also
                // resyncs our generation so the next wait parks instead
                // of treating the eviction rebalance as a fresh wakeup
                // forever.
                if let (Some((gid, mid)), Some((topics, assignor))) =
                    (self.group.clone(), self.subscription.clone())
                {
                    self.subscribe(&gid, &mid, &topics, assignor)?;
                }
            }
        }
    }

    /// Poll, waiting up to `timeout` for at least one record — the
    /// blocking flattened variant of [`Consumer::poll_batches_wait`].
    pub fn poll_wait(&mut self, max: usize, timeout: Duration) -> Result<Vec<ConsumedRecord>> {
        Ok(flatten(self.poll_batches_wait(max, timeout)?))
    }

    /// Commit current positions to the group coordinator (one round
    /// trip on the remote transport). Covers only the partitions this
    /// member **currently owns**: `positions` can retain cursors for
    /// partitions rebalanced away, and committing those would rewind a
    /// successor's newer commit (the coordinator stores the last write,
    /// not the max).
    pub fn commit(&self) -> Result<()> {
        if let Some((gid, _)) = &self.group {
            let offsets: Vec<(TopicPartition, u64)> = self
                .assigned
                .iter()
                .map(|tp| (tp.clone(), self.position(tp)))
                .collect();
            self.broker.commit_offsets(gid, &offsets)?;
        }
        Ok(())
    }
}

fn flatten(batches: Vec<RecordBatch>) -> Vec<ConsumedRecord> {
    let total = batches.iter().map(|b| b.len()).sum();
    let mut out = Vec::with_capacity(total);
    for batch in batches {
        out.extend(batch.into_consumed());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, Cluster, ClusterHandle, Record};

    fn cluster_with(topic: &str, parts: u32, records_per_part: u8) -> ClusterHandle {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic(topic, parts);
        for p in 0..parts {
            for i in 0..records_per_part {
                c.produce(
                    topic,
                    p,
                    &[Record::new(vec![p as u8, i])],
                    ClientLocality::InCluster,
                    None,
                )
                .unwrap();
            }
        }
        c
    }

    #[test]
    fn manual_assign_seek_poll() {
        let c = cluster_with("t", 1, 10);
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        cons.seek(("t".into(), 0), 4);
        let recs = cons.poll(3).unwrap();
        assert_eq!(recs.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![4, 5, 6]);
        // Position advanced.
        let more = cons.poll(100).unwrap();
        assert_eq!(more.first().unwrap().offset, 7);
        assert_eq!(more.len(), 3);
    }

    #[test]
    fn poll_round_robins_partitions() {
        let c = cluster_with("t", 2, 5);
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0), ("t".into(), 1)]);
        let recs = cons.poll(100).unwrap();
        assert_eq!(recs.len(), 10);
        let from_p0 = recs.iter().filter(|r| r.partition == 0).count();
        assert_eq!(from_p0, 5);
    }

    #[test]
    fn poll_batches_one_per_partition_sharing_log_payloads() {
        let c = cluster_with("t", 2, 3);
        let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0), ("t".into(), 1)]);
        let batches = cons.poll_batches(100).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches.iter().map(|b| b.len()).sum::<usize>(), 6);
        let t = c.topic("t").unwrap();
        for b in &batches {
            let stored = t.partition(b.partition).unwrap().lock().unwrap().read(0, 10);
            for ((off, rec), (soff, srec)) in b.records.iter().zip(&stored) {
                assert_eq!(off, soff);
                // Zero-copy: consumed payloads share the log's buffers.
                assert!(crate::util::Bytes::ptr_eq(&rec.value, &srec.value));
            }
        }
        // Positions advanced past everything.
        assert!(cons.poll_batches(100).unwrap().is_empty());
    }

    #[test]
    fn group_members_split_partitions_without_overlap() {
        let c = cluster_with("t", 4, 5);
        let mut a = Consumer::new(c.clone(), ClientLocality::InCluster);
        let mut b = Consumer::new(c.clone(), ClientLocality::InCluster);
        a.subscribe("g", "a", &["t".into()], Assignor::RoundRobin).unwrap();
        b.subscribe("g", "b", &["t".into()], Assignor::RoundRobin).unwrap();
        a.poll_heartbeat().unwrap();
        let pa: Vec<_> = a.assigned().to_vec();
        let pb: Vec<_> = b.assigned().to_vec();
        assert_eq!(pa.len() + pb.len(), 4);
        for tp in &pa {
            assert!(!pb.contains(tp));
        }
        // Together they consume everything exactly once.
        let mut all: Vec<crate::util::Bytes> = Vec::new();
        all.extend(a.poll(100).unwrap().into_iter().map(|r| r.record.value));
        all.extend(b.poll(100).unwrap().into_iter().map(|r| r.record.value));
        assert_eq!(all.len(), 20);
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 20);
    }

    #[test]
    fn committed_offsets_resume_replacement_member() {
        let c = cluster_with("t", 1, 10);
        let mut a = Consumer::new(c.clone(), ClientLocality::InCluster);
        a.subscribe("g", "a", &["t".into()], Assignor::Range).unwrap();
        let got = a.poll(4).unwrap();
        assert_eq!(got.len(), 4);
        a.commit().unwrap();
        a.leave();
        // Replacement resumes at the committed offset.
        let mut b = Consumer::new(c, ClientLocality::InCluster);
        b.subscribe("g", "b", &["t".into()], Assignor::Range).unwrap();
        let recs = b.poll(100).unwrap();
        assert_eq!(recs.first().unwrap().offset, 4);
        assert_eq!(recs.len(), 6);
    }

    #[test]
    fn commit_covers_only_the_current_assignment() {
        // Regression: commit() used to send every entry in `positions`,
        // including partitions rebalanced away — rewinding a successor's
        // newer committed offset.
        let c = cluster_with("t", 2, 5);
        let mut a = Consumer::new(c.clone(), ClientLocality::InCluster);
        a.subscribe("g", "a", &["t".into()], Assignor::Range).unwrap();
        assert_eq!(a.assigned().len(), 2);
        assert_eq!(a.poll(100).unwrap().len(), 10); // both cursors at 5
        // A second member takes one partition off a.
        let mut b = Consumer::new(c.clone(), ClientLocality::InCluster);
        b.subscribe("g", "b", &["t".into()], Assignor::Range).unwrap();
        a.poll_heartbeat().unwrap();
        assert_eq!(a.assigned().len(), 1);
        let bs = {
            let pa = a.assigned()[0].clone();
            let all = [("t".to_string(), 0), ("t".to_string(), 1)];
            all.iter().find(|tp| **tp != pa).unwrap().clone()
        };
        // b (the new owner) has made more progress than a ever saw.
        c.commit_offset("g", bs.clone(), 99);
        a.commit().unwrap();
        assert_eq!(
            c.committed_offset("g", &bs),
            Some(99),
            "a's stale cursor rewound the successor's commit"
        );
        assert_eq!(c.committed_offset("g", &a.assigned()[0].clone()), Some(5));
    }

    #[test]
    fn poll_wait_times_out_empty() {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("t", 1);
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        let t0 = Instant::now();
        let recs = cons.poll_wait(10, Duration::from_millis(30)).unwrap();
        assert!(recs.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn poll_batches_wait_parks_until_concurrent_produce() {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("t", 1);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            crate::broker::notify::pause(Duration::from_millis(20));
            c2.produce("t", 0, &[Record::new(vec![7])], ClientLocality::InCluster, None)
                .unwrap();
        });
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        let t0 = Instant::now();
        let batches = cons.poll_batches_wait(10, Duration::from_secs(5)).unwrap();
        assert_eq!(batches.iter().map(|b| b.len()).sum::<usize>(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
    }

    #[test]
    fn poll_wait_returns_early_with_data() {
        let c = cluster_with("t", 1, 1);
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        let t0 = Instant::now();
        let recs = cons.poll_wait(10, Duration::from_secs(5)).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn saturated_member_heartbeats_on_the_data_path() {
        // Regression: poll_batches_wait returns early when data is
        // ready, so a consumer that NEVER parks used to never
        // heartbeat — one session timeout later a perfectly live,
        // fully-saturated member was expired.
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new(0);
        let c = Cluster::with_clock(
            BrokerConfig { session_timeout_ms: 10_000, ..Default::default() },
            std::sync::Arc::new(clock.clone()),
        );
        c.create_topic("busy", 1);
        let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
        cons.subscribe("g", "hot", &["busy".into()], Assignor::Range).unwrap();
        // Last recorded heartbeat is at clock 0; move the clock near
        // the session edge, then keep the consumer saturated long
        // enough (real time) for the busy-path throttle to fire.
        clock.advance_ms(9_000);
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(700) {
            c.produce("busy", 0, &[Record::new(vec![1])], ClientLocality::InCluster, None)
                .unwrap();
            let got = cons.poll_batches_wait(8, Duration::from_secs(5)).unwrap();
            assert!(!got.is_empty(), "saturated consumer polled empty");
        }
        // Past the original session window: only a data-path heartbeat
        // (recorded at clock 9_000) keeps the member alive.
        clock.advance_ms(2_000);
        let evicted = c.expire_group_members();
        assert!(evicted.is_empty(), "saturated member was expired: {evicted:?}");
        assert_eq!(c.group_members("g"), vec!["hot".to_string()]);
    }

    #[test]
    fn member_parked_beyond_session_timeout_survives() {
        // Regression (ISSUE 5): a consumer parked on an idle topic never
        // used to heartbeat (PR 2 refreshed only on rebalance *wakes*),
        // so a park longer than session_ms got a live member wrongfully
        // expired. The broker now caps group wait rounds below the
        // session timeout and the consumer heartbeats between rounds.
        let session_ms = 600u64;
        let c = Cluster::new(BrokerConfig {
            session_timeout_ms: session_ms,
            ..Default::default()
        });
        c.create_topic("idle", 1);
        let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
        cons.subscribe("g", "parked", &["idle".into()], Assignor::Range).unwrap();
        // A housekeeping thread sweeps expirations the whole time the
        // member is parked (this is what evicts a silent member).
        let c2 = c.clone();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let sweeper = std::thread::spawn(move || {
            let mut evicted = Vec::new();
            while !stop2.load(std::sync::atomic::Ordering::SeqCst) {
                evicted.extend(c2.expire_group_members());
                crate::broker::notify::pause(Duration::from_millis(20));
            }
            evicted
        });
        // Park for 2x the session timeout on a topic nobody produces to.
        let park = Duration::from_millis(session_ms * 2);
        let recs = cons.poll_batches_wait(16, park).unwrap();
        assert!(recs.is_empty());
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let evicted = sweeper.join().unwrap();
        assert!(
            evicted.is_empty(),
            "parked member was wrongfully expired: {evicted:?}"
        );
        assert_eq!(c.group_members("g"), vec!["parked".to_string()]);
    }
}
