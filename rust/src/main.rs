//! `kafka-ml` — leader binary. See [`kafka_ml::cli`] for usage.

fn main() {
    kafka_ml::cli::main_entry();
}
