//! Consumer groups: the Kafka feature §IV-D's inference replicas exploit
//! for load balancing and fault tolerance ("matching replicas and
//! partitions").
//!
//! The group coordinator tracks members and their heartbeats, bumps a
//! generation id on every membership change, and computes partition
//! assignments with a pluggable assignor (range / round-robin — the two
//! Kafka ships). Committed offsets are stored per group so a replacement
//! replica resumes where the dead one stopped.

use super::notify::WaitSet;
use super::TopicPartition;
use crate::util::clock::TimestampMs;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Assignor {
    /// Contiguous ranges of partitions per member (Kafka default).
    Range,
    /// Partitions dealt one-by-one across members.
    RoundRobin,
}

/// What a member learns from (re)joining: its generation and partitions.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupMembership {
    pub generation: u64,
    pub assigned: Vec<TopicPartition>,
}

#[derive(Debug)]
struct Member {
    last_heartbeat: TimestampMs,
}

#[derive(Debug)]
pub(crate) struct GroupState {
    pub assignor: Assignor,
    pub generation: u64,
    members: BTreeMap<String, Member>, // BTreeMap => deterministic order
    assignments: HashMap<String, Vec<TopicPartition>>,
    pub committed: HashMap<TopicPartition, u64>,
    /// Topics this group subscribes to (set by the first joiner; later
    /// joins extend it).
    pub topics: Vec<String>,
    /// The partition set the last rebalance distributed — how a
    /// generation-stable re-join detects that the subscription now
    /// resolves to different partitions (a subscribed topic created
    /// *after* the member joined; topic creation itself never touches
    /// groups).
    pub rebalanced_partitions: Vec<TopicPartition>,
    /// Members parked in a blocking poll; membership changes signal it
    /// so they refresh their assignment immediately instead of on the
    /// next heartbeat interval.
    pub wait_set: Arc<WaitSet>,
}

impl GroupState {
    pub fn new(assignor: Assignor) -> GroupState {
        GroupState {
            assignor,
            generation: 0,
            members: BTreeMap::new(),
            assignments: HashMap::new(),
            committed: HashMap::new(),
            topics: Vec::new(),
            rebalanced_partitions: Vec::new(),
            wait_set: Arc::new(WaitSet::new()),
        }
    }

    pub fn member_ids(&self) -> Vec<String> {
        self.members.keys().cloned().collect()
    }

    /// Add (or refresh) a member. Returns `true` when membership
    /// actually changed — a new member, or new topics on the
    /// subscription. An existing member re-joining with identical
    /// topics (a client reconnect) is **generation-stable**: it only
    /// refreshes the heartbeat, so the rest of the group sees no
    /// spurious rebalance and parked members are not woken.
    pub fn join(&mut self, member_id: &str, topics: &[String], now: TimestampMs) -> bool {
        let mut changed = false;
        for t in topics {
            if !self.topics.contains(t) {
                self.topics.push(t.clone());
                changed = true;
            }
        }
        match self.members.get_mut(member_id) {
            Some(m) => m.last_heartbeat = now,
            None => {
                self.members
                    .insert(member_id.to_string(), Member { last_heartbeat: now });
                changed = true;
            }
        }
        if changed {
            self.generation += 1;
        }
        changed
    }

    pub fn leave(&mut self, member_id: &str) -> bool {
        if self.members.remove(member_id).is_some() {
            self.generation += 1;
            true
        } else {
            false
        }
    }

    pub fn heartbeat(&mut self, member_id: &str, now: TimestampMs) -> bool {
        match self.members.get_mut(member_id) {
            Some(m) => {
                m.last_heartbeat = now;
                true
            }
            None => false,
        }
    }

    /// Evict members whose heartbeat is older than `session_ms`;
    /// returns evicted ids (each eviction bumps the generation).
    ///
    /// An eviction is a membership change, so this also (a) purges the
    /// dead members' `assignments` entries — `assignment()` must stop
    /// answering for an evicted member *immediately*, not at the next
    /// external `rebalance()` — and (b) notifies the group wait-set so
    /// a parked surviving member observes the generation change now
    /// instead of sleeping through it until its deadline. Callers still
    /// rebalance afterwards (under the same group-map lock) to hand the
    /// orphaned partitions to the survivors.
    pub fn expire(&mut self, now: TimestampMs, session_ms: u64) -> Vec<String> {
        let dead: Vec<String> = self
            .members
            .iter()
            .filter(|(_, m)| now.saturating_sub(m.last_heartbeat) > session_ms)
            .map(|(id, _)| id.clone())
            .collect();
        for id in &dead {
            self.members.remove(id);
            self.assignments.remove(id);
            self.generation += 1;
        }
        if !dead.is_empty() {
            self.wait_set.notify_all();
        }
        dead
    }

    /// Recompute assignments over `partitions` (all partitions of all
    /// subscribed topics, in topic order) and wake parked members so
    /// they pick up the new generation at once.
    pub fn rebalance(&mut self, partitions: &[TopicPartition]) {
        self.wait_set.notify_all();
        self.rebalanced_partitions = partitions.to_vec();
        self.assignments.clear();
        let members = self.member_ids();
        if members.is_empty() {
            return;
        }
        match self.assignor {
            Assignor::RoundRobin => {
                for (i, tp) in partitions.iter().enumerate() {
                    let m = &members[i % members.len()];
                    self.assignments
                        .entry(m.clone())
                        .or_default()
                        .push(tp.clone());
                }
            }
            Assignor::Range => {
                // Per topic: contiguous ranges, earlier members get the
                // remainder — Kafka's RangeAssignor semantics.
                let mut by_topic: BTreeMap<&str, Vec<&TopicPartition>> = BTreeMap::new();
                for tp in partitions {
                    by_topic.entry(tp.0.as_str()).or_default().push(tp);
                }
                for (_, tps) in by_topic {
                    let n = tps.len();
                    let m = members.len();
                    let per = n / m;
                    let extra = n % m;
                    let mut idx = 0usize;
                    for (mi, member) in members.iter().enumerate() {
                        let take = per + usize::from(mi < extra);
                        for tp in tps.iter().skip(idx).take(take) {
                            self.assignments
                                .entry(member.clone())
                                .or_default()
                                .push((*tp).clone());
                        }
                        idx += take;
                    }
                }
            }
        }
        for m in &members {
            self.assignments.entry(m.clone()).or_default();
        }
    }

    pub fn assignment(&self, member_id: &str) -> Vec<TopicPartition> {
        self.assignments.get(member_id).cloned().unwrap_or_default()
    }

    pub fn commit(&mut self, tp: TopicPartition, offset: u64) {
        self.committed.insert(tp, offset);
    }

    pub fn committed(&self, tp: &TopicPartition) -> Option<u64> {
        self.committed.get(tp).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tps(topic: &str, n: u32) -> Vec<TopicPartition> {
        (0..n).map(|p| (topic.to_string(), p)).collect()
    }

    #[test]
    fn join_bumps_generation_and_assigns_all() {
        let mut g = GroupState::new(Assignor::Range);
        g.join("a", &["t".into()], 0);
        g.rebalance(&tps("t", 4));
        assert_eq!(g.generation, 1);
        assert_eq!(g.assignment("a").len(), 4);
    }

    #[test]
    fn range_assignor_contiguous_with_remainder_first() {
        let mut g = GroupState::new(Assignor::Range);
        g.join("a", &["t".into()], 0);
        g.join("b", &["t".into()], 0);
        g.rebalance(&tps("t", 5));
        let a = g.assignment("a");
        let b = g.assignment("b");
        assert_eq!(a.len(), 3); // gets the remainder
        assert_eq!(b.len(), 2);
        // Contiguity.
        assert_eq!(a.iter().map(|tp| tp.1).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.iter().map(|tp| tp.1).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn round_robin_interleaves() {
        let mut g = GroupState::new(Assignor::RoundRobin);
        g.join("a", &["t".into()], 0);
        g.join("b", &["t".into()], 0);
        g.rebalance(&tps("t", 4));
        assert_eq!(
            g.assignment("a").iter().map(|tp| tp.1).collect::<Vec<_>>(),
            vec![0, 2]
        );
        assert_eq!(
            g.assignment("b").iter().map(|tp| tp.1).collect::<Vec<_>>(),
            vec![1, 3]
        );
    }

    #[test]
    fn assignment_partitions_the_partition_set() {
        // Property: every partition to exactly one member, none dropped.
        for assignor in [Assignor::Range, Assignor::RoundRobin] {
            for members in 1..6 {
                for parts in 0..12 {
                    let mut g = GroupState::new(assignor);
                    for m in 0..members {
                        g.join(&format!("m{m}"), &["t".into()], 0);
                    }
                    let all = tps("t", parts);
                    g.rebalance(&all);
                    let mut seen: Vec<TopicPartition> = g
                        .member_ids()
                        .iter()
                        .flat_map(|m| g.assignment(m))
                        .collect();
                    seen.sort();
                    let mut want = all.clone();
                    want.sort();
                    assert_eq!(seen, want, "{assignor:?} m={members} p={parts}");
                }
            }
        }
    }

    #[test]
    fn expiry_evicts_stale_members() {
        let mut g = GroupState::new(Assignor::Range);
        g.join("a", &["t".into()], 0);
        g.join("b", &["t".into()], 0);
        g.heartbeat("a", 10_000);
        let dead = g.expire(10_001, 5_000);
        assert_eq!(dead, vec!["b".to_string()]);
        assert_eq!(g.member_ids(), vec!["a".to_string()]);
    }

    #[test]
    fn expiry_purges_assignments_and_notifies_parked_members() {
        // Regression (ISSUE 5): expire used to leave the dead member's
        // assignment answering and never woke parked survivors.
        use crate::broker::notify::Waiter;
        let mut g = GroupState::new(Assignor::Range);
        g.join("a", &["t".into()], 0);
        g.join("b", &["t".into()], 0);
        g.rebalance(&tps("t", 4));
        assert!(!g.assignment("b").is_empty());
        let parked = Waiter::new();
        g.wait_set.register(&parked);
        let seen = parked.generation();
        let gen0 = g.generation;
        g.heartbeat("a", 10_000);
        let dead = g.expire(10_001, 5_000);
        assert_eq!(dead, vec!["b".to_string()]);
        // The evicted member's assignment is gone *before* any external
        // rebalance recomputes the survivors'.
        assert!(g.assignment("b").is_empty());
        assert!(g.generation > gen0);
        // A parked survivor was woken by the eviction itself.
        assert!(
            parked.wait_until(seen, std::time::Instant::now()),
            "expire did not notify the group wait-set"
        );
        g.wait_set.deregister(&parked);
    }

    #[test]
    fn identical_rejoin_is_generation_stable() {
        // Regression (ISSUE 5): a reconnecting member re-joining with
        // identical topics must not bump the generation (and therefore
        // must not trigger a group-wide rebalance wakeup storm).
        let mut g = GroupState::new(Assignor::Range);
        assert!(g.join("a", &["t".into()], 0));
        assert!(g.join("b", &["t".into()], 0));
        g.rebalance(&tps("t", 4));
        let gen = g.generation;
        let assigned = g.assignment("a");
        assert!(!g.join("a", &["t".into()], 50));
        assert_eq!(g.generation, gen);
        assert_eq!(g.assignment("a"), assigned);
        // ... but a re-join that *adds* a topic is a real change.
        assert!(g.join("a", &["t".into(), "u".into()], 60));
        assert_eq!(g.generation, gen + 1);
    }

    #[test]
    fn leave_unknown_member_is_noop() {
        let mut g = GroupState::new(Assignor::Range);
        let gen0 = g.generation;
        assert!(!g.leave("ghost"));
        assert_eq!(g.generation, gen0);
    }

    #[test]
    fn commits_survive_rebalance() {
        let mut g = GroupState::new(Assignor::Range);
        g.join("a", &["t".into()], 0);
        g.rebalance(&tps("t", 2));
        g.commit(("t".into(), 0), 42);
        g.join("b", &["t".into()], 0);
        g.rebalance(&tps("t", 2));
        assert_eq!(g.committed(&("t".into(), 0)), Some(42));
    }
}
