//! The Apache Kafka substrate: a from-scratch distributed messaging
//! system (publish/subscribe over a *distributed log*) providing the
//! exact feature set §II of the paper depends on:
//!
//! * **topics / partitions / replicas** with a peer-to-peer set of
//!   brokers, per-partition leaders and in-sync-replica (ISR) tracking;
//! * **the distributed log**: records are retained after consumption
//!   under a configurable retention policy (`retention.bytes`,
//!   `retention.ms`, delete *and* compact cleanup policies) so consumers
//!   can seek anywhere in the log — the property Kafka-ML's stream-reuse
//!   contribution (§V) is built on;
//! * **message-set batching** in the producer (linger + batch size) — the
//!   paper's "high rate of message dispatching" feature;
//! * **consumer groups** with heartbeats, generations and pluggable
//!   range/round-robin assignors — what inference replicas use for load
//!   balancing (§IV-D);
//! * **delivery semantics**: at-most-once, at-least-once and
//!   exactly-once (idempotent producer de-duplication);
//! * a **zero-copy record path**: payloads are [`crate::util::Bytes`]
//!   (Arc-backed shared buffers), copied exactly once when the producer
//!   encodes them; log storage, segment reads, batched fetches
//!   ([`RecordBatch`]), consumer polls and retry buffers all share that
//!   allocation — the paper's "data chunks transferred without
//!   modifications";
//! * a **simulated network profile** (external vs in-cluster link
//!   latency) so the Tables I/II latency columns can be reproduced on a
//!   single machine — see DESIGN.md §Table I/II latency model.

mod cluster;
mod consumer;
mod group;
mod log;
mod net;
mod partition;
mod producer;
mod record;
mod topic;

pub use cluster::{BrokerConfig, Cluster, ClusterHandle};
pub use consumer::Consumer;
pub use group::{Assignor, GroupMembership};
pub use log::{CleanupPolicy, LogConfig, SegmentedLog};
pub use net::{ClientLocality, NetProfile};
pub use partition::Partition;
pub use producer::{Acks, Producer, ProducerConfig};
pub use record::{ConsumedRecord, Record, RecordBatch};
pub use topic::Topic;

/// `(topic, partition)` pair used throughout the broker.
pub type TopicPartition = (String, u32);
