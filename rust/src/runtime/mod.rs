//! The PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + `meta.json`) and executes them on
//! the request path — the piece that replaces TensorFlow in the paper's
//! training Jobs and inference replicas. Python is never involved here.
//!
//! * [`ArtifactMeta`] — the shapes/order contract parsed from
//!   `artifacts/meta.json`;
//! * [`Engine`] — compiles each `*.hlo.txt` once via the PJRT CPU client
//!   and exposes typed `init` / `train_step` / `eval_step` / `predict`;
//! * [`ModelParams`] — host-side parameter tensors with a stable binary
//!   wire format, so trained models can be uploaded to / downloaded from
//!   the back-end registry exactly like the paper's trained-model blobs.

mod engine;
mod meta;
mod params;

pub use engine::{Engine, TrainState};
pub use meta::{ArtifactInfo, ArtifactMeta, ParamMeta};
pub use params::{ModelParams, ParamTensor};
