//! TCP accept loop + thread-pool request handling with graceful shutdown.

use super::http::{Request, Response, Status};
use super::router::Router;
use crate::exec::{CancelToken, ThreadPool};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

pub struct Server {
    addr: SocketAddr,
    cancel: CancelToken,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve `router` on a
    /// pool of `workers` threads until `shutdown`.
    pub fn start(port: u16, workers: usize, router: Router) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port)).context("binding server")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let cancel = CancelToken::new();
        let token = cancel.clone();
        let router = Arc::new(router);
        let accept_thread = std::thread::Builder::new()
            .name("rest-accept".to_string())
            .spawn(move || {
                let pool = ThreadPool::new(workers, "rest-worker");
                while !token.is_cancelled() {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let router = router.clone();
                            pool.execute(move || handle(stream, &router));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            std::thread::sleep(Duration::from_millis(5));
                        }
                    }
                }
                pool.shutdown();
            })?;
        Ok(Server { addr, cancel, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.cancel.cancel();
        if let Some(h) = self.accept_thread.take() {
            h.join().ok();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle(mut stream: TcpStream, router: &Router) {
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .ok();
    let response = match Request::read_from(&mut stream) {
        Ok(req) => router.dispatch(req),
        Err(e) => Response::error(Status::BadRequest, &format!("{e}")),
    };
    if let Err(e) = response.write_to(&mut stream) {
        log::debug!("write response: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;
    use crate::rest::{HttpClient, Method};

    fn test_server() -> Server {
        let router = Router::new()
            .route(Method::Get, "/ping", |_| {
                Response::json(Status::Ok, &Json::str("pong"))
            })
            .route(Method::Post, "/echo", |req| {
                Response::binary(Status::Ok, req.body)
            });
        Server::start(0, 4, router).unwrap()
    }

    #[test]
    fn serves_requests() {
        let s = test_server();
        let client = HttpClient::new(&s.base_url());
        let resp = client.get("/ping").unwrap();
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.body_json().unwrap(), Json::str("pong"));
    }

    #[test]
    fn echoes_binary_bodies() {
        let s = test_server();
        let client = HttpClient::new(&s.base_url());
        let blob: Vec<u8> = (0..=255).collect();
        let resp = client.post_binary("/echo", blob.clone()).unwrap();
        assert_eq!(resp.body, blob);
    }

    #[test]
    fn concurrent_requests() {
        let s = test_server();
        let url = s.base_url();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let url = url.clone();
                std::thread::spawn(move || {
                    let client = HttpClient::new(&url);
                    for _ in 0..10 {
                        assert_eq!(client.get("/ping").unwrap().status, Status::Ok);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_stops_serving() {
        let s = test_server();
        let url = s.base_url();
        s.shutdown();
        let client = HttpClient::new(&url);
        assert!(client.get("/ping").is_err());
    }
}
