//! Inference replica scaling (§IV-D): "the Replication Controller
//! exploits the consumer group feature of Apache Kafka by matching
//! replicas and partitions to provide load balancing and higher data
//! ingestion."
//!
//! With the calibrated network profile the broker hop dominates
//! per-request cost, so extra replicas buy parallel consumption of the
//! partitioned input topic. (Run the `inference_scaling` *example* for
//! the zero-latency CPU-bound variant.)

use kafka_ml::benchkit::Table;
use kafka_ml::broker::{BrokerConfig, ClientLocality, NetProfile};
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;
use kafka_ml::orchestrator::OrchestratorCosts;
use std::time::{Duration, Instant};

fn raw() -> Json {
    Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ])
}

fn main() -> anyhow::Result<()> {
    let kml = KafkaMl::start(KafkaMlConfig {
        broker: BrokerConfig { net: NetProfile::calibrated(), ..Default::default() },
        costs: OrchestratorCosts::calibrated(),
        ..Default::default()
    })?;
    let model = kml.create_model("scale")?;
    let conf = kml.create_configuration("scale", &[model])?;
    let dep = kml.deploy_training(conf, &TrainParams { epochs: 3, ..Default::default() })?;
    let train = hcopd_dataset(200, 8, 4);
    kml.send_stream(
        dep.id, &train.samples, "scale-data", "RAW", &raw(), 0.0,
        ClientLocality::External,
    )?;
    let results = kml.wait_training(&dep, Duration::from_secs(600))?;
    let result_id = results[0].id;

    let requests = 200usize;
    let test = hcopd_dataset(requests, 8, 50);
    let mut t = Table::new(
        "Inference scaling under calibrated network profile",
        &["replicas", "startup (s)", "wall (s)", "req/s", "speedup"],
    );
    let mut base = None;
    for (round, replicas) in [1u32, 2, 4].into_iter().enumerate() {
        let t_start = Instant::now();
        let inf = kml.deploy_inference(
            result_id,
            replicas,
            &format!("sc-in-{round}"),
            &format!("sc-out-{round}"),
        )?;
        let startup = t_start.elapsed();
        let mut client = kml.inference_client(&inf, ClientLocality::External)?;

        let t0 = Instant::now();
        let mut keys = Vec::with_capacity(requests);
        for s in &test.samples {
            keys.push(client.send(&s.features)?);
        }
        for key in &keys {
            client.await_key(key, Duration::from_secs(60))?;
        }
        let wall = t0.elapsed();
        let rps = requests as f64 / wall.as_secs_f64();
        let speedup = match base {
            None => {
                base = Some(rps);
                1.0
            }
            Some(b) => rps / b,
        };
        t.row(&[
            replicas.to_string(),
            format!("{:.3}", startup.as_secs_f64()),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{:.0}", rps),
            format!("{:.2}x", speedup),
        ]);
        kml.stop_inference(inf.id)?;
    }
    t.print();
    kml.shutdown();
    Ok(())
}
