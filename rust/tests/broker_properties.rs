//! Property-based tests on broker + coordinator invariants, using the
//! in-crate `prop` mini-framework (proptest is not available offline).

use kafka_ml::broker::{
    Assignor, BrokerConfig, CleanupPolicy, ClientLocality, Cluster, Consumer, LogConfig,
    Producer, ProducerConfig, Record,
};
use kafka_ml::coordinator::StreamRef;
use kafka_ml::prop::{forall, BytesGen, Gen, IntGen, StringGen, VecGen};
use kafka_ml::util::clock::ManualClock;
use kafka_ml::util::Rng;
use std::sync::Arc;

#[test]
fn prop_log_offsets_dense_and_reads_consistent() {
    // For any payload sequence: offsets are 0..n, and any [from, from+k)
    // read returns exactly the records appended there.
    let gen = VecGen { elem: BytesGen { max_len: 64 }, max_len: 200 };
    forall(11, 60, &gen, |payloads: &Vec<Vec<u8>>| {
        let clock = ManualClock::new(1000);
        let mut log = kafka_ml::broker::SegmentedLog::new(
            LogConfig { segment_bytes: 256, ..LogConfig::default() },
            Arc::new(clock),
        );
        for (i, p) in payloads.iter().enumerate() {
            if log.append(Record::new(p.clone())) != i as u64 {
                return false;
            }
        }
        if log.latest_offset() != payloads.len() as u64 {
            return false;
        }
        // Random window checks.
        let mut rng = Rng::new(payloads.len() as u64);
        for _ in 0..5 {
            if payloads.is_empty() {
                break;
            }
            let from = rng.below(payloads.len() as u64);
            let k = rng.below(payloads.len() as u64 - from + 1) as usize;
            let got = log.read(from, k);
            if got.len() != k {
                return false;
            }
            for (j, (off, rec)) in got.iter().enumerate() {
                if *off != from + j as u64 || rec.value != payloads[(from as usize) + j] {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_retention_preserves_suffix_contiguity() {
    // After any delete-retention sweep, the retained records are a
    // contiguous suffix of what was appended (no holes in the middle).
    let gen = IntGen { lo: 1, hi: 300 };
    forall(13, 40, &gen, |&n: &i64| {
        let clock = ManualClock::new(1000);
        let mut log = kafka_ml::broker::SegmentedLog::new(
            LogConfig {
                segment_bytes: 128,
                retention_bytes: Some(512),
                retention_ms: None,
                cleanup_policy: CleanupPolicy::Delete,
                ..LogConfig::default()
            },
            Arc::new(clock),
        );
        for i in 0..n {
            log.append(Record::new(vec![(i % 251) as u8; 16]));
            log.enforce_retention();
        }
        let earliest = log.earliest_offset();
        let recs = log.read(0, n as usize + 1);
        // Dense suffix [earliest, n).
        recs.len() as u64 == n as u64 - earliest
            && recs
                .iter()
                .enumerate()
                .all(|(j, (off, _))| *off == earliest + j as u64)
    });
}

#[test]
fn prop_group_assignment_partitions_partition_set() {
    // For any member count and partition count under both assignors:
    // every partition is owned by exactly one member.
    #[derive(Clone, Debug)]
    struct Case {
        members: usize,
        partitions: u32,
        round_robin: bool,
    }
    struct CaseGen;
    impl Gen<Case> for CaseGen {
        fn generate(&self, rng: &mut Rng, _size: usize) -> Case {
            Case {
                members: 1 + rng.below(8) as usize,
                partitions: rng.below(20) as u32,
                round_robin: rng.chance(0.5),
            }
        }
    }
    forall(17, 120, &CaseGen, |case: &Case| {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("t", case.partitions.max(1));
        let assignor = if case.round_robin { Assignor::RoundRobin } else { Assignor::Range };
        let mut members = Vec::new();
        for m in 0..case.members {
            members.push(c.join_group("g", &format!("m{m}"), &["t".into()], assignor));
        }
        // Read final assignments via heartbeat (post-rebalance).
        let mut seen = std::collections::HashSet::new();
        let mut total = 0;
        for m in 0..case.members {
            let hb = c.heartbeat("g", &format!("m{m}")).unwrap();
            for tp in hb.assigned {
                total += 1;
                if !seen.insert(tp) {
                    return false; // duplicate ownership
                }
            }
        }
        total == case.partitions.max(1)
    });
}

#[test]
fn prop_produce_consume_preserves_per_partition_order_and_content() {
    // Any keyed record set: per key, consumption order == production
    // order, and nothing is lost or duplicated.
    let gen = VecGen {
        elem: StringGen { max_len: 6 },
        max_len: 120,
    };
    forall(19, 40, &gen, |keys: &Vec<String>| {
        let c = Cluster::new(BrokerConfig { default_partitions: 4, ..Default::default() });
        c.create_topic("t", 4);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 7, ..Default::default() },
        );
        for (i, k) in keys.iter().enumerate() {
            let rec = Record::with_key(k.as_bytes().to_vec(), (i as u32).to_le_bytes().to_vec());
            p.send("t", rec).unwrap();
        }
        p.flush().unwrap();
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign((0..4).map(|i| ("t".to_string(), i)).collect());
        let mut got = Vec::new();
        loop {
            let recs = cons.poll(64).unwrap();
            if recs.is_empty() {
                break;
            }
            got.extend(recs);
        }
        if got.len() != keys.len() {
            return false;
        }
        // Per-key order preserved.
        let mut last_seq: std::collections::HashMap<Vec<u8>, u32> = Default::default();
        let mut per_partition_last: std::collections::HashMap<u32, u64> = Default::default();
        for rec in &got {
            // Offsets strictly increase within a partition poll stream.
            if let Some(&prev) = per_partition_last.get(&rec.partition) {
                if rec.offset <= prev {
                    return false;
                }
            }
            per_partition_last.insert(rec.partition, rec.offset);
        }
        // Group by key and check sequence numbers are increasing.
        let mut by_key: std::collections::HashMap<kafka_ml::util::Bytes, Vec<(u32, u64)>> =
            Default::default();
        for rec in &got {
            let seq = u32::from_le_bytes(rec.record.value[..4].try_into().unwrap());
            by_key
                .entry(rec.record.key.clone().unwrap())
                .or_default()
                .push((seq, rec.offset));
        }
        for (_k, seqs) in by_key {
            let mut sorted_by_offset = seqs.clone();
            sorted_by_offset.sort_by_key(|&(_, off)| off);
            let seq_order: Vec<u32> = sorted_by_offset.iter().map(|&(s, _)| s).collect();
            let mut expected = seq_order.clone();
            expected.sort();
            if seq_order != expected {
                return false;
            }
        }
        let _ = last_seq.insert(vec![], 0);
        true
    });
}

#[test]
fn prop_produce_consume_roundtrip_across_segment_rolls() {
    // Bytes out == bytes in: any payload set produced through the
    // batching producer and read back through the consumer survives
    // segment rolls untouched and in order.
    let gen = VecGen { elem: BytesGen { max_len: 96 }, max_len: 150 };
    forall(31, 40, &gen, |payloads: &Vec<Vec<u8>>| {
        if payloads.is_empty() {
            return true;
        }
        let c = Cluster::new(BrokerConfig {
            log: LogConfig {
                segment_bytes: 200,
                retention_ms: None,
                ..LogConfig::default()
            },
            ..Default::default()
        });
        c.create_topic("t", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 9, ..Default::default() },
        );
        for pay in payloads {
            p.send_to("t", 0, Record::new(pay.clone())).unwrap();
        }
        p.flush().unwrap();
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        let mut got = Vec::new();
        loop {
            let recs = cons.poll(17).unwrap();
            if recs.is_empty() {
                break;
            }
            got.extend(recs);
        }
        got.len() == payloads.len()
            && got.iter().zip(payloads).all(|(r, pay)| r.record.value == *pay)
    });
}

#[test]
fn prop_roundtrip_survives_retention_as_contiguous_suffix() {
    // Delete-retention may drop old segments, but whatever the consumer
    // still sees is byte-identical to what was produced at that offset.
    let gen = IntGen { lo: 1, hi: 200 };
    forall(37, 30, &gen, |&n: &i64| {
        let c = Cluster::new(BrokerConfig {
            log: LogConfig {
                segment_bytes: 128,
                retention_bytes: Some(600),
                retention_ms: None,
                cleanup_policy: CleanupPolicy::Delete,
                ..LogConfig::default()
            },
            ..Default::default()
        });
        c.create_topic("t", 1);
        let payloads: Vec<Vec<u8>> = (0..n).map(|i| vec![(i % 251) as u8; 12]).collect();
        for pay in &payloads {
            c.produce("t", 0, &[Record::new(pay.clone())], ClientLocality::InCluster, None)
                .unwrap();
        }
        c.run_retention();
        let earliest = c.offsets("t", 0).unwrap().0;
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        cons.seek(("t".into(), 0), 0); // retained-away reads skip forward
        let mut got = Vec::new();
        loop {
            let recs = cons.poll(33).unwrap();
            if recs.is_empty() {
                break;
            }
            got.extend(recs);
        }
        got.len() as u64 == n as u64 - earliest
            && got.iter().enumerate().all(|(j, r)| {
                r.offset == earliest + j as u64
                    && r.record.value == payloads[r.offset as usize]
            })
    });
}

#[test]
fn prop_roundtrip_through_compaction_keeps_latest_value_per_key() {
    // Under compact cleanup, the newest surviving record of every key
    // carries exactly the bytes last produced for that key.
    let gen = IntGen { lo: 2, hi: 60 };
    forall(41, 30, &gen, |&n: &i64| {
        let keys = 3u8;
        let c = Cluster::new(BrokerConfig {
            log: LogConfig {
                segment_bytes: 96,
                retention_ms: None,
                cleanup_policy: CleanupPolicy::Compact,
                ..LogConfig::default()
            },
            ..Default::default()
        });
        c.create_topic("t", 1);
        let mut last: std::collections::HashMap<u8, Vec<u8>> = Default::default();
        for i in 0..n {
            let k = (i % keys as i64) as u8;
            let v = vec![k, (i % 250) as u8, 7];
            last.insert(k, v.clone());
            c.produce(
                "t",
                0,
                &[Record::with_key(vec![k], v)],
                ClientLocality::InCluster,
                None,
            )
            .unwrap();
        }
        c.run_retention();
        let mut cons = Consumer::new(c, ClientLocality::InCluster);
        cons.assign(vec![("t".into(), 0)]);
        let mut got = Vec::new();
        loop {
            let recs = cons.poll(19).unwrap();
            if recs.is_empty() {
                break;
            }
            got.extend(recs);
        }
        (0..keys).all(|k| {
            let newest = got
                .iter()
                .filter(|r| r.record.key.as_deref() == Some([k].as_slice()))
                .max_by_key(|r| r.offset);
            match (newest, last.get(&k)) {
                (Some(r), Some(v)) => r.record.value == *v,
                (None, None) => true,
                _ => false,
            }
        })
    });
}

#[test]
fn consume_path_shares_payload_allocation_with_log() {
    // The zero-copy acceptance check: between SegmentedLog storage and
    // the ConsumedRecord handed to the coordinator there are ZERO
    // payload deep-copies — every hop shares one allocation, observable
    // via Bytes::ptr_eq.
    use kafka_ml::util::Bytes;
    let c = Cluster::new(BrokerConfig::default());
    c.create_topic("t", 1);
    let mut p = Producer::new(
        c.clone(),
        ProducerConfig { batch_size: 4, ..Default::default() },
    );
    let payload = Bytes::from_vec(vec![9u8; 4096]);
    p.send_to("t", 0, Record::new(payload.clone())).unwrap();
    p.flush().unwrap();
    // The log-stored record shares the producer's allocation...
    let t = c.topic("t").unwrap();
    let stored = t.partition(0).unwrap().lock().unwrap().read(0, 1);
    assert!(Bytes::ptr_eq(&stored[0].1.value, &payload));
    // ...and so do both consume routes (direct fetch + consumer poll).
    let consumed = c.fetch("t", 0, 0, 1, ClientLocality::InCluster).unwrap();
    assert!(Bytes::ptr_eq(&consumed[0].record.value, &stored[0].1.value));
    let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
    cons.assign(vec![("t".into(), 0)]);
    let polled = cons.poll(10).unwrap();
    assert!(Bytes::ptr_eq(&polled[0].record.value, &stored[0].1.value));
    // The batch route shares too, and carries a shared topic name.
    let batch = c
        .fetch_batch("t", 0, 0, 10, ClientLocality::InCluster)
        .unwrap();
    assert!(Bytes::ptr_eq(&batch.records[0].1.value, &payload));
    assert_eq!(&*batch.topic, "t");
}

#[test]
fn prop_stream_ref_format_parse_roundtrip() {
    #[derive(Clone, Debug)]
    struct RefCase(String, u32, u64, u64);
    struct RefGen;
    impl Gen<RefCase> for RefGen {
        fn generate(&self, rng: &mut Rng, _size: usize) -> RefCase {
            let name_len = 1 + rng.below(12) as usize;
            let topic: String = (0..name_len)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            RefCase(
                topic,
                rng.below(64) as u32,
                rng.below(1 << 40),
                rng.below(1 << 20),
            )
        }
    }
    forall(23, 300, &RefGen, |c: &RefCase| {
        let r = StreamRef::new(&c.0, c.1, c.2, c.3);
        match StreamRef::parse(&r.format()) {
            Ok(back) => back == r,
            Err(_) => false,
        }
    });
}

#[test]
fn prop_avro_roundtrip_random_records() {
    // Random fixed-width feature vectors encode+decode losslessly
    // through the AVRO format used by the HCOPD pipeline.
    let gen = VecGen {
        elem: IntGen { lo: -1000, hi: 1000 },
        max_len: 16,
    };
    let config = kafka_ml::json::parse(
        r#"{
      "data_scheme": {"type":"record","name":"d","fields":[
        {"name":"vals","type":{"type":"array","items":"float"}}]},
      "label_scheme": {"type":"record","name":"l","fields":[
        {"name":"y","type":"int"}]}
    }"#,
    )
    .unwrap();
    let format = kafka_ml::formats::registry("AVRO", &config).unwrap();
    forall(29, 150, &gen, |vals: &Vec<i64>| {
        let feats: Vec<f32> = vals.iter().map(|&v| v as f32 * 0.5).collect();
        if feats.is_empty() {
            return true; // empty arrays are legal but produce no features
        }
        let label = (vals.len() % 4) as i32;
        let rec = format.encode(&feats, Some(label)).unwrap();
        let sample = format.decode(&rec).unwrap();
        sample.features == feats && sample.label == Some(label)
    });
}
