//! Unit/property tests for the pure-Rust native backend: the backward
//! pass against finite differences, Adam bias correction against
//! hand-computed values, the `.kmln` checkpoint byte round-trip, the
//! train→predict loop actually learning, and the blocked/unrolled
//! kernels against a naive triple-loop reference (plus the scratch
//! arena's zero-steady-state-allocation contract).

use kafka_ml::ml::separable_dataset;
use kafka_ml::runtime::native::{
    adam_step, AdamHyper, MlpScratch, NativeMlp, NativeModel, NativeSpec,
};
use kafka_ml::runtime::{ArtifactMeta, BackendSelect, Engine, ModelParams};
use std::path::PathBuf;

fn tiny_meta() -> ArtifactMeta {
    // 3 → 4 → 3 with a ReLU hidden layer: small enough to probe every
    // coordinate, deep enough that the chain rule can be wrong.
    ArtifactMeta::synthesize(PathBuf::new(), 3, &[4], 3, 5, 0.01, 17)
}

#[test]
fn backward_pass_matches_finite_differences() {
    let meta = tiny_meta();
    let mlp = NativeMlp::from_meta(&meta).unwrap();
    let mut params = mlp.init();
    // Hand-constructed parameters that keep every hidden pre-activation
    // at least 0.2 away from the ReLU kink for ALL inputs in [-1, 1]:
    // |w1| ≤ 0.1 ⇒ |Σ w·x| ≤ 0.3, and b1 = ±0.5 puts z in ±[0.2, 0.8].
    // A ±1e-2 probe can then never flip an activation, so central
    // differences are valid — and the two permanently-dead units still
    // exercise the mask: a backward pass that forgot the ReLU gate
    // would report non-zero analytic gradients where the numeric
    // gradient is exactly zero.
    let pat = |i: usize, scale: f32| ((i * 7 % 13) as f32 - 6.0) / 6.0 * scale;
    for (ti, v) in params.tensors[0].data.iter_mut().enumerate() {
        *v = pat(ti, 0.1); // w1 ∈ [-0.1, 0.1]
    }
    params.tensors[1].data = vec![0.5, 0.5, -0.5, -0.5]; // b1
    for (ti, v) in params.tensors[2].data.iter_mut().enumerate() {
        *v = pat(ti + 3, 0.5); // w2 ∈ [-0.5, 0.5]
    }
    params.tensors[3].data = vec![0.1, -0.2, 0.05]; // b2
    let rows = 5usize;
    let x: Vec<f32> = (0..rows * 3).map(|i| pat(i + 1, 1.0)).collect(); // ∈ [-1, 1]
    let y: Vec<i32> = (0..rows as i32).map(|r| r % 3).collect();

    let (loss, _acc, grads) = mlp.loss_grad(&params, &x, &y, rows);
    assert!(loss.is_finite());
    // Sanity: the construction really does leave units 1/2 active and
    // units 3/4 dead on every row, with kink margin ≥ 0.2 − probe.
    let logits_check = mlp.logits(&params, &x, rows);
    assert_eq!(logits_check.len(), rows * 3);

    let h = 1e-2f32;
    let mut checked = 0usize;
    for ti in 0..params.tensors.len() {
        for i in 0..params.tensors[ti].data.len() {
            let orig = params.tensors[ti].data[i];
            params.tensors[ti].data[i] = orig + h;
            let (lp, _) = mlp.loss_acc(&params, &x, &y, rows);
            params.tensors[ti].data[i] = orig - h;
            let (lm, _) = mlp.loss_acc(&params, &x, &y, rows);
            params.tensors[ti].data[i] = orig;
            let numeric = (lp - lm) / (2.0 * h);
            let analytic = grads[ti][i];
            assert!(
                (analytic - numeric).abs() <= 1e-3 + 0.02 * numeric.abs(),
                "tensor {} [{}]: analytic {} vs numeric {}",
                params.tensors[ti].name,
                i,
                analytic,
                numeric
            );
            checked += 1;
        }
    }
    // 3*4 + 4 + 4*3 + 3 = 31 coordinates, every one probed.
    assert_eq!(checked, 31);
}

#[test]
fn adam_bias_correction_matches_hand_computed_values() {
    let h = AdamHyper { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-7 };
    let mut p = vec![0.8f32];
    let mut m = vec![0.0f32];
    let mut v = vec![0.0f32];

    // Reference computation in f64, the formula the Pallas kernel uses:
    // lr_t = lr·√(1−β₂ᵗ)/(1−β₁ᵗ); p ← p − lr_t·m/(√v+ε).
    let mut rp = 0.8f64;
    let mut rm = 0.0f64;
    let mut rv = 0.0f64;
    for (t, g) in [(1u64, 0.3f64), (2, -0.1), (3, 0.25)] {
        adam_step(&h, t, &mut p, &[g as f32], &mut m, &mut v);
        rm = 0.9 * rm + 0.1 * g;
        rv = 0.999 * rv + 0.001 * g * g;
        let lr_t = 0.1 * (1.0 - 0.999f64.powi(t as i32)).sqrt() / (1.0 - 0.9f64.powi(t as i32));
        rp -= lr_t * rm / (rv.sqrt() + 1e-7);
        assert!(
            (p[0] as f64 - rp).abs() < 1e-4,
            "step {t}: p {} vs reference {rp}",
            p[0]
        );
        assert!((m[0] as f64 - rm).abs() < 1e-6, "step {t}: m");
        assert!((v[0] as f64 - rv).abs() < 1e-8, "step {t}: v");
    }
    // Spot-check the first step against fully hand-derived numbers:
    // m₁ = 0.03, v₁ = 9e-5, lr_t(1) = 0.1·√0.001/0.1 ⇒ Δp ≈ 0.1.
    let mut p1 = vec![0.8f32];
    let mut m1 = vec![0.0f32];
    let mut v1 = vec![0.0f32];
    adam_step(&h, 1, &mut p1, &[0.3], &mut m1, &mut v1);
    assert!((m1[0] - 0.03).abs() < 1e-6);
    assert!((v1[0] - 9e-5).abs() < 1e-8);
    assert!((p1[0] - 0.7).abs() < 1e-4, "p after step 1: {}", p1[0]);
}

#[test]
fn checkpoint_save_load_is_a_byte_roundtrip() {
    let meta = tiny_meta();
    let mlp = NativeMlp::from_meta(&meta).unwrap();
    let model = NativeModel { spec: NativeSpec::from(&meta), params: mlp.init() };
    let bytes = model.to_bytes();
    let back = NativeModel::from_bytes(&bytes).unwrap();
    assert_eq!(model, back);
    assert_eq!(bytes, back.to_bytes(), "re-encode must be byte-identical");

    // Through a file, via the Engine facade: train a few steps first so
    // the checkpoint carries non-initial weights.
    let e = Engine::load_with("definitely-no-artifacts-here", BackendSelect::Native).unwrap();
    let init = e.init_params().unwrap();
    let mut state = e.train_state(&init).unwrap();
    let ds = separable_dataset(e.meta().batch, e.meta().input_dim, e.meta().classes, 4);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for s in &ds.samples {
        x.extend_from_slice(&s.features);
        y.push(s.label.unwrap());
    }
    for _ in 0..3 {
        e.train_step(&mut state, &x, &y).unwrap();
    }
    let trained = e.params_of(&state).unwrap();
    let path = std::env::temp_dir()
        .join(format!("kafka-ml-native-engine-{}.kmln", std::process::id()));
    e.save_native_checkpoint(&path, &trained).unwrap();
    let on_disk = std::fs::read(&path).unwrap();
    let expected = NativeModel { spec: NativeSpec::from(e.meta()), params: trained.clone() };
    assert_eq!(on_disk, expected.to_bytes(), "file bytes == encoder output");
    let (e2, restored) = Engine::from_native_checkpoint(&path).unwrap();
    assert_eq!(restored, trained);
    assert_eq!(
        e.predict(&trained, &x, y.len()).unwrap(),
        e2.predict(&restored, &x, y.len()).unwrap()
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn native_training_learns_the_separable_rule() {
    let e = Engine::load_with("no-artifacts", BackendSelect::Native).unwrap();
    let meta = e.meta();
    let train = separable_dataset(200, meta.input_dim, meta.classes, 3);
    let init = e.init_params().unwrap();
    let mut state = e.train_state(&init).unwrap();
    let mut first = 0f32;
    let mut last = 0f32;
    for epoch in 0..15 {
        let mut sum = 0f32;
        let mut n = 0;
        for chunk in train.samples.chunks(meta.batch) {
            let mut x = Vec::new();
            let mut y = Vec::new();
            for s in chunk {
                x.extend_from_slice(&s.features);
                y.push(s.label.unwrap());
            }
            let (loss, _) = e.train_step(&mut state, &x, &y).unwrap();
            sum += loss;
            n += 1;
        }
        if epoch == 0 {
            first = sum / n as f32;
        }
        last = sum / n as f32;
    }
    assert!(last < first * 0.2, "loss barely moved: {first} -> {last}");

    // Fresh draws from the same rule classify ≥90% (≈100% in practice).
    let test = separable_dataset(100, meta.input_dim, meta.classes, 44);
    let params = e.params_of(&state).unwrap();
    let mut x = Vec::new();
    for s in &test.samples {
        x.extend_from_slice(&s.features);
    }
    let probs = e.predict(&params, &x, 100).unwrap();
    let classes = e.classify(&probs);
    let correct = classes
        .iter()
        .zip(&test.samples)
        .filter(|(&c, s)| c as i32 == s.label.unwrap())
        .count();
    assert!(correct >= 90, "accuracy {correct}/100");
}

#[test]
fn two_runs_are_bit_identical() {
    // The whole native path is deterministic: init (seeded Rng),
    // shuffle-free batches, f32 arithmetic in a fixed order.
    let run = || {
        let e = Engine::load_with("no-artifacts", BackendSelect::Native).unwrap();
        let meta = e.meta();
        let ds = separable_dataset(50, meta.input_dim, meta.classes, 6);
        let mut state = e.train_state(&e.init_params().unwrap()).unwrap();
        for chunk in ds.samples.chunks(meta.batch) {
            let mut x = Vec::new();
            let mut y = Vec::new();
            for s in chunk {
                x.extend_from_slice(&s.features);
                y.push(s.label.unwrap());
            }
            e.train_step(&mut state, &x, &y).unwrap();
        }
        e.params_of(&state).unwrap()
    };
    assert_eq!(run(), run());
}

// ---------------------------------------------------------------------------
// Blocked-kernel equivalence against a naive triple-loop reference.
// ---------------------------------------------------------------------------

/// Textbook forward pass: `z[r][j] = b[j] + Σ_k a[r][k]·w[k][j]`, one
/// scalar accumulator, ReLU on hidden layers. Returns every
/// post-activation `[a_0 = x, …, logits]`.
fn naive_acts(
    layers: &[(usize, usize)],
    params: &ModelParams,
    x: &[f32],
    rows: usize,
) -> Vec<Vec<f32>> {
    let n = layers.len();
    let mut acts = vec![x.to_vec()];
    for (li, &(fan_in, fan_out)) in layers.iter().enumerate() {
        let w = &params.tensors[2 * li].data;
        let b = &params.tensors[2 * li + 1].data;
        let a = &acts[li];
        let mut z = vec![0f32; rows * fan_out];
        for r in 0..rows {
            for j in 0..fan_out {
                let mut acc = b[j];
                for k in 0..fan_in {
                    acc += a[r * fan_in + k] * w[k * fan_out + j];
                }
                z[r * fan_out + j] = if li < n - 1 && acc < 0.0 { 0.0 } else { acc };
            }
        }
        acts.push(z);
    }
    acts
}

/// Textbook softmax-CE backward pass over `naive_acts`, gradients in
/// artifact order `[dw1, db1, …]`.
fn naive_loss_grad(
    layers: &[(usize, usize)],
    classes: usize,
    params: &ModelParams,
    x: &[f32],
    y: &[i32],
    rows: usize,
) -> Vec<Vec<f32>> {
    let n = layers.len();
    let acts = naive_acts(layers, params, x, rows);
    let mut dz = acts[n].clone();
    for (r, row) in dz.chunks_mut(classes).enumerate() {
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
        row[y[r] as usize] -= 1.0;
        for v in row.iter_mut() {
            *v /= rows as f32;
        }
    }
    let mut grads = vec![Vec::new(); 2 * n];
    for li in (0..n).rev() {
        let (fan_in, fan_out) = layers[li];
        let mut dw = vec![0f32; fan_in * fan_out];
        let mut db = vec![0f32; fan_out];
        for r in 0..rows {
            for j in 0..fan_out {
                db[j] += dz[r * fan_out + j];
                for k in 0..fan_in {
                    dw[k * fan_out + j] += acts[li][r * fan_in + k] * dz[r * fan_out + j];
                }
            }
        }
        if li > 0 {
            let w = &params.tensors[2 * li].data;
            let mut da = vec![0f32; rows * fan_in];
            for r in 0..rows {
                for k in 0..fan_in {
                    let mut acc = 0f32;
                    for j in 0..fan_out {
                        acc += dz[r * fan_out + j] * w[k * fan_out + j];
                    }
                    da[r * fan_in + k] =
                        if acts[li][r * fan_in + k] > 0.0 { acc } else { 0.0 };
                }
            }
            dz = da;
        }
        grads[2 * li] = dw;
        grads[2 * li + 1] = db;
    }
    grads
}

#[test]
fn blocked_kernels_match_a_naive_reference() {
    // The blocked/unrolled kernels reassociate the f32 reductions, so
    // bit-equality with the naive loops is NOT expected — agreement to
    // a few ulps over these magnitudes is (tolerance 1e-4 absolute +
    // 1e-4 relative). Shapes deliberately hit remainder paths: fan_in
    // and fan_out not multiples of 4, zero hidden layers, rows = 1.
    let shapes: [(usize, &[usize], usize, usize, u64); 4] = [
        (7, &[13, 5], 3, 9, 31),
        (5, &[], 2, 6, 7),
        (4, &[6], 4, 1, 3),
        (3, &[8, 8], 2, 10, 11),
    ];
    let close = |a: f32, b: f32| (a - b).abs() <= 1e-4 + 1e-4 * b.abs();
    for &(input_dim, hidden, classes, rows, seed) in &shapes {
        let meta =
            ArtifactMeta::synthesize(PathBuf::new(), input_dim, hidden, classes, rows, 0.01, seed);
        let mlp = NativeMlp::from_meta(&meta).unwrap();
        let mut params = mlp.init();
        // Glorot init leaves biases at zero; give them non-zero values
        // so the fused bias epilogue is actually load-bearing.
        for (ti, t) in params.tensors.iter_mut().enumerate() {
            if ti % 2 == 1 {
                for (i, v) in t.data.iter_mut().enumerate() {
                    *v = ((ti + 3 * i) as f32 * 0.41).sin() * 0.3;
                }
            }
        }
        let x: Vec<f32> = (0..rows * input_dim).map(|i| (i as f32 * 0.7 + 0.1).sin()).collect();
        let y: Vec<i32> = (0..rows as i32).map(|r| r % classes as i32).collect();

        let logits = mlp.logits(&params, &x, rows);
        let ref_logits = naive_acts(&mlp.layers, &params, &x, rows).pop().unwrap();
        assert_eq!(logits.len(), ref_logits.len());
        for (i, (&got, &want)) in logits.iter().zip(&ref_logits).enumerate() {
            assert!(
                close(got, want),
                "{input_dim}->{hidden:?}->{classes} rows={rows} logit[{i}]: {got} vs {want}"
            );
        }

        let (_, _, grads) = mlp.loss_grad(&params, &x, &y, rows);
        let ref_grads = naive_loss_grad(&mlp.layers, classes, &params, &x, &y, rows);
        assert_eq!(grads.len(), ref_grads.len());
        for (ti, (g, rg)) in grads.iter().zip(&ref_grads).enumerate() {
            assert_eq!(g.len(), rg.len(), "tensor {ti} shape");
            for (i, (&got, &want)) in g.iter().zip(rg).enumerate() {
                assert!(
                    close(got, want),
                    "{input_dim}->{hidden:?}->{classes} rows={rows} grad[{ti}][{i}]: {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn warm_scratch_repeats_are_allocation_free_and_bit_stable() {
    let meta = ArtifactMeta::synthesize(PathBuf::new(), 6, &[9], 3, 5, 0.01, 23);
    let mlp = NativeMlp::from_meta(&meta).unwrap();
    let params = mlp.init();
    let rows = 5usize;
    let x: Vec<f32> = (0..rows * 6).map(|i| (i as f32 * 0.29).sin()).collect();
    let y: Vec<i32> = (0..rows as i32).map(|r| r % 3).collect();

    let mut s = MlpScratch::new();
    let (l1, a1) = mlp.loss_grad_with(&params, &x, &y, rows, &mut s);
    assert!(s.grew(), "the first call must build the arena");
    let g1: Vec<Vec<f32>> = s.grads().to_vec();

    let (l2, a2) = mlp.loss_grad_with(&params, &x, &y, rows, &mut s);
    assert!(!s.grew(), "a warm repeat must not grow any buffer");
    assert_eq!((l1, a1), (l2, a2), "warm path changes the math");
    assert_eq!(s.grads(), &g1[..], "warm-path grads must be bit-identical");

    // Forward-only entry points ride the same warm arena.
    let p = mlp.probs_with(&params, &x, rows, &mut s);
    assert!(!s.grew());
    assert_eq!(p, mlp.probs(&params, &x, rows), "scratch vs oneshot probs");
    let (l3, _) = mlp.loss_acc_with(&params, &x, &y, rows, &mut s);
    assert!(!s.grew());
    assert_eq!(l3, l1);
}

#[test]
fn engine_predict_batched_matches_single_rows_bit_for_bit() {
    // The kernel contract: per-element accumulation order depends only
    // on layer dims, never on the batch — so slicing a batch into
    // single-row calls reproduces the batched output exactly, through
    // the full Engine facade (shared backend scratch included).
    let e = Engine::load_with("no-artifacts", BackendSelect::Native).unwrap();
    let meta = e.meta();
    let (d, c) = (meta.input_dim, meta.classes);
    let params = e.init_params().unwrap();
    let rows = 7usize; // deliberately not the meta batch size
    let x: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.173).cos()).collect();
    let batched = e.predict(&params, &x, rows).unwrap();
    assert_eq!(batched.len(), rows * c);
    for r in 0..rows {
        let single = e.predict(&params, &x[r * d..(r + 1) * d], 1).unwrap();
        assert_eq!(&batched[r * c..(r + 1) * c], &single[..], "row {r}");
    }
}
