//! Deterministic PRNG (SplitMix64 core) — no `rand` crate offline, and
//! the simulator wants reproducible streams anyway.

/// SplitMix64: tiny, fast, passes BigCrush for our simulation purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`; n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine at simulator fidelity.
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)` (f64).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child stream (for per-worker RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
