//! Hermetic stand-in for the `libc` crate.
//!
//! The offline build environment carries no crates.io registry, so this
//! path dependency declares exactly the raw FFI surface the broker's
//! event-loop network core (`broker/wire/reactor.rs`) needs and nothing
//! more:
//!
//! * `epoll_create1` / `epoll_ctl` / `epoll_wait` + `eventfd` — the
//!   Linux readiness engine and its cross-thread wakeup primitive;
//! * `fcntl(F_SETFL, O_NONBLOCK)` — nonblocking sockets;
//! * `writev` — vectored writes (header + zero-copy payload slices);
//! * `poll` + `pipe` — the portable POSIX fallback used on non-Linux
//!   Unixes (self-pipe instead of eventfd, `poll(2)` instead of epoll);
//! * `mmap` / `munmap` / `madvise` — page-cache-backed sealed-segment
//!   residency (`util::bytes::Bytes::map_file`): a read-only private
//!   mapping replaces the full `fs::read` copy, and `MADV_DONTNEED`
//!   releases physical pages on hot-demote. Linux-only, same discipline
//!   as the epoll/poll split — off-Linux callers take a read fallback.
//!
//! Declarations are call-for-call compatible with the real `libc`
//! crate's for this subset — swapping back is a one-line Cargo.toml
//! change. Types and constants are defined per-target exactly as the
//! platform ABI requires (notably `epoll_event` is packed on x86-64
//! Linux and `O_NONBLOCK` differs between Linux and the BSDs).
//!
//! Errors are read the std way: every wrapper-level caller uses
//! `std::io::Error::last_os_error()` right after a failing call, so no
//! `errno` accessor needs declaring here.

#![allow(non_camel_case_types)]

pub use std::os::raw::{c_char, c_int, c_short, c_uint, c_ulong, c_void};

pub type size_t = usize;
pub type ssize_t = isize;

#[cfg(target_os = "linux")]
pub type nfds_t = c_ulong;
#[cfg(not(target_os = "linux"))]
pub type nfds_t = c_uint;

// ---- fcntl ---------------------------------------------------------------

pub const F_GETFL: c_int = 3;
pub const F_SETFL: c_int = 4;

#[cfg(target_os = "linux")]
pub const O_NONBLOCK: c_int = 0o4000;
#[cfg(not(target_os = "linux"))]
pub const O_NONBLOCK: c_int = 0x0004;

// ---- poll (portable readiness fallback) ----------------------------------

pub const POLLIN: c_short = 0x001;
pub const POLLOUT: c_short = 0x004;
pub const POLLERR: c_short = 0x008;
pub const POLLHUP: c_short = 0x010;

#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct pollfd {
    pub fd: c_int,
    pub events: c_short,
    pub revents: c_short,
}

// ---- writev --------------------------------------------------------------

#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct iovec {
    pub iov_base: *mut c_void,
    pub iov_len: size_t,
}

// ---- epoll + eventfd (Linux) ---------------------------------------------

#[cfg(target_os = "linux")]
mod linux {
    use super::{c_int, c_void, size_t};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// The kernel ABI packs this struct on x86-64 (12 bytes); other
    /// architectures use natural alignment (16 bytes).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Debug, Clone, Copy)]
    pub struct epoll_event {
        pub events: u32,
        pub u64: u64,
    }

    // ---- mmap (sealed-segment residency) ---------------------------------

    /// 64-bit file offset: glibc exposes `mmap` with the LFS `off_t` on
    /// every 64-bit target this repo builds for (x86-64, aarch64).
    pub type off_t = i64;

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x02;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;
    pub const MADV_DONTNEED: c_int = 4;

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut epoll_event,
        ) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut epoll_event,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: super::c_uint, flags: c_int) -> c_int;
        pub fn mmap(
            addr: *mut c_void,
            len: size_t,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: off_t,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
        pub fn madvise(addr: *mut c_void, len: size_t, advice: c_int) -> c_int;
    }
}

#[cfg(target_os = "linux")]
pub use linux::*;

// ---- POSIX-universal calls -----------------------------------------------

extern "C" {
    pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    pub fn read(fd: c_int, buf: *mut c_void, count: size_t) -> ssize_t;
    pub fn write(fd: c_int, buf: *const c_void, count: size_t) -> ssize_t;
    pub fn writev(fd: c_int, iov: *const iovec, iovcnt: c_int) -> ssize_t;
    pub fn close(fd: c_int) -> c_int;
    pub fn pipe(fds: *mut c_int) -> c_int;
    pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A self-pipe round trip exercises pipe/fcntl/write/read/close —
    /// the portable half of the surface.
    #[test]
    fn pipe_nonblock_roundtrip() {
        unsafe {
            let mut fds = [-1 as c_int; 2];
            assert_eq!(pipe(fds.as_mut_ptr()), 0);
            let (r, w) = (fds[0], fds[1]);
            let flags = fcntl(r, F_GETFL);
            assert!(flags >= 0);
            assert_eq!(fcntl(r, F_SETFL, flags | O_NONBLOCK), 0);
            // Empty nonblocking pipe: read must not park this thread.
            let mut byte = 0u8;
            let n = read(r, &mut byte as *mut u8 as *mut c_void, 1);
            assert_eq!(n, -1);
            assert_eq!(
                std::io::Error::last_os_error().kind(),
                std::io::ErrorKind::WouldBlock
            );
            assert_eq!(write(w, b"x".as_ptr() as *const c_void, 1), 1);
            assert_eq!(read(r, &mut byte as *mut u8 as *mut c_void, 1), 1);
            assert_eq!(byte, b'x');
            assert_eq!(close(r), 0);
            assert_eq!(close(w), 0);
        }
    }

    #[test]
    fn writev_gathers_slices() {
        unsafe {
            let mut fds = [-1 as c_int; 2];
            assert_eq!(pipe(fds.as_mut_ptr()), 0);
            let (r, w) = (fds[0], fds[1]);
            let (a, b) = (b"hello ".to_vec(), b"world".to_vec());
            let iov = [
                iovec { iov_base: a.as_ptr() as *mut c_void, iov_len: a.len() },
                iovec { iov_base: b.as_ptr() as *mut c_void, iov_len: b.len() },
            ];
            assert_eq!(writev(w, iov.as_ptr(), 2), 11);
            let mut buf = [0u8; 16];
            assert_eq!(read(r, buf.as_mut_ptr() as *mut c_void, 16), 11);
            assert_eq!(&buf[..11], b"hello world");
            close(r);
            close(w);
        }
    }

    #[test]
    fn poll_reports_readiness() {
        unsafe {
            let mut fds = [-1 as c_int; 2];
            assert_eq!(pipe(fds.as_mut_ptr()), 0);
            let (r, w) = (fds[0], fds[1]);
            let mut pfd = [pollfd { fd: r, events: POLLIN, revents: 0 }];
            // Nothing written yet: a zero-timeout poll reports quiet.
            assert_eq!(poll(pfd.as_mut_ptr(), 1, 0), 0);
            assert_eq!(write(w, b"x".as_ptr() as *const c_void, 1), 1);
            assert_eq!(poll(pfd.as_mut_ptr(), 1, 1000), 1);
            assert_ne!(pfd[0].revents & POLLIN, 0);
            close(r);
            close(w);
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn mmap_reads_a_file_and_survives_dontneed() {
        use std::io::Write as _;
        use std::os::unix::io::AsRawFd;
        let path = std::env::temp_dir()
            .join(format!("libc-shim-mmap-{}.bin", std::process::id()));
        let data: Vec<u8> = (0..8192u32).map(|i| (i % 251) as u8).collect();
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&data)
            .unwrap();
        unsafe {
            let f = std::fs::File::open(&path).unwrap();
            let ptr = mmap(
                std::ptr::null_mut(),
                data.len(),
                PROT_READ,
                MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            );
            assert_ne!(ptr, MAP_FAILED);
            // The mapping pins the inode; the fd may close immediately.
            drop(f);
            let view =
                std::slice::from_raw_parts(ptr as *const u8, data.len());
            assert_eq!(view, &data[..]);
            // DONTNEED on a read-only private file mapping drops the
            // physical pages only; the next touch re-faults from the
            // (immutable) file and must read back identical bytes.
            assert_eq!(madvise(ptr, data.len(), MADV_DONTNEED), 0);
            assert_eq!(view, &data[..]);
            assert_eq!(munmap(ptr, data.len()), 0);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_eventfd_roundtrip() {
        unsafe {
            let ep = epoll_create1(EPOLL_CLOEXEC);
            assert!(ep >= 0);
            let ev = eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK);
            assert!(ev >= 0);
            let mut reg = epoll_event { events: EPOLLIN, u64: 42 };
            assert_eq!(epoll_ctl(ep, EPOLL_CTL_ADD, ev, &mut reg), 0);
            // Quiet eventfd: zero-timeout wait returns no events.
            let mut out = [epoll_event { events: 0, u64: 0 }; 4];
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);
            // A counter bump makes it readable, tagged with our token.
            let one = 1u64.to_ne_bytes();
            assert_eq!(write(ev, one.as_ptr() as *const c_void, 8), 8);
            let n = epoll_wait(ep, out.as_mut_ptr(), 4, 1000);
            assert_eq!(n, 1);
            assert_eq!({ out[0].u64 }, 42);
            assert_ne!({ out[0].events } & EPOLLIN, 0);
            // Draining resets it to quiet.
            let mut buf = [0u8; 8];
            assert_eq!(read(ev, buf.as_mut_ptr() as *mut c_void, 8), 8);
            assert_eq!(u64::from_ne_bytes(buf), 1);
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);
            close(ev);
            close(ep);
        }
    }
}
