"""Layer-2: the Kafka-ML model as a JAX compute graph.

The paper's validation model (Listing 1 / Listing 2) is a small Keras MLP
— one hidden layer, multi-input HCOPD features in, a 4-class diagnosis
out (COPD / HC / Asthma / Infected), compiled with
``Adam(lr=.0001)`` + ``sparse_categorical_crossentropy`` + ``accuracy``.

This module rebuilds that model in JAX on top of the Layer-1 Pallas
kernels (:mod:`compile.kernels`):

  * :func:`forward` — dense kernels with ReLU on hidden layers;
  * :func:`predict` — forward + Pallas softmax (the inference artifact);
  * :func:`train_step` — value_and_grad through the dense kernels' custom
    VJP plus a fused Pallas Adam update per tensor (the training
    artifact);
  * :func:`eval_step` — loss + accuracy (the evaluation artifact);
  * :func:`init_params` — Glorot-uniform init (the ``init`` artifact, so
    the Rust side never needs an RNG for model weights).

All functions take/return *flat tuples* of arrays. At AOT time each leaf
becomes one HLO parameter/result, in exactly this order; the order is
recorded in ``artifacts/meta.json`` and relied upon by
``rust/src/runtime``.

Everything Keras' ``model.fit`` did *around* the step function —
iterating the stream, batching, shuffling, validation split, metric
aggregation — is deliberately **not** here: that is Layer-3's job
(``rust/src/coordinator/training.rs``), because in Kafka-ML the data
arrives as a Kafka stream, not as an in-memory dataset.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import adam_update, dense, softmax


@dataclass(frozen=True)
class ModelSpec:
    """Architecture + training hyper-parameters, fixed at AOT time.

    Defaults mirror the paper's HCOPD validation: multi-input features
    (age, gender, smoking status + biosensor channels), one hidden layer,
    4 diagnosis classes, batch size 10, Adam(lr=1e-4).
    """

    input_dim: int = 8
    hidden: Tuple[int, ...] = (16,)
    classes: int = 4
    batch: int = 10
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-7
    seed: int = 42

    @property
    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = [self.input_dim, *self.hidden, self.classes]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def n_layers(self) -> int:
        return len(self.hidden) + 1

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Flat ``(name, shape)`` list in artifact order: w1, b1, w2, b2…"""
        out = []
        for i, (fan_in, fan_out) in enumerate(self.layer_dims, start=1):
            out.append((f"w{i}", (fan_in, fan_out)))
            out.append((f"b{i}", (fan_out,)))
        return out

    def to_json_dict(self) -> dict:
        return {
            "input_dim": self.input_dim,
            "hidden": list(self.hidden),
            "classes": self.classes,
            "batch": self.batch,
            "lr": self.lr,
            "beta1": self.beta1,
            "beta2": self.beta2,
            "eps": self.eps,
            "seed": self.seed,
        }


def init_params(spec: ModelSpec):
    """Glorot-uniform weights + zero biases, in flat artifact order."""
    key = jax.random.PRNGKey(spec.seed)
    params = []
    for fan_in, fan_out in spec.layer_dims:
        key, sub = jax.random.split(key)
        limit = jnp.sqrt(6.0 / (fan_in + fan_out))
        w = jax.random.uniform(
            sub, (fan_in, fan_out), jnp.float32, -limit, limit
        )
        params.extend([w, jnp.zeros((fan_out,), jnp.float32)])
    return tuple(params)


def forward(spec: ModelSpec, params, x):
    """Logits. Hidden layers ReLU, output layer linear — all Pallas."""
    n = spec.n_layers
    h = x
    for i in range(n):
        w, b = params[2 * i], params[2 * i + 1]
        h = dense(h, w, b, "relu" if i < n - 1 else "linear")
    return h


def predict(spec: ModelSpec, params, x):
    """Class probabilities — the inference artifact body."""
    return (softmax(forward(spec, params, x)),)


def loss_and_acc(spec: ModelSpec, params, x, y):
    """Mean sparse categorical cross-entropy + accuracy (f32 scalars)."""
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def eval_step(spec: ModelSpec, params, x, y):
    """Evaluation artifact: ``(loss, accuracy)`` on one batch."""
    loss, acc = loss_and_acc(spec, params, x, y)
    return (loss, acc)


def train_step(spec: ModelSpec, params, m, v, t, x, y):
    """One optimizer step on one streamed batch.

    Args (flat artifact order):
      params: tuple of 2L tensors (w1, b1, …).
      m, v:   Adam first/second-moment tuples, same shapes as params.
      t:      f32 scalar, 1-based step count (for bias correction).
      x:      ``(batch, input_dim)`` f32 features.
      y:      ``(batch,)`` i32 labels.

    Returns ``(*new_params, *new_m, *new_v, loss, acc)``.
    """

    def scalar_loss(ps):
        loss, _ = loss_and_acc(spec, ps, x, y)
        return loss

    (loss, acc), grads = jax.value_and_grad(
        lambda ps: loss_and_acc(spec, ps, x, y), has_aux=True
    )(tuple(params))

    new_p, new_m, new_v = [], [], []
    for p_i, g_i, m_i, v_i in zip(params, grads, m, v):
        p2, m2, v2 = adam_update(
            p_i, g_i, m_i, v_i, t,
            lr=spec.lr, beta1=spec.beta1, beta2=spec.beta2, eps=spec.eps,
        )
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (*new_p, *new_m, *new_v, loss, acc)


# ---------------------------------------------------------------------------
# Reference-model helpers used by the python tests (not lowered).
# ---------------------------------------------------------------------------

def zeros_like_params(spec: ModelSpec):
    """Zero moment tuples matching :func:`init_params`."""
    return tuple(
        jnp.zeros(shape, jnp.float32) for _, shape in spec.param_shapes()
    )
