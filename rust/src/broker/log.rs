//! The distributed log: an append-only, segmented, offset-addressed
//! record store with Kafka's retention semantics.
//!
//! This is the substrate under the paper's §V contribution: because
//! records survive consumption until retention expires them, a data
//! stream identified by `[topic:partition:offset:length]` can be re-read
//! by any number of later deployments.
//!
//! Retention (the paper's §V list):
//!  * `retention.bytes` — drop whole old segments once the partition
//!    exceeds the cap (default: unlimited, as in Kafka);
//!  * `retention.ms` — drop segments whose newest record is older
//!    (default 7 days, as in Kafka);
//!  * cleanup policy `Delete` (Kafka-ML's choice) or `Compact` (keep the
//!    last value per key — implemented for completeness; the paper
//!    explains why Kafka-ML prefers delete).
//!
//! Deletion happens at *segment* granularity, exactly like Kafka: the
//! active (last) segment is never deleted.

use super::record::Record;
use crate::util::bytes::Bytes;
use crate::util::clock::{SharedClock, TimestampMs};
use std::collections::{HashMap, VecDeque};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CleanupPolicy {
    Delete,
    Compact,
}

#[derive(Debug, Clone)]
pub struct LogConfig {
    /// Roll to a new segment after this many bytes.
    pub segment_bytes: usize,
    /// `retention.bytes` (None = unlimited, Kafka default).
    pub retention_bytes: Option<u64>,
    /// `retention.ms` (None = keep forever; Kafka default 7 days).
    pub retention_ms: Option<u64>,
    pub cleanup_policy: CleanupPolicy,
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig {
            segment_bytes: 1 << 20, // 1 MiB
            retention_bytes: None,
            retention_ms: Some(7 * 24 * 3600 * 1000),
            cleanup_policy: CleanupPolicy::Delete,
        }
    }
}

#[derive(Debug)]
struct Segment {
    /// Offsets parallel to `records` — after compaction offsets are no
    /// longer dense, so they are stored explicitly.
    offsets: Vec<u64>,
    records: Vec<Record>,
    size_bytes: usize,
    max_timestamp: TimestampMs,
}

impl Segment {
    fn new() -> Segment {
        Segment {
            offsets: Vec::new(),
            records: Vec::new(),
            size_bytes: 0,
            max_timestamp: 0,
        }
    }

    fn last_offset(&self) -> Option<u64> {
        self.offsets.last().copied()
    }
}

/// An in-memory segmented log for one partition.
#[derive(Debug)]
pub struct SegmentedLog {
    config: LogConfig,
    clock: SharedClock,
    segments: VecDeque<Segment>,
    next_offset: u64,
}

impl SegmentedLog {
    pub fn new(config: LogConfig, clock: SharedClock) -> SegmentedLog {
        let mut segments = VecDeque::new();
        segments.push_back(Segment::new());
        SegmentedLog { config, clock, segments, next_offset: 0 }
    }

    /// Append one record; returns its offset. Stamps the record with the
    /// broker clock if the producer left timestamp 0.
    pub fn append(&mut self, mut record: Record) -> u64 {
        if record.timestamp_ms == 0 {
            record.timestamp_ms = self.clock.now_ms();
        }
        let offset = self.next_offset;
        self.next_offset += 1;

        let roll = {
            let active = self.segments.back().unwrap();
            !active.records.is_empty() && active.size_bytes >= self.config.segment_bytes
        };
        if roll {
            self.segments.push_back(Segment::new());
        }
        let active = self.segments.back_mut().unwrap();
        active.size_bytes += record.size_bytes();
        active.max_timestamp = active.max_timestamp.max(record.timestamp_ms);
        active.offsets.push(offset);
        active.records.push(record);
        offset
    }

    /// Read up to `max` records starting at `from` (inclusive). Records
    /// below the log-start offset are skipped (they were retained away).
    ///
    /// Zero-copy: each returned [`Record`] shares its key/value/header
    /// payload allocations with the stored record (`Record::clone` is an
    /// Arc bump), so a read costs O(1) copies per record instead of
    /// O(payload bytes).
    pub fn read(&self, from: u64, max: usize) -> Vec<(u64, Record)> {
        let mut out = Vec::new();
        for seg in &self.segments {
            if seg.last_offset().map(|l| l < from).unwrap_or(true) {
                continue;
            }
            let start = seg.offsets.partition_point(|&o| o < from);
            for i in start..seg.offsets.len() {
                if out.len() >= max {
                    return out;
                }
                out.push((seg.offsets[i], seg.records[i].clone()));
            }
        }
        out
    }

    /// First retained offset.
    pub fn earliest_offset(&self) -> u64 {
        self.segments
            .front()
            .and_then(|s| s.offsets.first().copied())
            .unwrap_or(self.next_offset)
    }

    /// Offset that will be assigned to the next record (= "latest").
    pub fn latest_offset(&self) -> u64 {
        self.next_offset
    }

    pub fn len(&self) -> u64 {
        self.segments.iter().map(|s| s.records.len() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.size_bytes as u64).sum()
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Apply the retention policy; returns the number of records removed.
    /// Mirrors Kafka's log cleaner: `Delete` drops whole expired/oversize
    /// segments (never the active one); `Compact` rewrites closed
    /// segments keeping only the most recent value per key.
    pub fn enforce_retention(&mut self) -> u64 {
        match self.config.cleanup_policy {
            CleanupPolicy::Delete => self.enforce_delete(),
            CleanupPolicy::Compact => self.compact(),
        }
    }

    fn enforce_delete(&mut self) -> u64 {
        let now = self.clock.now_ms();
        let mut removed = 0u64;
        // Time-based: drop closed segments whose newest record expired.
        if let Some(ret_ms) = self.config.retention_ms {
            while self.segments.len() > 1 {
                let first = self.segments.front().unwrap();
                if now.saturating_sub(first.max_timestamp) > ret_ms {
                    removed += self.segments.pop_front().unwrap().records.len() as u64;
                } else {
                    break;
                }
            }
        }
        // Size-based: drop oldest closed segments until under the cap.
        if let Some(cap) = self.config.retention_bytes {
            while self.segments.len() > 1 && self.size_bytes() > cap {
                removed += self.segments.pop_front().unwrap().records.len() as u64;
            }
        }
        removed
    }

    /// Keep the last value for each key across *closed* segments (the
    /// active segment is left untouched, as in Kafka). Records without a
    /// key are retained (Kafka requires keys for compacted topics; we are
    /// lenient and treat key-less records as unique).
    fn compact(&mut self) -> u64 {
        if self.segments.len() <= 1 {
            return 0;
        }
        // Latest offset per key across the whole log (active included —
        // a newer value in the active segment supersedes older ones).
        // Keys are shared `Bytes`, so building the index copies nothing.
        let mut latest: HashMap<Bytes, u64> = HashMap::new();
        for seg in &self.segments {
            for (i, r) in seg.records.iter().enumerate() {
                if let Some(k) = &r.key {
                    latest.insert(k.clone(), seg.offsets[i]);
                }
            }
        }
        let mut removed = 0u64;
        let closed = self.segments.len() - 1;
        for seg in self.segments.iter_mut().take(closed) {
            let mut offsets = Vec::new();
            let mut records = Vec::new();
            let mut size = 0usize;
            for (i, r) in seg.records.iter().enumerate() {
                let keep = match &r.key {
                    Some(k) => latest.get(k) == Some(&seg.offsets[i]),
                    None => true,
                };
                if keep {
                    size += r.size_bytes();
                    offsets.push(seg.offsets[i]);
                    records.push(r.clone());
                } else {
                    removed += 1;
                }
            }
            seg.offsets = offsets;
            seg.records = records;
            seg.size_bytes = size;
        }
        // Drop fully-compacted-away segments (keep at least the active).
        while self.segments.len() > 1 && self.segments.front().unwrap().records.is_empty() {
            self.segments.pop_front();
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::sync::Arc;

    fn log_with(config: LogConfig) -> (SegmentedLog, ManualClock) {
        let clock = ManualClock::new(1_000_000);
        (SegmentedLog::new(config, Arc::new(clock.clone())), clock)
    }

    fn rec(n: u8) -> Record {
        Record::new(vec![n; 10])
    }

    #[test]
    fn offsets_dense_and_monotonic() {
        let (mut log, _) = log_with(LogConfig::default());
        for i in 0..100u8 {
            assert_eq!(log.append(rec(i)), i as u64);
        }
        assert_eq!(log.latest_offset(), 100);
        assert_eq!(log.earliest_offset(), 0);
    }

    #[test]
    fn read_range_and_bounds() {
        let (mut log, _) = log_with(LogConfig::default());
        for i in 0..50u8 {
            log.append(rec(i));
        }
        let got = log.read(10, 5);
        assert_eq!(got.len(), 5);
        assert_eq!(got[0].0, 10);
        assert_eq!(got[4].0, 14);
        assert_eq!(got[0].1.value, vec![10u8; 10]);
        assert!(log.read(50, 10).is_empty());
        assert_eq!(log.read(48, 10).len(), 2);
    }

    #[test]
    fn segments_roll_at_size() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 100,
            ..LogConfig::default()
        });
        for i in 0..20u8 {
            log.append(rec(i)); // 26 bytes each
        }
        assert!(log.segment_count() > 2, "{}", log.segment_count());
        // All records still readable across segments.
        assert_eq!(log.read(0, 100).len(), 20);
    }

    #[test]
    fn time_retention_drops_old_segments_not_active() {
        let (mut log, clock) = log_with(LogConfig {
            segment_bytes: 100,
            retention_ms: Some(1000),
            ..LogConfig::default()
        });
        for i in 0..10u8 {
            log.append(rec(i));
        }
        clock.advance_ms(10_000);
        for i in 10..14u8 {
            log.append(rec(i)); // fresh records in newer segments
        }
        let removed = log.enforce_retention();
        assert!(removed > 0);
        // Old records gone; fresh ones retained.
        assert!(log.earliest_offset() > 0);
        let all = log.read(0, 100);
        assert!(all.iter().all(|(o, _)| *o >= log.earliest_offset()));
        assert!(all.iter().any(|(_, r)| r.value == vec![13u8; 10]));
    }

    #[test]
    fn size_retention_caps_log() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 100,
            retention_bytes: Some(300),
            retention_ms: None,
            ..LogConfig::default()
        });
        for i in 0..100u8 {
            log.append(rec(i));
            log.enforce_retention();
        }
        assert!(log.size_bytes() <= 300 + 100 + 26, "{}", log.size_bytes());
        assert!(log.earliest_offset() > 0);
    }

    #[test]
    fn retention_never_removes_unexpired_data() {
        let (mut log, clock) = log_with(LogConfig {
            segment_bytes: 50,
            retention_ms: Some(60_000),
            ..LogConfig::default()
        });
        for i in 0..30u8 {
            log.append(rec(i));
        }
        clock.advance_ms(1000); // well within retention
        assert_eq!(log.enforce_retention(), 0);
        assert_eq!(log.read(0, 100).len(), 30);
    }

    #[test]
    fn compaction_keeps_last_value_per_key() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 60,
            cleanup_policy: CleanupPolicy::Compact,
            retention_ms: None,
            ..LogConfig::default()
        });
        for round in 0..5u8 {
            for key in 0..3u8 {
                log.append(Record::with_key(vec![key], vec![round; 4]));
            }
        }
        let removed = log.enforce_retention();
        assert!(removed > 0);
        // For each key, the newest surviving value must be the last round.
        let survivors = log.read(0, 1000);
        for key in 0..3u8 {
            let newest = survivors
                .iter()
                .filter(|(_, r)| r.key.as_deref() == Some(&[key]))
                .map(|(o, _)| *o)
                .max()
                .unwrap();
            let (_, r) = survivors.iter().find(|(o, _)| *o == newest).unwrap();
            assert_eq!(r.value, vec![4u8; 4], "key {key}");
        }
        // Offsets remain strictly increasing after compaction.
        let offsets: Vec<u64> = survivors.iter().map(|(o, _)| *o).collect();
        let mut sorted = offsets.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(offsets, sorted);
    }

    #[test]
    fn read_skips_compacted_holes() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 40,
            cleanup_policy: CleanupPolicy::Compact,
            retention_ms: None,
            ..LogConfig::default()
        });
        for i in 0..10u8 {
            log.append(Record::with_key(vec![0], vec![i]));
        }
        log.enforce_retention();
        // Reading from 0 must not loop or return stale offsets < start.
        let got = log.read(0, 100);
        assert!(!got.is_empty());
        for w in got.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }
}
