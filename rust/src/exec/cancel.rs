//! Cooperative cancellation: clone a token into each worker; `cancel()`
//! flips all clones. Used to stop inference replicas, reconcilers and
//! the REST accept loop.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Sleep in small slices so cancellation is observed promptly.
    /// Returns `true` if the full duration elapsed, `false` if cancelled.
    pub fn sleep(&self, d: Duration) -> bool {
        let slice = Duration::from_millis(5);
        let mut left = d;
        while left > Duration::ZERO {
            if self.is_cancelled() {
                return false;
            }
            let step = left.min(slice);
            std::thread::sleep(step);
            left = left.saturating_sub(step);
        }
        !self.is_cancelled()
    }

    /// A child token that is cancelled when either it or the parent is.
    /// (Implemented by sharing the same flag — sufficient for our tree-of
    /// -workers usage where children never outlive a cancelled parent.)
    pub fn child(&self) -> CancelToken {
        self.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancels_across_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn sleep_interrupted_by_cancel() {
        let t = CancelToken::new();
        let t2 = t.clone();
        let h = std::thread::spawn(move || t2.sleep(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        t.cancel();
        let completed = h.join().unwrap();
        assert!(!completed);
    }

    #[test]
    fn sleep_completes_when_not_cancelled() {
        let t = CancelToken::new();
        assert!(t.sleep(Duration::from_millis(10)));
    }
}
