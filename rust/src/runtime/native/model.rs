//! The self-describing native model format (`.kmln`).
//!
//! The PJRT path ships a trained model as a bare [`ModelParams`] blob
//! (`KMLP`, `runtime/params.rs`) because the artifact dir carries the
//! architecture. The native backend has no artifact dir to lean on, so
//! its checkpoint bundles the **spec** (layer shapes + Adam hyper-
//! parameters + seed) with the parameter blob — a single file restores
//! a runnable engine with zero external artifacts:
//!
//! ```text
//! magic "KMLN" | u32 version
//! u32 input_dim | u32 classes | u32 batch
//! f64 lr | f64 beta1 | f64 beta2 | f64 eps | u64 seed
//! u8 n_hidden | u32 hidden[n_hidden]
//! u32 params_len | KMLP blob (ModelParams::to_bytes)
//! ```
//!
//! Everything is little-endian; the embedded params blob keeps its own
//! magic/version so both layers of the format are independently
//! checkable.

use crate::runtime::meta::ArtifactMeta;
use crate::runtime::params::{ModelParams, Reader};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"KMLN";
const VERSION: u32 = 1;

/// Architecture + training hyper-parameters — the native twin of
/// `python/compile/model.py::ModelSpec`, and exactly what
/// [`ArtifactMeta::synthesize`] needs to rebuild a meta.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeSpec {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub batch: usize,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub seed: u64,
}

impl From<&ArtifactMeta> for NativeSpec {
    fn from(m: &ArtifactMeta) -> NativeSpec {
        NativeSpec {
            input_dim: m.input_dim,
            hidden: m.hidden.clone(),
            classes: m.classes,
            batch: m.batch,
            lr: m.lr,
            beta1: m.beta1,
            beta2: m.beta2,
            eps: m.eps,
            seed: m.seed,
        }
    }
}

impl NativeSpec {
    /// Rebuild a full artifact meta (params in `w1, b1, …` order, no
    /// HLO artifacts) rooted at `dir`.
    pub fn to_meta(&self, dir: PathBuf) -> ArtifactMeta {
        let mut meta = ArtifactMeta::synthesize(
            dir,
            self.input_dim,
            &self.hidden,
            self.classes,
            self.batch,
            self.lr,
            self.seed,
        );
        meta.beta1 = self.beta1;
        meta.beta2 = self.beta2;
        meta.eps = self.eps;
        meta
    }
}

/// A checkpoint: spec + trained parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeModel {
    pub spec: NativeSpec,
    pub params: ModelParams,
}

impl NativeModel {
    pub fn to_bytes(&self) -> Vec<u8> {
        let params = self.params.to_bytes();
        let s = &self.spec;
        let mut out = Vec::with_capacity(64 + 4 * s.hidden.len() + params.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(s.input_dim as u32).to_le_bytes());
        out.extend_from_slice(&(s.classes as u32).to_le_bytes());
        out.extend_from_slice(&(s.batch as u32).to_le_bytes());
        out.extend_from_slice(&s.lr.to_le_bytes());
        out.extend_from_slice(&s.beta1.to_le_bytes());
        out.extend_from_slice(&s.beta2.to_le_bytes());
        out.extend_from_slice(&s.eps.to_le_bytes());
        out.extend_from_slice(&s.seed.to_le_bytes());
        out.push(s.hidden.len() as u8);
        for &h in &s.hidden {
            out.extend_from_slice(&(h as u32).to_le_bytes());
        }
        out.extend_from_slice(&(params.len() as u32).to_le_bytes());
        out.extend_from_slice(&params);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<NativeModel> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != MAGIC {
            bail!("bad magic (not a KMLN native model checkpoint)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported native checkpoint version {version}");
        }
        let input_dim = r.u32()? as usize;
        let classes = r.u32()? as usize;
        let batch = r.u32()? as usize;
        let lr = r.f64()?;
        let beta1 = r.f64()?;
        let beta2 = r.f64()?;
        let eps = r.f64()?;
        let seed = r.u64()?;
        let n_hidden = r.take(1)?[0] as usize;
        let mut hidden = Vec::with_capacity(n_hidden);
        for _ in 0..n_hidden {
            hidden.push(r.u32()? as usize);
        }
        let params_len = r.u32()? as usize;
        let params = ModelParams::from_bytes(r.take(params_len)?)
            .context("embedded params blob")?;
        if r.pos != r.len() {
            bail!("trailing bytes in native checkpoint");
        }
        let spec = NativeSpec { input_dim, hidden, classes, batch, lr, beta1, beta2, eps, seed };
        let model = NativeModel { spec, params };
        model.check()?;
        Ok(model)
    }

    /// Cross-check the embedded params against the embedded spec.
    pub fn check(&self) -> Result<()> {
        let meta = self.spec.to_meta(PathBuf::new());
        self.params
            .check_against(&meta.params)
            .context("native checkpoint: params contradict spec")
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<NativeModel> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeMlp;

    fn sample() -> NativeModel {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 3, &[5], 2, 4, 0.02, 11);
        let params = NativeMlp::from_meta(&meta).unwrap().init();
        NativeModel { spec: NativeSpec::from(&meta), params }
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let m = sample();
        let bytes = m.to_bytes();
        let back = NativeModel::from_bytes(&bytes).unwrap();
        assert_eq!(m, back);
        assert_eq!(bytes, back.to_bytes());
    }

    #[test]
    fn spec_to_meta_round_trips() {
        let m = sample();
        let meta = m.spec.to_meta(PathBuf::from("/x"));
        assert_eq!(NativeSpec::from(&meta), m.spec);
        assert!(!meta.has_hlo_artifacts());
    }

    #[test]
    fn rejects_corruption() {
        let m = sample();
        let good = m.to_bytes();
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(NativeModel::from_bytes(&bad_magic).is_err());
        let mut short = good.clone();
        short.truncate(short.len() - 5);
        assert!(NativeModel::from_bytes(&short).is_err());
        let mut long = good.clone();
        long.push(0);
        assert!(NativeModel::from_bytes(&long).is_err());
        // Spec/params contradiction: claim a different input width.
        let mut mismatched = m.clone();
        mismatched.spec.input_dim = 7;
        assert!(NativeModel::from_bytes(&mismatched.to_bytes()).is_err());
    }

    #[test]
    fn save_load_through_a_file() {
        let m = sample();
        let path = std::env::temp_dir()
            .join(format!("kafka-ml-kmln-unit-test-{}.kmln", std::process::id()));
        m.save(&path).unwrap();
        let back = NativeModel::load(&path).unwrap();
        assert_eq!(m, back);
        let _ = std::fs::remove_file(&path);
    }
}
