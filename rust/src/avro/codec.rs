//! Avro binary encoding/decoding (spec-faithful for the supported
//! subset): zigzag varints, IEEE754 little-endian floats, length-prefixed
//! strings/bytes, block-encoded arrays, field-ordered records.

use super::schema::{AvroType, Schema};
use super::AvroValue;
use anyhow::{anyhow, bail, Result};

// ---- varint / zigzag ---------------------------------------------------------

fn write_varint(mut n: u64, out: &mut Vec<u8>) {
    loop {
        let b = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn zigzag(n: i64) -> u64 {
    ((n << 1) ^ (n >> 63)) as u64
}

fn unzigzag(n: u64) -> i64 {
    ((n >> 1) as i64) ^ -((n & 1) as i64)
}

pub(crate) fn write_long(n: i64, out: &mut Vec<u8>) {
    write_varint(zigzag(n), out);
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated avro datum at byte {}", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn varint(&mut self) -> Result<u64> {
        let mut shift = 0u32;
        let mut out = 0u64;
        loop {
            let b = self.take(1)?[0];
            out |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(out);
            }
            shift += 7;
            if shift > 63 {
                bail!("varint overflow");
            }
        }
    }

    fn long(&mut self) -> Result<i64> {
        Ok(unzigzag(self.varint()?))
    }
}

// ---- encode ---------------------------------------------------------------------

/// Encode `value` under `schema` (top-level record).
pub fn encode(schema: &Schema, value: &AvroValue) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_record(schema, value, &mut out)?;
    Ok(out)
}

fn encode_record(schema: &Schema, value: &AvroValue, out: &mut Vec<u8>) -> Result<()> {
    let AvroValue::Record(fields) = value else {
        bail!("schema '{}' expects a record", schema.name);
    };
    if fields.len() != schema.fields.len() {
        bail!(
            "record '{}': {} fields given, schema has {}",
            schema.name,
            fields.len(),
            schema.fields.len()
        );
    }
    for ((fname, fval), fschema) in fields.iter().zip(&schema.fields) {
        if fname != &fschema.name {
            bail!(
                "record '{}': field '{}' out of order (schema wants '{}')",
                schema.name,
                fname,
                fschema.name
            );
        }
        encode_value(&fschema.ty, fval, out)?;
    }
    Ok(())
}

fn encode_value(ty: &AvroType, value: &AvroValue, out: &mut Vec<u8>) -> Result<()> {
    match (ty, value) {
        (AvroType::Boolean, AvroValue::Boolean(b)) => out.push(u8::from(*b)),
        (AvroType::Int, AvroValue::Int(v)) => write_long(*v as i64, out),
        (AvroType::Long, AvroValue::Long(v)) => write_long(*v, out),
        (AvroType::Float, AvroValue::Float(v)) => out.extend_from_slice(&v.to_le_bytes()),
        (AvroType::Double, AvroValue::Double(v)) => out.extend_from_slice(&v.to_le_bytes()),
        (AvroType::Str, AvroValue::Str(s)) => {
            write_long(s.len() as i64, out);
            out.extend_from_slice(s.as_bytes());
        }
        (AvroType::Bytes, AvroValue::Bytes(b)) => {
            write_long(b.len() as i64, out);
            out.extend_from_slice(b);
        }
        (AvroType::Array(item_ty), AvroValue::Array(items)) => {
            if !items.is_empty() {
                write_long(items.len() as i64, out);
                for item in items {
                    encode_value(item_ty, item, out)?;
                }
            }
            out.push(0); // end of blocks
        }
        (AvroType::Record(schema), rec) => encode_record(schema, rec, out)?,
        (ty, val) => bail!("type mismatch: schema {ty:?} vs value {val:?}"),
    }
    Ok(())
}

// ---- decode ---------------------------------------------------------------------

/// Decode one datum under `schema`; errors on trailing bytes.
pub fn decode(schema: &Schema, bytes: &[u8]) -> Result<AvroValue> {
    let mut r = Reader { bytes, pos: 0 };
    let v = decode_record(schema, &mut r)?;
    if r.pos != bytes.len() {
        bail!("trailing bytes after avro datum ({} of {})", r.pos, bytes.len());
    }
    Ok(v)
}

/// Decode one datum, returning the value and the bytes consumed (for
/// concatenated datum streams).
pub fn decode_prefix(schema: &Schema, bytes: &[u8]) -> Result<(AvroValue, usize)> {
    let mut r = Reader { bytes, pos: 0 };
    let v = decode_record(schema, &mut r)?;
    Ok((v, r.pos))
}

fn decode_record(schema: &Schema, r: &mut Reader) -> Result<AvroValue> {
    let mut fields = Vec::with_capacity(schema.fields.len());
    for f in &schema.fields {
        fields.push((f.name.clone(), decode_value(&f.ty, r)?));
    }
    Ok(AvroValue::Record(fields))
}

fn decode_value(ty: &AvroType, r: &mut Reader) -> Result<AvroValue> {
    Ok(match ty {
        AvroType::Boolean => AvroValue::Boolean(match r.take(1)?[0] {
            0 => false,
            1 => true,
            b => bail!("invalid boolean byte {b}"),
        }),
        AvroType::Int => {
            let v = r.long()?;
            AvroValue::Int(
                i32::try_from(v).map_err(|_| anyhow!("int out of range: {v}"))?,
            )
        }
        AvroType::Long => AvroValue::Long(r.long()?),
        AvroType::Float => {
            let b = r.take(4)?;
            AvroValue::Float(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }
        AvroType::Double => {
            let b = r.take(8)?;
            AvroValue::Double(f64::from_le_bytes([
                b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
            ]))
        }
        AvroType::Str => {
            let len = r.long()?;
            if len < 0 {
                bail!("negative string length");
            }
            AvroValue::Str(String::from_utf8(r.take(len as usize)?.to_vec())?)
        }
        AvroType::Bytes => {
            let len = r.long()?;
            if len < 0 {
                bail!("negative bytes length");
            }
            AvroValue::Bytes(r.take(len as usize)?.to_vec())
        }
        AvroType::Array(item_ty) => {
            let mut items = Vec::new();
            loop {
                let mut count = r.long()?;
                if count == 0 {
                    break;
                }
                if count < 0 {
                    // Negative count: block size in bytes follows (spec).
                    count = -count;
                    let _block_bytes = r.long()?;
                }
                for _ in 0..count {
                    items.push(decode_value(item_ty, r)?);
                }
            }
            AvroValue::Array(items)
        }
        AvroType::Record(schema) => decode_record(schema, r)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::avro::Schema;

    fn hcopd_schema() -> Schema {
        Schema::parse_str(
            r#"{"type":"record","name":"copd","fields":[
                {"name":"age","type":"int"},
                {"name":"gender","type":"int"},
                {"name":"smoking","type":"int"},
                {"name":"sensors","type":{"type":"array","items":"float"}}]}"#,
        )
        .unwrap()
    }

    fn hcopd_value() -> AvroValue {
        AvroValue::Record(vec![
            ("age".into(), AvroValue::Int(63)),
            ("gender".into(), AvroValue::Int(1)),
            ("smoking".into(), AvroValue::Int(2)),
            (
                "sensors".into(),
                AvroValue::Array(vec![
                    AvroValue::Float(0.25),
                    AvroValue::Float(-1.5),
                    AvroValue::Float(3.75),
                ]),
            ),
        ])
    }

    #[test]
    fn roundtrip_hcopd_record() {
        let s = hcopd_schema();
        let v = hcopd_value();
        let bytes = encode(&s, &v).unwrap();
        assert_eq!(decode(&s, &bytes).unwrap(), v);
    }

    #[test]
    fn zigzag_known_values() {
        // Avro spec examples: 0→0, -1→1, 1→2, -2→3, 2→4.
        for (n, z) in [(0i64, 0u64), (-1, 1), (1, 2), (-2, 3), (2, 4)] {
            assert_eq!(zigzag(n), z);
            assert_eq!(unzigzag(z), n);
        }
    }

    #[test]
    fn int_encoding_matches_spec() {
        // 63 zigzags to 126 = 0x7e, one byte.
        let s = Schema::parse_str(
            r#"{"type":"record","name":"x","fields":[{"name":"a","type":"int"}]}"#,
        )
        .unwrap();
        let bytes = encode(&s, &AvroValue::Record(vec![("a".into(), AvroValue::Int(63))]))
            .unwrap();
        assert_eq!(bytes, vec![0x7e]);
    }

    #[test]
    fn empty_array_is_single_zero() {
        let s = Schema::parse_str(
            r#"{"type":"record","name":"x","fields":[
                {"name":"a","type":{"type":"array","items":"int"}}]}"#,
        )
        .unwrap();
        let bytes =
            encode(&s, &AvroValue::Record(vec![("a".into(), AvroValue::Array(vec![]))]))
                .unwrap();
        assert_eq!(bytes, vec![0]);
        let back = decode(&s, &bytes).unwrap();
        assert_eq!(back.field("a"), Some(&AvroValue::Array(vec![])));
    }

    #[test]
    fn decode_handles_negative_block_counts() {
        // Encode an array block with negative count + byte size manually.
        let s = Schema::parse_str(
            r#"{"type":"record","name":"x","fields":[
                {"name":"a","type":{"type":"array","items":"int"}}]}"#,
        )
        .unwrap();
        let mut bytes = Vec::new();
        write_long(-2, &mut bytes); // 2 items, negative => size follows
        write_long(2, &mut bytes); // block byte size
        write_long(5, &mut bytes); // item 5
        write_long(7, &mut bytes); // item 7
        write_long(0, &mut bytes); // end
        let v = decode(&s, &bytes).unwrap();
        assert_eq!(
            v.field("a"),
            Some(&AvroValue::Array(vec![AvroValue::Int(5), AvroValue::Int(7)]))
        );
    }

    #[test]
    fn rejects_type_mismatch_and_truncation() {
        let s = hcopd_schema();
        let bad = AvroValue::Record(vec![("age".into(), AvroValue::Str("old".into()))]);
        assert!(encode(&s, &bad).is_err());
        let bytes = encode(&s, &hcopd_value()).unwrap();
        assert!(decode(&s, &bytes[..bytes.len() - 2]).is_err());
        let mut extra = bytes.clone();
        extra.push(9);
        assert!(decode(&s, &extra).is_err());
    }

    #[test]
    fn strings_and_bytes_roundtrip() {
        let s = Schema::parse_str(
            r#"{"type":"record","name":"x","fields":[
                {"name":"s","type":"string"},{"name":"b","type":"bytes"},
                {"name":"ok","type":"boolean"},{"name":"d","type":"double"},
                {"name":"l","type":"long"}]}"#,
        )
        .unwrap();
        let v = AvroValue::Record(vec![
            ("s".into(), AvroValue::Str("héllo".into())),
            ("b".into(), AvroValue::Bytes(vec![0, 255, 128])),
            ("ok".into(), AvroValue::Boolean(true)),
            ("d".into(), AvroValue::Double(-2.75)),
            ("l".into(), AvroValue::Long(1 << 40)),
        ]);
        let bytes = encode(&s, &v).unwrap();
        assert_eq!(decode(&s, &bytes).unwrap(), v);
    }

    #[test]
    fn decode_prefix_reports_consumed() {
        let s = hcopd_schema();
        let mut bytes = encode(&s, &hcopd_value()).unwrap();
        let len1 = bytes.len();
        bytes.extend(encode(&s, &hcopd_value()).unwrap());
        let (v, used) = decode_prefix(&s, &bytes).unwrap();
        assert_eq!(used, len1);
        assert_eq!(v, hcopd_value());
    }
}
