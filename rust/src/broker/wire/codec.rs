//! The binary wire format.
//!
//! ```text
//! frame    := len:u32 | crc:u32 | body              (integers little-endian)
//! request  := corr_id:u64 | opcode:u8  | payload
//! response := corr_id:u64 | status:u8  | payload    (status 0 = ok, 1 = err)
//! ```
//!
//! `len` is the body length, `crc` a CRC-32 (IEEE) over the body — the
//! exact framing discipline of the on-disk segment format
//! (`broker/log/format.rs`), so a reader can *prove* where a valid
//! frame ends: a truncated read, a flipped byte or a lying length
//! prefix is detected before a single payload byte is interpreted.
//! Oversized length prefixes are rejected up front ([`MAX_FRAME_BYTES`])
//! so a corrupt header cannot make a peer allocate gigabytes.
//!
//! Records inside `Produce`/`FetchBatch` payloads are segment-format
//! record frames ([`format::encode_frame`]): self-checksummed,
//! self-describing, and decoded **zero-copy** — key/value/header
//! payloads come back as [`Bytes`] slices of the one buffer the frame
//! body was read into. A produced record therefore lands in the broker
//! log sharing the request buffer's allocation, and a fetched record
//! reaches the consumer sharing the response buffer's.
//!
//! The `corr_id` is the pipelining handle: a client may write many
//! requests down one connection before reading anything back, and
//! responses come back in *completion* order (a parked long-poll
//! finishes after the produce that followed it), so each side matches
//! frames by correlation id ([`peek_corr`]) rather than by position.
//! The server additionally peeks the opcode ([`peek_op`]) to pick a
//! dispatch lane before decoding.
//!
//! Error payloads carry the server's error message verbatim, so client
//! code that matches on messages (the exactly-once producer looks for
//! `duplicate`) behaves identically over the wire.

use crate::broker::clusterctl::{BrokerInfo, ClusterView};
use crate::broker::group::{Assignor, GroupMembership};
use crate::broker::log::format::{self, FrameError};
use crate::broker::record::Record;
use crate::broker::TopicPartition;
use crate::util::bytes::Bytes;
use std::io::Read;

/// Hard ceiling on one frame's body: protects both sides from a
/// corrupt/hostile length prefix. 64 MiB comfortably fits the largest
/// legitimate message set.
pub const MAX_FRAME_BYTES: u32 = 64 * 1024 * 1024;

/// Bytes of `len` + `crc` before each frame body.
pub const WIRE_HEADER_BYTES: usize = 8;

/// Response status: success, payload follows.
pub const STATUS_OK: u8 = 0;
/// Response status: error, payload is the message string.
pub const STATUS_ERR: u8 = 1;

/// Request opcodes. The discriminants are the wire values — append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum OpCode {
    CreateTopic = 1,
    Metadata = 2,
    ListTopics = 3,
    Produce = 4,
    FetchBatch = 5,
    FetchWait = 6,
    Offsets = 7,
    AllocProducerId = 8,
    JoinGroup = 9,
    LeaveGroup = 10,
    Heartbeat = 11,
    CommitOffsets = 12,
    CommittedOffset = 13,
    Metric = 14,
    /// Presents an API key; must precede every other opcode on a
    /// connection when the server enforces auth.
    Authenticate = 15,
    /// The cluster membership/placement view (epoch + broker roster).
    /// An empty roster answers "not clustered".
    ClusterMeta = 16,
    /// Broker-to-broker replication pull: a follower streams a led
    /// partition's records and acks its applied log end.
    ReplicaFetch = 17,
    /// Push a newer membership view to a peer (failover propagation).
    ClusterUpdate = 18,
}

impl OpCode {
    pub fn from_u8(v: u8) -> Option<OpCode> {
        Some(match v {
            1 => OpCode::CreateTopic,
            2 => OpCode::Metadata,
            3 => OpCode::ListTopics,
            4 => OpCode::Produce,
            5 => OpCode::FetchBatch,
            6 => OpCode::FetchWait,
            7 => OpCode::Offsets,
            8 => OpCode::AllocProducerId,
            9 => OpCode::JoinGroup,
            10 => OpCode::LeaveGroup,
            11 => OpCode::Heartbeat,
            12 => OpCode::CommitOffsets,
            13 => OpCode::CommittedOffset,
            14 => OpCode::Metric,
            15 => OpCode::Authenticate,
            16 => OpCode::ClusterMeta,
            17 => OpCode::ReplicaFetch,
            18 => OpCode::ClusterUpdate,
            _ => return None,
        })
    }
}

/// Why a wire frame or payload could not be decoded.
#[derive(Debug)]
pub enum WireError {
    /// The stream ended (or the buffer ran out) mid-frame.
    Truncated,
    /// The frame body does not match its checksum.
    BadChecksum,
    /// The length prefix exceeds [`MAX_FRAME_BYTES`].
    TooLarge(u32),
    /// Structurally invalid payload despite a valid checksum.
    Malformed(&'static str),
    /// Underlying socket error.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated wire frame"),
            WireError::BadChecksum => write!(f, "wire frame failed its CRC-32 check"),
            WireError::TooLarge(n) => {
                write!(f, "wire frame claims {n} bytes (max {MAX_FRAME_BYTES})")
            }
            WireError::Malformed(what) => write!(f, "malformed wire payload: {what}"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<FrameError> for WireError {
    fn from(e: FrameError) -> WireError {
        match e {
            FrameError::Truncated => WireError::Truncated,
            FrameError::BadChecksum => WireError::BadChecksum,
            FrameError::Malformed => WireError::Malformed("record frame"),
        }
    }
}

impl WireError {
    /// Is this a transport-level failure (worth a reconnect) rather
    /// than a decoded protocol answer?
    pub fn is_io(&self) -> bool {
        matches!(self, WireError::Io(_) | WireError::Truncated)
    }
}

// ---- frame I/O -------------------------------------------------------------

/// Append one `len | crc | body` frame to `out`.
pub fn write_frame(out: &mut Vec<u8>, body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&format::crc32(body).to_le_bytes());
    out.extend_from_slice(body);
}

/// Read exactly one frame body off a stream, validating length bound
/// and checksum. A clean EOF before the first header byte — the peer
/// hung up between requests — surfaces as `Truncated`, which callers
/// treat as a normal disconnect.
pub fn read_frame(stream: &mut impl Read) -> Result<Bytes, WireError> {
    let mut hdr = [0u8; WIRE_HEADER_BYTES];
    read_exact(stream, &mut hdr)?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge(len));
    }
    let mut body = vec![0u8; len as usize];
    read_exact(stream, &mut body)?;
    if format::crc32(&body) != crc {
        return Err(WireError::BadChecksum);
    }
    Ok(Bytes::from_vec(body))
}

fn read_exact(stream: &mut impl Read, buf: &mut [u8]) -> Result<(), WireError> {
    stream.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    })
}

/// Peek the correlation id of a frame *body* — requests and responses
/// both lead with `corr:u64`, so this is what a pipelined peer demuxes
/// on before any further decoding. `None` if the body is shorter than
/// the envelope prefix.
pub fn peek_corr(body: &[u8]) -> Option<u64> {
    Some(u64::from_le_bytes(body.get(0..8)?.try_into().ok()?))
}

/// Peek the opcode byte of a request body (byte 8, right after the
/// correlation id) — how the server picks a dispatch lane (one-way
/// metric / long-poll / ordinary-serial) before decoding the frame.
pub fn peek_op(body: &[u8]) -> Option<u8> {
    body.get(8).copied()
}

/// Start a request frame in `out` (clearing it): placeholder header,
/// then `corr | op`. Append the payload with the `put_*` writers and
/// seal with [`finish_frame`]. Encoding straight into a reused buffer
/// is what keeps the steady-state wire path allocation-free.
pub fn begin_request(out: &mut Vec<u8>, corr: u64, op: OpCode) {
    out.clear();
    out.extend_from_slice(&[0u8; WIRE_HEADER_BYTES]); // len + crc, patched by finish_frame
    put_u64(out, corr);
    put_u8(out, op as u8);
}

/// Start a success-response frame in `out` (clearing it): placeholder
/// header, then `corr | STATUS_OK`. Seal with [`finish_frame`].
pub fn begin_response(out: &mut Vec<u8>, corr: u64) {
    out.clear();
    out.extend_from_slice(&[0u8; WIRE_HEADER_BYTES]);
    put_u64(out, corr);
    put_u8(out, STATUS_OK);
}

/// Patch the `len | crc` header of the frame begun by
/// [`begin_request`]/[`begin_response`]. `out` then holds one complete
/// wire frame, byte-identical to the [`encode_request`]/
/// [`encode_response`] forms.
pub fn finish_frame(out: &mut Vec<u8>) {
    let len = (out.len() - WIRE_HEADER_BYTES) as u32;
    let crc = format::crc32(&out[WIRE_HEADER_BYTES..]);
    out[0..4].copy_from_slice(&len.to_le_bytes());
    out[4..8].copy_from_slice(&crc.to_le_bytes());
}

/// Encode one full request frame into a reused buffer (cleared first).
pub fn encode_request_into(out: &mut Vec<u8>, corr: u64, op: OpCode, payload: &[u8]) {
    begin_request(out, corr, op);
    out.extend_from_slice(payload);
    finish_frame(out);
}

/// Encode one full response frame (`corr | status | payload-or-message`)
/// into a reused buffer (cleared first).
pub fn encode_response_into(out: &mut Vec<u8>, corr: u64, result: Result<&[u8], &str>) {
    match result {
        Ok(payload) => {
            begin_response(out, corr);
            out.extend_from_slice(payload);
        }
        Err(msg) => {
            out.clear();
            out.extend_from_slice(&[0u8; WIRE_HEADER_BYTES]);
            put_u64(out, corr);
            put_u8(out, STATUS_ERR);
            put_str(out, msg);
        }
    }
    finish_frame(out);
}

/// One full request frame: `corr | op | payload`, framed.
pub fn encode_request(corr: u64, op: OpCode, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(WIRE_HEADER_BYTES + 9 + payload.len());
    encode_request_into(&mut out, corr, op, payload);
    out
}

/// One full response frame: `corr | status | payload-or-message`.
pub fn encode_response(corr: u64, result: Result<&[u8], &str>) -> Vec<u8> {
    let mut out = Vec::new();
    encode_response_into(&mut out, corr, result);
    out
}

// ---- gather-write response chunks ------------------------------------------

/// Values at or above this size ride the response as shared [`Bytes`]
/// slices (`writev` gather segments) instead of being copied into the
/// response buffer. Below it, one copy into the contiguous header chunk
/// is cheaper than an extra iovec entry.
pub const SHARED_CHUNK_MIN: usize = 4096;

/// One piece of an outgoing frame. A response is a sequence of chunks
/// whose concatenation is byte-identical to the contiguous encoding;
/// `Shared` chunks alias broker-log (or segment-file) buffers so large
/// payloads cross from log to socket without an intermediate copy.
#[derive(Debug)]
pub enum Chunk {
    Owned(Vec<u8>),
    Shared(Bytes),
}

impl Chunk {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Chunk::Owned(v) => v.as_slice(),
            Chunk::Shared(b) => b.as_slice(),
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Encode a `FetchBatch` success response as gather-write chunks:
/// `count | record-frame*` under one wire frame, where every value of
/// at least [`SHARED_CHUNK_MIN`] bytes is emitted as a zero-copy
/// `Shared` chunk (the record-frame header for it comes from
/// [`format::encode_frame_header`], whose `len`/`crc` already cover the
/// detached value). The outer frame's `len`/`crc` are streamed across
/// all chunks and patched into the first, so no contiguous response
/// buffer ever exists. `first` is the caller's recycled scratch buffer
/// (cleared here); chunk 0 is always `Owned` and starts with the wire
/// header.
pub fn encode_fetch_response_chunks<'a>(
    first: Vec<u8>,
    corr: u64,
    records: impl ExactSizeIterator<Item = (u64, &'a Record)>,
) -> Vec<Chunk> {
    let mut buf = first;
    begin_response(&mut buf, corr);
    put_u32(&mut buf, records.len() as u32);
    let mut chunks: Vec<Chunk> = Vec::new();
    for (offset, rec) in records {
        if rec.value.len() >= SHARED_CHUNK_MIN {
            format::encode_frame_header(&mut buf, offset, rec);
            chunks.push(Chunk::Owned(std::mem::take(&mut buf)));
            chunks.push(Chunk::Shared(rec.value.clone()));
        } else {
            format::encode_frame(&mut buf, offset, rec);
        }
    }
    if !buf.is_empty() {
        chunks.push(Chunk::Owned(buf));
    }
    let total: usize = chunks.iter().map(Chunk::len).sum();
    let len = (total - WIRE_HEADER_BYTES) as u32;
    let mut crc = format::Crc32::new();
    for (i, c) in chunks.iter().enumerate() {
        let s = c.as_slice();
        crc.update(if i == 0 { &s[WIRE_HEADER_BYTES..] } else { s });
    }
    let crc = crc.finish();
    match &mut chunks[0] {
        Chunk::Owned(head) => {
            head[0..4].copy_from_slice(&len.to_le_bytes());
            head[4..8].copy_from_slice(&crc.to_le_bytes());
        }
        Chunk::Shared(_) => unreachable!("chunk 0 is the owned header"),
    }
    chunks
}

// ---- primitive writers -----------------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub fn put_strings(out: &mut Vec<u8>, ss: &[String]) {
    put_u32(out, ss.len() as u32);
    for s in ss {
        put_str(out, s);
    }
}

/// Tagged option: `0` or `1 | value`.
pub fn put_opt<T>(out: &mut Vec<u8>, v: Option<&T>, put: impl FnOnce(&mut Vec<u8>, &T)) {
    match v {
        None => out.push(0),
        Some(t) => {
            out.push(1);
            put(out, t);
        }
    }
}

/// `count | record-frame*` — each record is a segment-format frame
/// carrying `offset` (meaningful in fetch responses; the produce path
/// sends the in-batch index, which the broker re-assigns).
pub fn put_records<'a>(
    out: &mut Vec<u8>,
    records: impl ExactSizeIterator<Item = (u64, &'a Record)>,
) {
    put_u32(out, records.len() as u32);
    for (offset, rec) in records {
        format::encode_frame(out, offset, rec);
    }
}

/// `epoch:u64 | count:u32 | (id:u32 addr:str alive:bool)*` — the
/// cluster metadata view (`ClusterMeta` response, `ClusterUpdate`
/// request payload).
pub fn put_cluster_view(out: &mut Vec<u8>, v: &ClusterView) {
    put_u64(out, v.epoch);
    put_u32(out, v.brokers.len() as u32);
    for b in &v.brokers {
        put_u32(out, b.id);
        put_str(out, &b.addr);
        put_bool(out, b.alive);
    }
}

pub fn put_membership(out: &mut Vec<u8>, m: &GroupMembership) {
    put_u64(out, m.generation);
    put_u32(out, m.assigned.len() as u32);
    for (topic, p) in &m.assigned {
        put_str(out, topic);
        put_u32(out, *p);
    }
}

pub fn assignor_to_u8(a: Assignor) -> u8 {
    match a {
        Assignor::Range => 0,
        Assignor::RoundRobin => 1,
    }
}

pub fn assignor_from_u8(v: u8) -> Result<Assignor, WireError> {
    match v {
        0 => Ok(Assignor::Range),
        1 => Ok(Assignor::RoundRobin),
        _ => Err(WireError::Malformed("assignor")),
    }
}

// ---- payload reader --------------------------------------------------------

/// Cursor over one received frame body. Scalar reads copy; `records`
/// decodes zero-copy slices of the underlying buffer.
pub struct Reader {
    buf: Bytes,
    pos: usize,
}

impl Reader {
    pub fn new(buf: Bytes) -> Reader {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&[u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let start = self.pos;
        self.pos += n;
        Ok(&self.buf.as_slice()[start..start + n])
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed("utf-8 string"))
    }

    pub fn strings(&mut self) -> Result<Vec<String>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            out.push(self.str()?);
        }
        Ok(out)
    }

    pub fn opt<T>(
        &mut self,
        read: impl FnOnce(&mut Self) -> Result<T, WireError>,
    ) -> Result<Option<T>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(read(self)?)),
            _ => Err(WireError::Malformed("option tag")),
        }
    }

    /// Decode a record set. Each record's key/value/header payloads are
    /// O(1) [`Bytes`] slices of this reader's buffer — the zero-copy
    /// hop across the wire.
    pub fn records(&mut self) -> Result<Vec<(u64, Record)>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let f = format::decode_frame(&self.buf, self.pos)?;
            self.pos = f.end;
            out.push((f.offset, f.record));
        }
        Ok(out)
    }

    pub fn cluster_view(&mut self) -> Result<ClusterView, WireError> {
        let epoch = self.u64()?;
        let n = self.u32()? as usize;
        let mut brokers = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let id = self.u32()?;
            let addr = self.str()?;
            let alive = self.bool()?;
            brokers.push(BrokerInfo { id, addr, alive });
        }
        Ok(ClusterView { epoch, brokers })
    }

    pub fn membership(&mut self) -> Result<GroupMembership, WireError> {
        let generation = self.u64()?;
        let n = self.u32()? as usize;
        let mut assigned: Vec<TopicPartition> = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let topic = self.str()?;
            let p = self.u32()?;
            assigned.push((topic, p));
        }
        Ok(GroupMembership { generation, assigned })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_body(body: &[u8]) -> Bytes {
        let mut framed = Vec::new();
        write_frame(&mut framed, body);
        read_frame(&mut framed.as_slice()).unwrap()
    }

    #[test]
    fn frame_roundtrip() {
        let body = b"hello wire".to_vec();
        assert_eq!(roundtrip_body(&body).as_slice(), body.as_slice());
        assert!(roundtrip_body(&[]).is_empty());
    }

    #[test]
    fn truncated_frame_detected() {
        let mut framed = Vec::new();
        write_frame(&mut framed, b"some payload body");
        for cut in [framed.len() - 1, WIRE_HEADER_BYTES + 3, 5, 0] {
            let mut short = &framed[..cut];
            match read_frame(&mut short) {
                Err(WireError::Truncated) => {}
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn flipped_byte_fails_checksum() {
        let mut framed = Vec::new();
        write_frame(&mut framed, b"checksummed payload");
        for i in WIRE_HEADER_BYTES..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0xFF;
            match read_frame(&mut bad.as_slice()) {
                Err(WireError::BadChecksum) => {}
                other => panic!("flip at {i}: expected BadChecksum, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut framed = Vec::new();
        write_frame(&mut framed, b"x");
        framed[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        match read_frame(&mut framed.as_slice()) {
            Err(WireError::TooLarge(n)) => assert_eq!(n, u32::MAX),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn request_response_envelopes() {
        let req = encode_request(42, OpCode::Offsets, b"pay");
        let body = read_frame(&mut req.as_slice()).unwrap();
        let mut r = Reader::new(body.clone());
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(OpCode::from_u8(r.u8().unwrap()), Some(OpCode::Offsets));
        assert_eq!(r.take(3).unwrap(), b"pay");

        let ok = encode_response(42, Ok(b"result"));
        let body = read_frame(&mut ok.as_slice()).unwrap();
        let mut r = Reader::new(body);
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(r.u8().unwrap(), STATUS_OK);

        let err = encode_response(7, Err("duplicate batch"));
        let body = read_frame(&mut err.as_slice()).unwrap();
        let mut r = Reader::new(body);
        assert_eq!(r.u64().unwrap(), 7);
        assert_eq!(r.u8().unwrap(), STATUS_ERR);
        assert_eq!(r.str().unwrap(), "duplicate batch");
    }

    #[test]
    fn into_encoders_recycle_a_buffer_and_match_allocating_forms() {
        let mut scratch = vec![0xEEu8; 64]; // stale content must not leak through
        encode_request_into(&mut scratch, 42, OpCode::Offsets, b"pay");
        assert_eq!(scratch, encode_request(42, OpCode::Offsets, b"pay"));
        encode_response_into(&mut scratch, 7, Ok(b"result"));
        assert_eq!(scratch, encode_response(7, Ok(b"result")));
        encode_response_into(&mut scratch, 9, Err("duplicate batch"));
        assert_eq!(scratch, encode_response(9, Err("duplicate batch")));

        // The begin/put/finish form composes with the payload writers.
        begin_response(&mut scratch, 11);
        put_bool(&mut scratch, true);
        finish_frame(&mut scratch);
        let mut payload = Vec::new();
        put_bool(&mut payload, true);
        assert_eq!(scratch, encode_response(11, Ok(&payload)));
    }

    #[test]
    fn fetch_response_chunks_match_contiguous_encoding() {
        let recs = vec![
            Record::with_key(vec![1], vec![2u8; 10]).header("fmt", b"raw"),
            Record::new(vec![7u8; SHARED_CHUNK_MIN + 100]),
            Record::new(vec![3u8; 5]),
            Record::new(vec![9u8; SHARED_CHUNK_MIN]), // boundary: shared
        ];
        let mut payload = Vec::new();
        put_records(
            &mut payload,
            recs.iter().enumerate().map(|(i, r)| (i as u64 + 3, r)),
        );
        let contiguous = encode_response(5, Ok(&payload));

        let chunks = encode_fetch_response_chunks(
            vec![0xEE; 32], // recycled scratch with stale content
            5,
            recs.iter().enumerate().map(|(i, r)| (i as u64 + 3, r)),
        );
        let mut flat = Vec::new();
        for c in &chunks {
            flat.extend_from_slice(c.as_slice());
        }
        assert_eq!(flat, contiguous);

        // Large values ride as zero-copy slices of the records' own
        // buffers — never copied into a response buffer.
        let shared: Vec<&Bytes> = chunks
            .iter()
            .filter_map(|c| match c {
                Chunk::Shared(b) => Some(b),
                Chunk::Owned(_) => None,
            })
            .collect();
        assert_eq!(shared.len(), 2);
        assert!(Bytes::ptr_eq(shared[0], &recs[1].value));
        assert!(Bytes::ptr_eq(shared[1], &recs[3].value));

        // And the reassembled frame still decodes like any other.
        let body = read_frame(&mut flat.as_slice()).unwrap();
        let mut r = Reader::new(body);
        assert_eq!(r.u64().unwrap(), 5);
        assert_eq!(r.u8().unwrap(), STATUS_OK);
        let got = r.records().unwrap();
        assert_eq!(got.len(), recs.len());
        for (i, (off, rec)) in got.iter().enumerate() {
            assert_eq!(*off, i as u64 + 3);
            assert_eq!(rec, &recs[i]);
        }
    }

    #[test]
    fn fetch_response_chunks_empty_and_all_large() {
        // Zero records: one owned chunk, identical to the contiguous form.
        let chunks =
            encode_fetch_response_chunks(Vec::new(), 1, std::iter::empty::<(u64, &Record)>());
        assert_eq!(chunks.len(), 1);
        let mut payload = Vec::new();
        put_u32(&mut payload, 0);
        assert_eq!(chunks[0].as_slice(), encode_response(1, Ok(&payload)));

        // A trailing large value leaves no dangling empty owned chunk.
        let recs = [Record::new(vec![4u8; SHARED_CHUNK_MIN * 2])];
        let chunks = encode_fetch_response_chunks(
            Vec::new(),
            2,
            recs.iter().map(|r| (0u64, r)),
        );
        assert_eq!(chunks.len(), 2);
        assert!(matches!(chunks[1], Chunk::Shared(_)));
        let mut payload = Vec::new();
        put_records(&mut payload, recs.iter().map(|r| (0u64, r)));
        let mut flat = Vec::new();
        for c in &chunks {
            flat.extend_from_slice(c.as_slice());
        }
        assert_eq!(flat, encode_response(2, Ok(&payload)));
    }

    #[test]
    fn records_roundtrip_zero_copy() {
        let recs = vec![
            Record::with_key(vec![1, 2], vec![9u8; 100]).header("fmt", b"raw"),
            Record::new(vec![7u8; 50]),
        ];
        let mut payload = Vec::new();
        put_records(
            &mut payload,
            recs.iter().enumerate().map(|(i, r)| (i as u64 + 10, r)),
        );
        let buf = roundtrip_body(&payload);
        let mut r = Reader::new(buf.clone());
        let got = r.records().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 10);
        assert_eq!(got[1].0, 11);
        assert_eq!(got[0].1, recs[0]);
        assert_eq!(got[1].1, recs[1]);
        // Zero-copy: decoded payloads are slices of the received buffer.
        assert!(Bytes::ptr_eq(&got[0].1.value, &buf));
        assert!(Bytes::ptr_eq(got[0].1.key.as_ref().unwrap(), &buf));
        assert!(Bytes::ptr_eq(&got[1].1.value, &buf));
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn membership_and_scalars_roundtrip() {
        let m = GroupMembership {
            generation: 9,
            assigned: vec![("in".to_string(), 0), ("in".to_string(), 2)],
        };
        let mut out = Vec::new();
        put_membership(&mut out, &m);
        put_opt(&mut out, Some(&(3u64, 4u64)), |o, (a, b)| {
            put_u64(o, *a);
            put_u64(o, *b);
        });
        put_opt::<u64>(&mut out, None, |o, v| put_u64(o, *v));
        put_strings(&mut out, &["a".to_string(), "b".to_string()]);
        put_bool(&mut out, true);

        let mut r = Reader::new(Bytes::from_vec(out));
        assert_eq!(r.membership().unwrap(), m);
        assert_eq!(
            r.opt(|r| Ok((r.u64()?, r.u64()?))).unwrap(),
            Some((3u64, 4u64))
        );
        assert_eq!(r.opt(|r| r.u64()).unwrap(), None);
        assert_eq!(r.strings().unwrap(), vec!["a".to_string(), "b".to_string()]);
        assert!(r.bool().unwrap());
        assert_eq!(r.remaining(), 0);
        // Reading past the end is Truncated, never a panic.
        assert!(matches!(r.u8(), Err(WireError::Truncated)));
    }

    #[test]
    fn cluster_view_roundtrips() {
        let v = ClusterView {
            epoch: 7,
            brokers: vec![
                BrokerInfo { id: 0, addr: "10.0.0.1:9092".into(), alive: true },
                BrokerInfo { id: 1, addr: "10.0.0.2:9092".into(), alive: false },
            ],
        };
        let mut out = Vec::new();
        put_cluster_view(&mut out, &v);
        let mut r = Reader::new(roundtrip_body(&out));
        assert_eq!(r.cluster_view().unwrap(), v);
        assert_eq!(r.remaining(), 0);
        // The solo (empty-roster) view survives too.
        let mut out = Vec::new();
        put_cluster_view(&mut out, &ClusterView::solo());
        let mut r = Reader::new(roundtrip_body(&out));
        assert_eq!(r.cluster_view().unwrap(), ClusterView::solo());
    }

    #[test]
    fn assignor_mapping_roundtrips_and_rejects() {
        for a in [Assignor::Range, Assignor::RoundRobin] {
            assert_eq!(assignor_from_u8(assignor_to_u8(a)).unwrap(), a);
        }
        assert!(assignor_from_u8(9).is_err());
    }

    #[test]
    fn unknown_opcode_is_none() {
        assert_eq!(OpCode::from_u8(0), None);
        assert_eq!(OpCode::from_u8(200), None);
        assert_eq!(OpCode::from_u8(4), Some(OpCode::Produce));
    }
}
