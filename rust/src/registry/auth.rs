//! API keys, tenants and quotas — the credential model shared by the
//! REST control plane and the broker wire protocol.
//!
//! One [`AuthKeys`] table serves both planes: the REST router's auth
//! guard resolves `authorization: Bearer` tokens against it, and the
//! wire server resolves `Authenticate` frames against the same table,
//! so a key minted once works everywhere.
//!
//! * **Keys** map a secret token to a tenant (+ an `admin` bit). Token
//!   lookup is a constant-time sweep over the whole table — the compare
//!   never early-exits on a prefix match, so response timing leaks
//!   nothing about stored tokens.
//! * **Usage** is metered per key: requests served, records produced,
//!   bytes stored.
//! * **Quotas** are enforced per tenant (several keys may share one):
//!   a produce-rate **token bucket** (sustained records/second plus a
//!   configurable burst) and a stored-bytes ceiling, checked at produce
//!   time and at model/topic creation.
//! * **Expiry and rotation**: a key may carry an `expires_at` deadline;
//!   an expired key answers like a revoked one (403, not 401).
//!   [`AuthKeys::rotate`] mints a successor key for the same tenant and
//!   puts the old one on a grace-period countdown, so credentials roll
//!   without a hard cutover.
//!
//! The table persists through [`super::Store`]'s snapshot (`to_json` /
//! `restore_from_json`) and through a standalone keys file
//! (`serve --auth-keys`, managed by the `kafka-ml keys` subcommand) —
//! both carry the same JSON schema.

use crate::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// The tenant every request belongs to when authentication is off (the
/// single-process `pipeline` topology and all pre-auth snapshots).
pub const DEFAULT_TENANT: &str = "default";

/// Per-tenant resource limits. `None` = unlimited.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Quota {
    /// Sustained produce rate: the token bucket refills at this many
    /// records per second.
    pub records_per_sec: Option<u64>,
    /// Bucket capacity — the largest spike accepted at once. Defaults
    /// to `records_per_sec` when unset, i.e. at most one second of
    /// sustained rate in a burst.
    pub burst: Option<u64>,
    /// Ceiling on bytes durably stored for the tenant (broker records
    /// plus uploaded model blobs).
    pub stored_bytes: Option<u64>,
}

/// Per-key usage counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Usage {
    pub requests: u64,
    pub records_produced: u64,
    pub bytes_stored: u64,
}

/// The resolved identity behind an accepted credential.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Identity {
    pub token: String,
    pub tenant: String,
    /// Admin keys see every tenant's entities and manage keys.
    pub admin: bool,
}

impl Identity {
    /// The tenant scope for registry reads: admins are unscoped.
    pub fn scope(&self) -> Option<&str> {
        if self.admin {
            None
        } else {
            Some(&self.tenant)
        }
    }
}

/// Outcome of presenting a token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AuthOutcome {
    Accepted(Identity),
    /// Token matches no key — indistinguishable from a wrong key.
    Unknown,
    /// Token matches a key that has been revoked.
    Revoked,
    /// Token matches a key whose `expires_at` deadline has passed —
    /// answered like revocation (403, the caller proved possession).
    Expired,
}

/// A key row as reported by [`AuthKeys::list`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KeyInfo {
    pub token: String,
    pub tenant: String,
    pub admin: bool,
    pub revoked: bool,
    /// Unix-seconds deadline after which the key stops authenticating.
    pub expires_at: Option<u64>,
    pub usage: Usage,
}

#[derive(Debug, Clone)]
struct KeyState {
    tenant: String,
    admin: bool,
    revoked: bool,
    /// Unix seconds (wall clock, so deadlines survive restarts).
    expires_at: Option<u64>,
    usage: Usage,
}

#[derive(Debug, Default)]
struct TenantState {
    quota: Quota,
    /// Bytes currently charged against `quota.stored_bytes`.
    stored_bytes: u64,
    /// Token-bucket produce-rate state (not persisted). `None` refill
    /// instant means the bucket has never been touched since the quota
    /// was (re)set — the next charge starts from a full bucket.
    bucket_tokens: f64,
    bucket_refilled: Option<Instant>,
}

#[derive(Debug, Default)]
struct AuthState {
    /// token -> key. The BTreeMap key doubles as the secret; lookups
    /// never use `get` — see [`AuthKeys::authenticate`].
    keys: BTreeMap<String, KeyState>,
    tenants: BTreeMap<String, TenantState>,
}

/// The shared key/tenant/quota table. Cheap to `Arc` across the REST
/// router, the wire server and the registry store.
#[derive(Debug, Default)]
pub struct AuthKeys {
    /// When false (the default), every request runs unauthenticated as
    /// an unscoped admin — the single-process topology needs no keys.
    require: AtomicBool,
    state: Mutex<AuthState>,
}

/// Constant-time byte-string equality: compares every position of the
/// longer input regardless of where the first mismatch sits, and folds
/// the length difference into the verdict.
pub fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    let n = a.len().max(b.len());
    let mut diff = (a.len() ^ b.len()) as u8;
    for i in 0..n {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        diff |= x ^ y;
    }
    diff == 0
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mint a fresh token: 128 bits from a splitmix64 stream seeded by
/// wall-clock nanos, pid and a process-wide counter. Not a CSPRNG — an
/// operator who wants externally generated secrets puts them in the
/// keys file directly; this covers the common "mint me a key" path
/// with tokens that never repeat within a deployment.
fn generate_token() -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut seed = nanos
        ^ (u64::from(std::process::id())).rotate_left(32)
        ^ COUNTER
            .fetch_add(1, Ordering::Relaxed)
            .wrapping_mul(0xA24B_AED4_963E_E407);
    let (a, b) = (splitmix64(&mut seed), splitmix64(&mut seed));
    format!("kml_{a:016x}{b:016x}")
}

/// Seconds since the Unix epoch — the clock `expires_at` deadlines are
/// expressed on.
fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

impl AuthKeys {
    pub fn new() -> AuthKeys {
        AuthKeys::default()
    }

    /// Is authentication enforced? When false every caller is an
    /// unscoped admin.
    pub fn require_auth(&self) -> bool {
        self.require.load(Ordering::Acquire)
    }

    pub fn set_require(&self, require: bool) {
        self.require.store(require, Ordering::Release);
    }

    /// Mint and register a fresh key for `tenant`.
    pub fn create_key(&self, tenant: &str, admin: bool) -> Result<String> {
        if tenant.is_empty() {
            bail!("tenant name must not be empty");
        }
        let token = generate_token();
        self.insert_key(&token, tenant, admin)?;
        Ok(token)
    }

    /// Register an externally supplied token (keys-file load).
    pub fn insert_key(&self, token: &str, tenant: &str, admin: bool) -> Result<()> {
        self.insert_key_with(token, tenant, admin, None)
    }

    /// [`AuthKeys::insert_key`] with an explicit expiry deadline
    /// (unix seconds; `None` = never expires).
    pub fn insert_key_with(
        &self,
        token: &str,
        tenant: &str,
        admin: bool,
        expires_at: Option<u64>,
    ) -> Result<()> {
        if token.is_empty() || tenant.is_empty() {
            bail!("token and tenant must not be empty");
        }
        let mut st = self.state.lock().unwrap();
        if st.keys.contains_key(token) {
            bail!("key already exists");
        }
        st.keys.insert(
            token.to_string(),
            KeyState {
                tenant: tenant.to_string(),
                admin,
                revoked: false,
                expires_at,
                usage: Usage::default(),
            },
        );
        st.tenants.entry(tenant.to_string()).or_default();
        Ok(())
    }

    /// Rotate a key: mint a successor with the same tenant and admin
    /// bit, and put the old key on a `grace_secs` expiry countdown so
    /// in-flight deployments can switch over without a hard cutover.
    /// With `grace_secs == 0` the old key stops working immediately.
    pub fn rotate(&self, token: &str, grace_secs: u64) -> Result<String> {
        let successor = generate_token();
        let mut st = self.state.lock().unwrap();
        let Some(k) = st.keys.get(token) else {
            bail!("no such key");
        };
        if k.revoked {
            bail!("key is revoked");
        }
        if k.expires_at.is_some_and(|deadline| unix_now() >= deadline) {
            bail!("key is expired");
        }
        let (tenant, admin) = (k.tenant.clone(), k.admin);
        st.keys.insert(
            successor.clone(),
            KeyState {
                tenant,
                admin,
                revoked: false,
                expires_at: None,
                usage: Usage::default(),
            },
        );
        let old = st.keys.get_mut(token).expect("checked above");
        old.expires_at = Some(unix_now().saturating_add(grace_secs));
        Ok(successor)
    }

    /// Revoke a key. Returns false when no such key exists. The row is
    /// kept (revoked) so its usage history — and the 403-vs-401
    /// distinction — survive.
    pub fn revoke(&self, token: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.keys.get_mut(token) {
            Some(k) => {
                k.revoked = true;
                true
            }
            None => false,
        }
    }

    pub fn list(&self) -> Vec<KeyInfo> {
        let st = self.state.lock().unwrap();
        st.keys
            .iter()
            .map(|(token, k)| KeyInfo {
                token: token.clone(),
                tenant: k.tenant.clone(),
                admin: k.admin,
                revoked: k.revoked,
                expires_at: k.expires_at,
                usage: k.usage,
            })
            .collect()
    }

    /// Resolve a presented token. Sweeps the whole table with a
    /// constant-time compare per entry (no early exit on a match), so
    /// timing reveals only the table size, and meters the matched key's
    /// request counter.
    pub fn authenticate(&self, token: &str) -> AuthOutcome {
        let mut st = self.state.lock().unwrap();
        let mut matched: Option<String> = None;
        for stored in st.keys.keys() {
            if constant_time_eq(stored.as_bytes(), token.as_bytes()) && matched.is_none() {
                matched = Some(stored.clone());
            }
        }
        let Some(stored) = matched else {
            return AuthOutcome::Unknown;
        };
        let k = st.keys.get_mut(&stored).expect("matched key exists");
        if k.revoked {
            return AuthOutcome::Revoked;
        }
        if k.expires_at.is_some_and(|deadline| unix_now() >= deadline) {
            return AuthOutcome::Expired;
        }
        k.usage.requests += 1;
        AuthOutcome::Accepted(Identity {
            token: stored,
            tenant: k.tenant.clone(),
            admin: k.admin,
        })
    }

    /// Set (or clear fields of) a tenant's quota. Resets the rate
    /// bucket so the new rate/burst take effect from a full bucket.
    pub fn set_quota(&self, tenant: &str, quota: Quota) {
        let mut st = self.state.lock().unwrap();
        let t = st.tenants.entry(tenant.to_string()).or_default();
        t.quota = quota;
        t.bucket_refilled = None;
    }

    pub fn quota(&self, tenant: &str) -> Quota {
        let st = self.state.lock().unwrap();
        st.tenants.get(tenant).map(|t| t.quota.clone()).unwrap_or_default()
    }

    /// Charge a produce of `records` records / `bytes` bytes against
    /// `identity`'s tenant. `Err("quota")` when either the rate bucket
    /// or the stored-bytes ceiling would be breached — nothing is
    /// charged or metered on rejection.
    ///
    /// Rate limiting is a token bucket: the bucket holds up to
    /// `burst` tokens (default: one second of `records_per_sec`),
    /// refills continuously at `records_per_sec`, and a produce of N
    /// records spends N tokens or rejects whole.
    pub fn charge_produce(
        &self,
        identity: &Identity,
        records: u64,
        bytes: u64,
    ) -> std::result::Result<(), &'static str> {
        self.charge_produce_at(identity, records, bytes, Instant::now())
    }

    /// [`AuthKeys::charge_produce`] with an explicit clock, so the
    /// refill math is unit-testable without sleeping.
    fn charge_produce_at(
        &self,
        identity: &Identity,
        records: u64,
        bytes: u64,
        now: Instant,
    ) -> std::result::Result<(), &'static str> {
        let mut st = self.state.lock().unwrap();
        let tenant = st.tenants.entry(identity.tenant.clone()).or_default();
        let rate = tenant.quota.records_per_sec;
        if let Some(rate) = rate {
            let burst = tenant.quota.burst.unwrap_or(rate).max(1) as f64;
            tenant.bucket_tokens = match tenant.bucket_refilled {
                // First charge since the quota was (re)set: full bucket.
                None => burst,
                Some(then) => {
                    let dt = now.saturating_duration_since(then).as_secs_f64();
                    (tenant.bucket_tokens + dt * rate as f64).min(burst)
                }
            };
            tenant.bucket_refilled = Some(now);
            // The epsilon keeps exact-fit spends (refill computed 5.0,
            // spend 5) from rejecting on float rounding.
            if tenant.bucket_tokens + 1e-9 < records as f64 {
                return Err("quota");
            }
        }
        if let Some(limit) = tenant.quota.stored_bytes {
            if tenant.stored_bytes.saturating_add(bytes) > limit {
                return Err("quota");
            }
        }
        if rate.is_some() {
            tenant.bucket_tokens -= records as f64;
        }
        tenant.stored_bytes += bytes;
        if let Some(k) = st.keys.get_mut(&identity.token) {
            k.usage.records_produced += records;
            k.usage.bytes_stored += bytes;
        }
        Ok(())
    }

    /// Charge `bytes` of durable storage (model blob upload) against
    /// `identity`'s tenant. Same rejection contract as
    /// [`AuthKeys::charge_produce`].
    pub fn charge_stored(
        &self,
        identity: &Identity,
        bytes: u64,
    ) -> std::result::Result<(), &'static str> {
        let mut st = self.state.lock().unwrap();
        let tenant = st.tenants.entry(identity.tenant.clone()).or_default();
        if let Some(limit) = tenant.quota.stored_bytes {
            if tenant.stored_bytes.saturating_add(bytes) > limit {
                return Err("quota");
            }
        }
        tenant.stored_bytes += bytes;
        if let Some(k) = st.keys.get_mut(&identity.token) {
            k.usage.bytes_stored += bytes;
        }
        Ok(())
    }

    /// Is the tenant already at (or past) its stored-bytes ceiling?
    /// Creation of new storage-bearing resources (topics, models) is
    /// refused once the ceiling is reached.
    pub fn storage_exhausted(&self, identity: &Identity) -> bool {
        let st = self.state.lock().unwrap();
        match st.tenants.get(&identity.tenant) {
            Some(t) => match t.quota.stored_bytes {
                Some(limit) => t.stored_bytes >= limit,
                None => false,
            },
            None => false,
        }
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let keys = st
            .keys
            .iter()
            .map(|(token, k)| {
                let mut fields = vec![
                    ("token", Json::str(token)),
                    ("tenant", Json::str(&k.tenant)),
                    ("admin", Json::Bool(k.admin)),
                    ("revoked", Json::Bool(k.revoked)),
                ];
                if let Some(deadline) = k.expires_at {
                    fields.push(("expires_at", Json::from(deadline)));
                }
                fields.push((
                    "usage",
                    Json::obj(vec![
                        ("requests", Json::from(k.usage.requests)),
                        ("records_produced", Json::from(k.usage.records_produced)),
                        ("bytes_stored", Json::from(k.usage.bytes_stored)),
                    ]),
                ));
                Json::obj(fields)
            })
            .collect();
        let tenants = st
            .tenants
            .iter()
            .map(|(name, t)| {
                let mut fields = vec![
                    ("name", Json::str(name)),
                    ("stored_bytes", Json::from(t.stored_bytes)),
                ];
                if let Some(rps) = t.quota.records_per_sec {
                    fields.push(("records_per_sec", Json::from(rps)));
                }
                if let Some(burst) = t.quota.burst {
                    fields.push(("burst", Json::from(burst)));
                }
                if let Some(sb) = t.quota.stored_bytes {
                    fields.push(("quota_stored_bytes", Json::from(sb)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("require", Json::Bool(self.require_auth())),
            ("keys", Json::arr(keys)),
            ("tenants", Json::arr(tenants)),
        ])
    }

    /// Replace the whole table from a snapshot produced by
    /// [`AuthKeys::to_json`]. Rate buckets restart full.
    pub fn restore_from_json(&self, j: &Json) -> Result<()> {
        let mut next = AuthState::default();
        for k in j.get("keys").as_arr().unwrap_or(&[]) {
            let token = k.req_str("token")?.to_string();
            let usage = k.get("usage");
            next.keys.insert(
                token,
                KeyState {
                    tenant: k.req_str("tenant")?.to_string(),
                    admin: k.get("admin").as_bool().unwrap_or(false),
                    revoked: k.get("revoked").as_bool().unwrap_or(false),
                    expires_at: k.get("expires_at").as_u64(),
                    usage: Usage {
                        requests: usage.get("requests").as_u64().unwrap_or(0),
                        records_produced: usage.get("records_produced").as_u64().unwrap_or(0),
                        bytes_stored: usage.get("bytes_stored").as_u64().unwrap_or(0),
                    },
                },
            );
        }
        for t in j.get("tenants").as_arr().unwrap_or(&[]) {
            let name = t.req_str("name")?.to_string();
            next.tenants.insert(
                name,
                TenantState {
                    quota: Quota {
                        records_per_sec: t.get("records_per_sec").as_u64(),
                        burst: t.get("burst").as_u64(),
                        stored_bytes: t.get("quota_stored_bytes").as_u64(),
                    },
                    stored_bytes: t.get("stored_bytes").as_u64().unwrap_or(0),
                    bucket_tokens: 0.0,
                    bucket_refilled: None,
                },
            );
        }
        // Every key's tenant must have a row even if the snapshot
        // omitted it.
        let tenants_of_keys: Vec<String> = next.keys.values().map(|k| k.tenant.clone()).collect();
        for t in tenants_of_keys {
            next.tenants.entry(t).or_default();
        }
        if let Some(require) = j.get("require").as_bool() {
            self.set_require(require);
        }
        *self.state.lock().unwrap() = next;
        Ok(())
    }

    /// Load a keys file written by [`AuthKeys::save_file`] (or by hand:
    /// the same JSON schema as the store snapshot's `auth` section).
    pub fn load_file(&self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading keys file {path}: {e}"))?;
        let j = crate::json::parse(&text).map_err(|e| anyhow!("parsing keys file {path}: {e}"))?;
        self.restore_from_json(&j)
    }

    pub fn save_file(&self, path: &str) -> Result<()> {
        let text = crate::json::to_string_pretty(&self.to_json());
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, text).map_err(|e| anyhow!("writing keys file {tmp}: {e}"))?;
        std::fs::rename(&tmp, path).map_err(|e| anyhow!("renaming keys file into {path}: {e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn identity(auth: &AuthKeys, token: &str) -> Identity {
        match auth.authenticate(token) {
            AuthOutcome::Accepted(id) => id,
            other => panic!("expected acceptance, got {other:?}"),
        }
    }

    #[test]
    fn constant_time_eq_semantics() {
        assert!(constant_time_eq(b"abc", b"abc"));
        assert!(!constant_time_eq(b"abc", b"abd"));
        assert!(!constant_time_eq(b"abc", b"ab"));
        assert!(!constant_time_eq(b"", b"x"));
        assert!(constant_time_eq(b"", b""));
    }

    #[test]
    fn create_authenticate_revoke_cycle() {
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        assert!(token.starts_with("kml_"));
        let id = identity(&auth, &token);
        assert_eq!(id.tenant, "acme");
        assert!(!id.admin);
        assert_eq!(id.scope(), Some("acme"));
        assert_eq!(auth.authenticate("kml_bogus"), AuthOutcome::Unknown);
        assert!(auth.revoke(&token));
        assert_eq!(auth.authenticate(&token), AuthOutcome::Revoked);
        assert!(!auth.revoke("kml_bogus"));
        // The revoked row survives in the listing.
        let rows = auth.list();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].revoked);
    }

    #[test]
    fn admin_scope_is_unscoped() {
        let auth = AuthKeys::new();
        let token = auth.create_key("platform", true).unwrap();
        assert_eq!(identity(&auth, &token).scope(), None);
    }

    #[test]
    fn tokens_are_unique() {
        let auth = AuthKeys::new();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..100 {
            assert!(seen.insert(auth.create_key("t", false).unwrap()));
        }
    }

    #[test]
    fn request_metering_counts_authentications() {
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        for _ in 0..3 {
            identity(&auth, &token);
        }
        assert_eq!(auth.list()[0].usage.requests, 3);
    }

    #[test]
    fn produce_rate_quota_enforced_per_window() {
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        auth.set_quota("acme", Quota { records_per_sec: Some(10), ..Quota::default() });
        let id = identity(&auth, &token);
        assert!(auth.charge_produce(&id, 8, 100).is_ok());
        assert!(auth.charge_produce(&id, 2, 100).is_ok());
        // Window exhausted: the 11th record in the same second rejects.
        assert_eq!(auth.charge_produce(&id, 1, 1), Err("quota"));
        // Rejection charges nothing: usage reflects the accepted 10.
        assert_eq!(auth.list()[0].usage.records_produced, 10);
        assert_eq!(auth.list()[0].usage.bytes_stored, 200);
    }

    #[test]
    fn stored_bytes_quota_enforced() {
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        auth.set_quota("acme", Quota { stored_bytes: Some(1000), ..Quota::default() });
        let id = identity(&auth, &token);
        assert!(!auth.storage_exhausted(&id));
        assert!(auth.charge_stored(&id, 900).is_ok());
        assert_eq!(auth.charge_stored(&id, 200), Err("quota"));
        assert!(auth.charge_stored(&id, 100).is_ok());
        assert!(auth.storage_exhausted(&id));
        assert_eq!(auth.charge_produce(&id, 1, 1), Err("quota"));
    }

    #[test]
    fn other_tenant_unaffected_by_quota_breach() {
        let auth = AuthKeys::new();
        let capped = auth.create_key("capped", false).unwrap();
        let free = auth.create_key("free", false).unwrap();
        auth.set_quota("capped", Quota { records_per_sec: Some(1), ..Quota::default() });
        let capped_id = identity(&auth, &capped);
        let free_id = identity(&auth, &free);
        assert!(auth.charge_produce(&capped_id, 1, 10).is_ok());
        assert_eq!(auth.charge_produce(&capped_id, 1, 10), Err("quota"));
        for _ in 0..100 {
            assert!(auth.charge_produce(&free_id, 1, 10).is_ok());
        }
    }

    #[test]
    fn snapshot_roundtrip_preserves_keys_quotas_usage() {
        let auth = AuthKeys::new();
        auth.set_require(true);
        let a = auth.create_key("acme", false).unwrap();
        let b = auth.create_key("platform", true).unwrap();
        auth.set_quota("acme", Quota { records_per_sec: Some(5), stored_bytes: Some(4096), ..Quota::default() });
        let id = identity(&auth, &a);
        auth.charge_produce(&id, 3, 300).unwrap();
        auth.revoke(&b);

        let snap = auth.to_json();
        let restored = AuthKeys::new();
        restored.restore_from_json(&snap).unwrap();
        assert!(restored.require_auth());
        assert_eq!(restored.list(), auth.list());
        assert_eq!(
            restored.quota("acme"),
            Quota { records_per_sec: Some(5), stored_bytes: Some(4096), ..Quota::default() }
        );
        assert_eq!(restored.authenticate(&b), AuthOutcome::Revoked);
        // Stored-bytes accounting survives: 300 of 4096 used, so a
        // 3900-byte upload must reject on the restored table too.
        let rid = match restored.authenticate(&a) {
            AuthOutcome::Accepted(id) => id,
            other => panic!("{other:?}"),
        };
        assert_eq!(restored.charge_stored(&rid, 3900), Err("quota"));
        assert!(restored.charge_stored(&rid, 3700).is_ok());
    }

    #[test]
    fn duplicate_and_empty_keys_rejected() {
        let auth = AuthKeys::new();
        auth.insert_key("tok", "t", false).unwrap();
        assert!(auth.insert_key("tok", "t2", false).is_err());
        assert!(auth.insert_key("", "t", false).is_err());
        assert!(auth.insert_key("x", "", false).is_err());
        assert!(auth.create_key("", false).is_err());
    }

    #[test]
    fn token_bucket_burst_and_refill() {
        use std::time::Duration;
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        auth.set_quota(
            "acme",
            Quota { records_per_sec: Some(10), burst: Some(20), stored_bytes: None },
        );
        let id = identity(&auth, &token);
        let t0 = Instant::now();
        // The bucket starts full at the burst size...
        assert!(auth.charge_produce_at(&id, 20, 0, t0).is_ok());
        // ...and once drained, the same instant has no tokens left.
        assert_eq!(auth.charge_produce_at(&id, 1, 0, t0), Err("quota"));
        // 500 ms at 10 records/s refills exactly 5 tokens.
        let t1 = t0 + Duration::from_millis(500);
        assert!(auth.charge_produce_at(&id, 5, 0, t1).is_ok());
        assert_eq!(auth.charge_produce_at(&id, 1, 0, t1), Err("quota"));
        // A long idle stretch caps at the burst, not rate × elapsed.
        let t2 = t1 + Duration::from_secs(3600);
        assert_eq!(auth.charge_produce_at(&id, 21, 0, t2), Err("quota"));
        assert!(auth.charge_produce_at(&id, 20, 0, t2).is_ok());
        // Rejections charged nothing; the three accepted spends did.
        assert_eq!(auth.list()[0].usage.records_produced, 45);
    }

    #[test]
    fn token_bucket_burst_defaults_to_rate() {
        use std::time::Duration;
        let auth = AuthKeys::new();
        let token = auth.create_key("acme", false).unwrap();
        auth.set_quota("acme", Quota { records_per_sec: Some(10), ..Quota::default() });
        let id = identity(&auth, &token);
        let t0 = Instant::now();
        assert!(auth.charge_produce_at(&id, 10, 0, t0).is_ok());
        assert_eq!(auth.charge_produce_at(&id, 1, 0, t0), Err("quota"));
        // 100 ms refills one token at 10/s.
        let t1 = t0 + Duration::from_millis(100);
        assert!(auth.charge_produce_at(&id, 1, 0, t1).is_ok());
        assert_eq!(auth.charge_produce_at(&id, 1, 0, t1), Err("quota"));
    }

    #[test]
    fn expired_key_answers_expired() {
        let auth = AuthKeys::new();
        auth.insert_key_with("tok", "acme", false, Some(0)).unwrap();
        assert_eq!(auth.authenticate("tok"), AuthOutcome::Expired);
        // A future deadline still authenticates, and the deadline shows
        // up in the listing.
        auth.insert_key_with("tok2", "acme", false, Some(unix_now() + 3600)).unwrap();
        assert!(matches!(auth.authenticate("tok2"), AuthOutcome::Accepted(_)));
        assert_eq!(auth.list()[0].expires_at, Some(0));
    }

    #[test]
    fn rotate_mints_successor_and_expires_the_old_key() {
        let auth = AuthKeys::new();
        let old = auth.create_key("acme", true).unwrap();
        let new = auth.rotate(&old, 0).unwrap();
        assert_ne!(old, new);
        // Grace 0: the old key dies right away; the successor works and
        // inherits tenant + admin.
        assert_eq!(auth.authenticate(&old), AuthOutcome::Expired);
        let id = identity(&auth, &new);
        assert_eq!(id.tenant, "acme");
        assert!(id.admin);
        // A real grace period keeps the old key alive for now.
        let newer = auth.rotate(&new, 3600).unwrap();
        assert!(matches!(auth.authenticate(&new), AuthOutcome::Accepted(_)));
        identity(&auth, &newer);
        // Unknown, revoked and expired keys refuse to rotate.
        assert!(auth.rotate("kml_bogus", 0).is_err());
        assert!(auth.rotate(&old, 0).is_err());
        auth.revoke(&newer);
        assert!(auth.rotate(&newer, 0).is_err());
    }

    #[test]
    fn expiry_survives_snapshot_roundtrip() {
        let auth = AuthKeys::new();
        auth.insert_key_with("tok", "acme", false, Some(12345)).unwrap();
        auth.set_quota(
            "acme",
            Quota { records_per_sec: Some(9), burst: Some(42), stored_bytes: None },
        );
        let restored = AuthKeys::new();
        restored.restore_from_json(&auth.to_json()).unwrap();
        assert_eq!(restored.list()[0].expires_at, Some(12345));
        assert_eq!(restored.quota("acme").burst, Some(42));
    }

    #[test]
    fn keys_file_roundtrip() {
        let auth = AuthKeys::new();
        auth.set_require(true);
        auth.create_key("acme", false).unwrap();
        auth.set_quota("acme", Quota { records_per_sec: Some(7), ..Quota::default() });
        let path = std::env::temp_dir().join(format!(
            "kafka-ml-keys-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_string_lossy().to_string();
        auth.save_file(&path).unwrap();
        let loaded = AuthKeys::new();
        loaded.load_file(&path).unwrap();
        assert_eq!(loaded.list(), auth.list());
        assert_eq!(loaded.quota("acme").records_per_sec, Some(7));
        assert!(loaded.require_auth());
        let _ = std::fs::remove_file(&path);
    }
}
