//! Execution substrate: thread pool, bounded channels with backpressure,
//! and cancellation tokens.
//!
//! The offline vendor set has no tokio, so the event loops Kafka-ML needs
//! (broker request handling, orchestrator reconciliation, training jobs,
//! inference replicas, the REST server) run on this std-only substrate.

mod cancel;
mod channel;
mod pool;

pub use cancel::CancelToken;
pub use channel::{bounded, unbounded, Receiver, RecvError, SendError, Sender, TryRecvError};
pub use pool::ThreadPool;
