//! Metrics substrate: counters, gauges, histograms and a latency
//! recorder, exported as plain text (Prometheus-ish exposition).
//!
//! Every Kafka-ML component (broker, orchestrator, training jobs,
//! inference replicas, REST server) reports here; the benches read the
//! same numbers the paper reports in its Tables.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge that can move both ways.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency/size histogram with fixed log-spaced buckets (µs domain for
/// durations) plus exact count/sum and streaming min/max.
#[derive(Debug)]
pub struct Histogram {
    /// Bucket upper bounds in µs (last = +inf).
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    min_us: AtomicU64,
    max_us: AtomicU64,
    /// Reservoir of raw samples for exact quantiles in benches.
    samples: Mutex<Vec<u64>>,
    max_samples: usize,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 1µs .. ~17min, ×2 per bucket.
        let bounds: Vec<u64> = (0..30).map(|i| 1u64 << i).collect();
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds,
            counts,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
            samples: Mutex::new(Vec::new()),
            max_samples: 100_000,
        }
    }

    pub fn observe(&self, d: Duration) {
        self.observe_us(d.as_micros() as u64)
    }

    pub fn observe_us(&self, us: u64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        let mut s = self.samples.lock().unwrap();
        if s.len() < self.max_samples {
            s.push(us);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> Duration {
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn min(&self) -> Duration {
        let v = self.min_us.load(Ordering::Relaxed);
        Duration::from_micros(if v == u64::MAX { 0 } else { v })
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Exact quantile over the retained sample reservoir (q in [0,1]).
    pub fn quantile(&self, q: f64) -> Duration {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return Duration::ZERO;
        }
        s.sort_unstable();
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        Duration::from_micros(s[idx])
    }
}

/// A named registry of metrics, shareable across components.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Plain-text exposition of everything (stable order).
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", c.get()));
        }
        for (k, g) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {k} {}\n", g.get()));
        }
        for (k, h) in self.inner.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "histogram {k} count={} mean_us={} p50_us={} p99_us={} max_us={}\n",
                h.count(),
                h.mean().as_micros(),
                h.quantile(0.5).as_micros(),
                h.quantile(0.99).as_micros(),
                h.max().as_micros(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("msgs").add(5);
        r.counter("msgs").inc();
        assert_eq!(r.counter("msgs").get(), 6);
        r.gauge("depth").set(4);
        r.gauge("depth").add(-1);
        assert_eq!(r.gauge("depth").get(), 3);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for us in [100u64, 200, 300, 400, 500] {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean(), Duration::from_micros(300));
        assert_eq!(h.min(), Duration::from_micros(100));
        assert_eq!(h.max(), Duration::from_micros(500));
        assert_eq!(h.quantile(0.5), Duration::from_micros(300));
        assert_eq!(h.quantile(1.0), Duration::from_micros(500));
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn expose_contains_everything() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(2);
        r.histogram("c").observe_us(10);
        let text = r.expose();
        assert!(text.contains("counter a 1"));
        assert!(text.contains("gauge b 2"));
        assert!(text.contains("histogram c count=1"));
    }

    #[test]
    fn registry_clones_share_metrics() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }
}
