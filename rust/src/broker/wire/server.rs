//! `BrokerServer`: the broker as a TCP service, built on an event-loop
//! network core.
//!
//! One **reactor** thread owns every socket and multiplexes them
//! through a readiness poller ([`super::reactor::Poller`] — epoll on
//! Linux); a small fixed **worker pool** (`broker-io`) runs request
//! handlers, which may block on disk (produce, fetch) or on cluster
//! locks. Thread count is O(worker pool), not O(connections): ten
//! thousand idle consumers cost ten thousand fd registrations and
//! per-connection buffers, never ten thousand stacks.
//!
//! Per connection, two state machines driven by readiness events:
//!
//! * **read**: bytes accumulate in a per-connection buffer across
//!   readiness events until a full `len | crc | body` frame is present
//!   ([`super::codec`]); the frame body then ships to a worker.
//!   Requests on one connection stay strictly serial — while one is in
//!   flight the reactor parks that connection's read interest, so a
//!   fast client backpressures through TCP exactly as it did against
//!   the thread-per-connection server.
//! * **write**: response chunks queue per-connection and drain on
//!   writability via vectored writes ([`super::reactor::writev`]). A
//!   fetch response is a header chunk plus zero-copy
//!   [`Bytes`](crate::util::Bytes) slices of the broker log
//!   ([`codec::encode_fetch_response_chunks`]), so a large batch goes
//!   from log to socket without ever being copied into a contiguous
//!   response buffer. Plain responses are encoded into a recycled
//!   per-connection scratch buffer — no steady-state allocation.
//!
//! **Long-polls park as registrations, not threads.** A `FetchWait`
//! registers a [`Waiter`] with the cluster's wait-sets
//! ([`Cluster::register_data_wait`]) whose wake hook posts a reactor
//! wakeup through an eventfd ([`super::reactor::WakeFd`]); the
//! connection then sits in `Parked` state with a timer-heap entry for
//! its (group-liveness-capped) deadline. A produce wakes it in one
//! eventfd write + one response frame; an idle parked consumer costs
//! zero threads and zero CPU. The server's shutdown wait-set is an
//! extra wakeup source of every park, so stopping the server answers
//! all of them immediately.
//!
//! [`Cluster::register_data_wait`]: crate::broker::Cluster::register_data_wait
//! [`Waiter`]: crate::broker::notify::Waiter
//!
//! **Shutdown is deterministic**: the cancel token flips, one eventfd
//! write wakes the reactor, every parked long-poll is answered
//! (`woken = true`) and every socket closed, then the reactor and the
//! worker pool are joined — no dummy self-connect, no per-connection
//! thread sweep.
//!
//! **Corruption never propagates**: a frame that fails its length bound
//! or CRC, or an unreadable envelope, drops the connection; an unknown
//! opcode or malformed payload answers with an error response — the
//! broker state and its locks are untouched either way, because
//! decoding completes before any cluster call.

use super::codec::{self, Chunk, OpCode, Reader};
use super::reactor::{self, Poller, PollerEvent, WakeFd, MAX_WRITEV_SEGMENTS};
use crate::broker::cluster::{ClusterHandle, DataWaitGuard};
use crate::broker::log::format;
use crate::broker::net::ClientLocality;
use crate::broker::notify::{WaitSet, Waiter};
use crate::broker::record::Record;
use crate::broker::transport::BrokerTransport;
use crate::broker::TopicPartition;
use crate::exec::{CancelToken, ThreadPool};
use crate::util::bytes::Bytes;
use anyhow::{Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Hygiene ceiling on one `FetchWait` park — NOT a poll interval. A
/// parked connection wakes on data, rebalance, *or server shutdown*
/// (the shutdown wait-set is one of its wakeup sources), so the server
/// can honor the client's full long-poll deadline with zero polling on
/// the wire; this cap only bounds a wait whose client named an absurd
/// timeout.
pub const MAX_WAIT_SLICE: Duration = Duration::from_secs(600);

/// Idle connections are dropped after this long without a request; the
/// client pool reconnects transparently on its next call. Parked
/// long-polls and the metrics channel are exempt.
const IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// How often the reactor sweeps for idle connections.
const SWEEP_INTERVAL: Duration = Duration::from_secs(5);

/// Request handlers that may block (disk appends, segment loads,
/// cluster locks) run on this many `broker-io` threads by default.
pub const DEFAULT_IO_WORKERS: usize = 4;

/// Poller token of the accept socket.
const TOKEN_LISTENER: u64 = 0;
/// Poller token of the reactor's wake fd.
const TOKEN_WAKE: u64 = 1;
/// Connection ids count up from here and are never reused, so a stale
/// timer or event can never hit a different connection.
const FIRST_CONN_TOKEN: u64 = 2;

/// Reactor-owned read staging buffer: one per reactor, not per
/// connection, so ten thousand idle connections hold only their (tiny)
/// pending-frame buffers.
const READ_BUF_BYTES: usize = 64 * 1024;

/// An empty, fully-parsed connection buffer above this capacity is
/// released rather than kept hot (one huge produce should not pin 64
/// MiB to an otherwise idle connection).
const RBUF_KEEP_BYTES: usize = 256 * 1024;

/// State shared between the reactor, the worker pool and shutdown.
struct Shared {
    cluster: ClusterHandle,
    cancel: CancelToken,
    /// Notified once at shutdown: every parked long-poll registration
    /// wakes (its hook posts a reactor wakeup) and is answered.
    shutdown: Arc<WaitSet>,
    /// Events posted to the reactor by workers and waiter hooks;
    /// drained on every reactor wakeup.
    inbox: Mutex<Vec<Event>>,
    /// The reactor's wakeup fd. Lives here — not on the reactor thread —
    /// so a worker finishing after shutdown still writes to a live fd.
    wake: WakeFd,
}

impl Shared {
    fn post(&self, ev: Event) {
        self.inbox.lock().unwrap().push(ev);
        self.wake.wake();
    }
}

/// Messages from worker threads (and waiter wake hooks) to the reactor.
/// Workers never touch sockets; all socket I/O happens on the reactor.
enum Event {
    /// A request finished: queue these chunks and return the connection
    /// to `Idle`. An empty chunk list (or empty chunks) just completes
    /// the request cycle.
    Respond { conn: u64, chunks: Vec<Chunk> },
    /// A `FetchWait` found nothing ready: park the connection.
    Park { conn: u64, parked: Box<Parked> },
    /// A waiter wake hook fired for this connection's park.
    PollWake { conn: u64 },
    /// Protocol violation (bad CRC, unreadable envelope): drop the
    /// connection.
    Close { conn: u64 },
}

/// A parked `FetchWait`: everything needed to answer the long-poll
/// later. Dropping it deregisters the waiter from every wait-set (the
/// `guard`), so an abandoned park can never leak registrations.
struct Parked {
    corr: u64,
    assignments: Vec<(TopicPartition, u64)>,
    group: Option<(String, u64)>,
    /// Already capped by [`Cluster::register_data_wait`] for group
    /// liveness; the reactor's timer heap fires it.
    ///
    /// [`Cluster::register_data_wait`]: crate::broker::Cluster::register_data_wait
    deadline: Instant,
    waiter: Waiter,
    /// Generation snapshot taken after registration; a wake that raced
    /// the park has already moved it.
    seen: u64,
    guard: DataWaitGuard,
    /// The connection's scratch buffer rides along so the eventual
    /// response allocates nothing.
    scratch: Vec<u8>,
}

enum ConnState {
    /// Reading requests.
    Idle,
    /// One request is on the worker pool; read interest is off
    /// (TCP backpressure) until its `Respond` comes back.
    Busy,
    /// A `FetchWait` is registered with the cluster's wait-sets.
    Parked(Box<Parked>),
}

struct Conn {
    stream: TcpStream,
    peer: String,
    /// Partial-frame accumulation across readiness events.
    rbuf: Vec<u8>,
    /// Outgoing chunks; `front_written` bytes of the front chunk are
    /// already in the socket.
    out: VecDeque<Chunk>,
    front_written: usize,
    state: ConnState,
    metrics_channel: bool,
    eof: bool,
    last_activity: Instant,
    /// Interest currently registered with the poller.
    reg_read: bool,
    reg_write: bool,
    /// Recycled response scratch buffer (the codec encode path reuses
    /// it instead of allocating a fresh `Vec` per response frame).
    spare: Vec<u8>,
}

impl Conn {
    fn new(stream: TcpStream, peer: String) -> Conn {
        Conn {
            stream,
            peer,
            rbuf: Vec::new(),
            out: VecDeque::new(),
            front_written: 0,
            state: ConnState::Idle,
            metrics_channel: false,
            eof: false,
            last_activity: Instant::now(),
            reg_read: true,
            reg_write: false,
            spare: Vec::new(),
        }
    }
}

/// The broker's TCP front door. See the module docs.
pub struct BrokerServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    reactor: Option<std::thread::JoinHandle<()>>,
    workers: Option<Arc<ThreadPool>>,
}

impl BrokerServer {
    /// Bind `listen` (e.g. `127.0.0.1:9092`; port 0 = ephemeral) and
    /// serve `cluster` until [`BrokerServer::shutdown`], with
    /// [`DEFAULT_IO_WORKERS`] request workers.
    pub fn start(listen: &str, cluster: ClusterHandle) -> Result<BrokerServer> {
        BrokerServer::start_with(listen, cluster, DEFAULT_IO_WORKERS)
    }

    /// [`BrokerServer::start`] with an explicit worker-pool size (the
    /// `--io-workers` CLI flag). The pool bounds concurrent request
    /// *handling*; connection count is bounded only by fds.
    pub fn start_with(listen: &str, cluster: ClusterHandle, io_workers: usize) -> Result<BrokerServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding broker on {listen}"))?;
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let addr = listener.local_addr()?;
        let wake = WakeFd::new().context("creating reactor wake fd")?;
        let mut poller = Poller::new().context("creating readiness poller")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)
            .context("registering listener")?;
        poller
            .register(wake.raw(), TOKEN_WAKE, true, false)
            .context("registering wake fd")?;
        let shared = Arc::new(Shared {
            cluster,
            cancel: CancelToken::new(),
            shutdown: Arc::new(WaitSet::new()),
            inbox: Mutex::new(Vec::new()),
            wake,
        });
        let io_workers = io_workers.max(1);
        let workers = Arc::new(ThreadPool::new(io_workers, "broker-io"));
        let reactor = Reactor {
            shared: shared.clone(),
            workers: workers.clone(),
            listener,
            poller,
            conns: HashMap::new(),
            timers: BinaryHeap::new(),
            next_id: FIRST_CONN_TOKEN,
            read_buf: vec![0u8; READ_BUF_BYTES],
        };
        let handle = std::thread::Builder::new()
            .name("broker-reactor".to_string())
            .spawn(move || reactor.run())?;
        log::info!("broker wire protocol serving on {addr} (reactor + {io_workers} io workers)");
        Ok(BrokerServer { addr, shared, reactor: Some(handle), workers: Some(workers) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        let Some(handle) = self.reactor.take() else { return };
        self.shared.cancel.cancel();
        // Wake every parked long-poll registration (their hooks post
        // reactor wakeups) and the reactor itself; it answers the
        // parked connections and exits.
        self.shared.shutdown.notify_all();
        self.shared.wake.wake();
        handle.join().ok();
        // Drain in-flight request handlers: once the pool is joined, no
        // cluster call started by this server is still running. Late
        // posts from those handlers land in a dead inbox (the wake fd
        // stays alive inside `Shared`) and are simply dropped.
        if let Some(workers) = self.workers.take() {
            match Arc::try_unwrap(workers) {
                Ok(pool) => pool.shutdown(),
                Err(arc) => drop(arc), // last ref joins via Drop
            }
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---- the reactor -----------------------------------------------------------

struct Reactor {
    shared: Arc<Shared>,
    workers: Arc<ThreadPool>,
    listener: TcpListener,
    poller: Poller,
    conns: HashMap<u64, Conn>,
    /// `(deadline, conn)` min-heap for parked long-polls. Entries can
    /// go stale (the park completed early); firing one against a
    /// connection that is no longer parked is a no-op.
    timers: BinaryHeap<Reverse<(Instant, u64)>>,
    next_id: u64,
    read_buf: Vec<u8>,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<PollerEvent> = Vec::new();
        let mut next_sweep = Instant::now() + SWEEP_INTERVAL;
        loop {
            if self.shared.cancel.is_cancelled() {
                break;
            }
            let now = Instant::now();
            let mut wake_at = next_sweep;
            if let Some(&Reverse((t, _))) = self.timers.peek() {
                wake_at = wake_at.min(t);
            }
            let timeout = wake_at.saturating_duration_since(now);
            events.clear();
            if let Err(e) = self.poller.wait(&mut events, Some(timeout)) {
                log::warn!("broker reactor poll error: {e}");
            }
            if self.shared.cancel.is_cancelled() {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.shared.wake.drain(),
                    id => self.conn_ready(id, &ev),
                }
            }
            // Posts can land without the wake event racing into this
            // batch — always drain.
            self.drain_inbox();
            self.fire_timers();
            let now = Instant::now();
            if now >= next_sweep {
                self.sweep_idle(now);
                next_sweep = now + SWEEP_INTERVAL;
            }
        }
        self.shutdown_conns();
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nodelay(true).ok();
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let id = self.next_id;
                    self.next_id += 1;
                    if let Err(e) = self.poller.register(stream.as_raw_fd(), id, true, false) {
                        log::warn!("broker: registering {peer}: {e}");
                        continue;
                    }
                    self.conns.insert(id, Conn::new(stream, peer.to_string()));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("broker accept error: {e}");
                    return;
                }
            }
        }
    }

    fn conn_ready(&mut self, id: u64, ev: &PollerEvent) {
        if ev.writable {
            self.flush_conn(id);
        }
        let idle = match self.conns.get(&id) {
            Some(c) => matches!(c.state, ConnState::Idle),
            None => return, // closed earlier in this batch
        };
        if (ev.readable || ev.hangup) && idle {
            self.read_conn(id);
            self.parse_frames(id);
        } else if ev.hangup {
            // The client vanished while a request was in flight. A
            // parked long-poll is abandoned outright (its guard
            // deregisters); a busy one closes as soon as its response
            // cycle completes.
            match self.conns.get_mut(&id) {
                Some(c) if matches!(c.state, ConnState::Parked(_)) => {
                    self.close_conn(id);
                    return;
                }
                Some(c) => c.eof = true,
                None => return,
            }
        }
        self.finish_io(id);
    }

    /// Pull everything the socket has into the connection's frame
    /// buffer (via the reactor's one staging buffer).
    fn read_conn(&mut self, id: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            match conn.stream.read(&mut self.read_buf) {
                Ok(0) => {
                    conn.eof = true;
                    return;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&self.read_buf[..n]);
                    conn.last_activity = Instant::now();
                    if n < self.read_buf.len() {
                        return;
                    }
                    // A torrential sender must not starve the loop: one
                    // max-size frame buffered is enough for one round.
                    if conn.rbuf.len() > codec::MAX_FRAME_BYTES as usize {
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::debug!("broker: reading from {}: {e}", conn.peer);
                    self.close_conn(id);
                    return;
                }
            }
        }
    }

    /// Carve complete frames out of the connection buffer and dispatch
    /// them. Stops at the first non-one-way frame (serial requests).
    fn parse_frames(&mut self, id: u64) {
        enum Next {
            Frame { body: Bytes, crc: u32, metric: bool },
            Close,
            Done,
        }
        loop {
            let next = {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if !matches!(conn.state, ConnState::Idle)
                    || conn.rbuf.len() < codec::WIRE_HEADER_BYTES
                {
                    Next::Done
                } else {
                    let len = u32::from_le_bytes(conn.rbuf[0..4].try_into().unwrap());
                    let total = codec::WIRE_HEADER_BYTES + len as usize;
                    if len > codec::MAX_FRAME_BYTES {
                        log::debug!(
                            "broker: dropping {}: wire frame claims {len} bytes (max {})",
                            conn.peer,
                            codec::MAX_FRAME_BYTES
                        );
                        Next::Close
                    } else if conn.rbuf.len() < total {
                        Next::Done
                    } else {
                        let crc = u32::from_le_bytes(conn.rbuf[4..8].try_into().unwrap());
                        let body =
                            Bytes::copy_from_slice(&conn.rbuf[codec::WIRE_HEADER_BYTES..total]);
                        conn.rbuf.drain(..total);
                        conn.last_activity = Instant::now();
                        // Peek the opcode (offset 8: after corr_id).
                        // `Metric` is one-way — fire-and-forget, the
                        // connection stays idle — and marks the
                        // connection as the client's dedicated metrics
                        // channel, exempt from the idle sweep.
                        let metric = body.as_slice().get(8) == Some(&(OpCode::Metric as u8));
                        if metric {
                            conn.metrics_channel = true;
                        } else {
                            conn.state = ConnState::Busy;
                        }
                        Next::Frame { body, crc, metric }
                    }
                }
            };
            match next {
                Next::Done => return,
                Next::Close => {
                    self.close_conn(id);
                    return;
                }
                Next::Frame { body, crc, metric } => {
                    let shared = self.shared.clone();
                    if metric {
                        self.workers.execute(move || handle_metric(&shared, id, body, crc));
                        continue;
                    }
                    let scratch = self
                        .conns
                        .get_mut(&id)
                        .map(|c| std::mem::take(&mut c.spare))
                        .unwrap_or_default();
                    self.workers
                        .execute(move || handle_request(&shared, id, body, crc, scratch));
                    // Busy: the next frame waits for this one's Respond.
                    self.update_interest(id);
                    return;
                }
            }
        }
    }

    /// Drain the outgoing chunk queue with vectored writes until the
    /// socket blocks or the queue empties.
    fn flush_conn(&mut self, id: u64) {
        loop {
            let outcome = {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.out.is_empty() {
                    return;
                }
                let mut slices: Vec<&[u8]> =
                    Vec::with_capacity(conn.out.len().min(MAX_WRITEV_SEGMENTS));
                for (i, c) in conn.out.iter().take(MAX_WRITEV_SEGMENTS).enumerate() {
                    let s = c.as_slice();
                    slices.push(if i == 0 { &s[conn.front_written..] } else { s });
                }
                reactor::writev(conn.stream.as_raw_fd(), &slices)
            };
            match outcome {
                Ok(0) => return,
                Ok(n) => {
                    let Some(conn) = self.conns.get_mut(&id) else { return };
                    Reactor::advance_out(conn, n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    if let Some(conn) = self.conns.get(&id) {
                        log::debug!("broker: writing to {}: {e}", conn.peer);
                    }
                    self.close_conn(id);
                    return;
                }
            }
        }
    }

    /// Account `n` written bytes against the front of the queue,
    /// recycling fully-written owned chunks into the scratch buffer.
    fn advance_out(conn: &mut Conn, mut n: usize) {
        while n > 0 {
            let Some(front) = conn.out.front() else { return };
            let avail = front.len() - conn.front_written;
            if n < avail {
                conn.front_written += n;
                return;
            }
            n -= avail;
            conn.front_written = 0;
            if let Some(Chunk::Owned(mut v)) = conn.out.pop_front() {
                if v.capacity() > conn.spare.capacity() {
                    v.clear();
                    conn.spare = v;
                }
            }
        }
    }

    /// Post-I/O bookkeeping: release oversized buffers, close drained
    /// EOF connections, sync poller interest.
    fn finish_io(&mut self, id: u64) {
        let close = {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            if conn.rbuf.is_empty() && conn.rbuf.capacity() > RBUF_KEEP_BYTES {
                conn.rbuf = Vec::new();
            }
            conn.eof && conn.out.is_empty() && matches!(conn.state, ConnState::Idle)
        };
        if close {
            self.close_conn(id);
            return;
        }
        self.update_interest(id);
    }

    fn update_interest(&mut self, id: u64) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        let want_read = matches!(conn.state, ConnState::Idle) && !conn.eof;
        let want_write = !conn.out.is_empty();
        if want_read != conn.reg_read || want_write != conn.reg_write {
            if let Err(e) = self
                .poller
                .modify(conn.stream.as_raw_fd(), id, want_read, want_write)
            {
                log::debug!("broker: poller modify for {}: {e}", conn.peer);
            } else {
                conn.reg_read = want_read;
                conn.reg_write = want_write;
            }
        }
    }

    fn close_conn(&mut self, id: u64) {
        if let Some(conn) = self.conns.remove(&id) {
            self.poller.deregister(conn.stream.as_raw_fd()).ok();
            log::debug!("broker: {} disconnected", conn.peer);
            // Dropping `conn` closes the socket; a parked state's guard
            // deregisters its waiter from every wait-set.
        }
    }

    fn drain_inbox(&mut self) {
        loop {
            let batch: Vec<Event> = std::mem::take(&mut *self.shared.inbox.lock().unwrap());
            if batch.is_empty() {
                return;
            }
            for ev in batch {
                self.handle_event(ev);
            }
        }
    }

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::Respond { conn: id, chunks } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                conn.state = ConnState::Idle;
                for c in chunks {
                    if c.is_empty() {
                        // Degenerate chunk: recycle its buffer.
                        if let Chunk::Owned(v) = c {
                            if v.capacity() > conn.spare.capacity() {
                                conn.spare = v;
                            }
                        }
                    } else {
                        conn.out.push_back(c);
                    }
                }
                self.flush_conn(id);
                self.parse_frames(id); // a pipelined next request may be buffered
                self.finish_io(id);
            }
            Event::Park { conn: id, parked } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.eof {
                    // Client already gone: abandon the long-poll.
                    self.close_conn(id);
                    return;
                }
                if self.shared.cancel.is_cancelled()
                    || parked.waiter.generation() != parked.seen
                {
                    // A wake raced the park decision (the hook's
                    // PollWake may even sit earlier in this inbox, a
                    // no-op against a Busy connection): complete now.
                    self.complete_wait_async(id, parked);
                } else {
                    self.timers.push(Reverse((parked.deadline, id)));
                    conn.state = ConnState::Parked(parked);
                    self.update_interest(id);
                }
            }
            Event::PollWake { conn: id } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if matches!(conn.state, ConnState::Parked(_)) {
                    let ConnState::Parked(parked) =
                        std::mem::replace(&mut conn.state, ConnState::Busy)
                    else {
                        unreachable!()
                    };
                    self.complete_wait_async(id, parked);
                }
                // Idle/Busy: a stale wake for a park that already
                // completed — ignore.
            }
            Event::Close { conn: id } => self.close_conn(id),
        }
    }

    /// Answer a (completed or expired) park on the worker pool — the
    /// readiness re-check takes cluster locks, which stay off the
    /// reactor thread.
    fn complete_wait_async(&self, id: u64, parked: Box<Parked>) {
        let shared = self.shared.clone();
        self.workers.execute(move || complete_wait(&shared, id, parked));
    }

    fn fire_timers(&mut self) {
        let now = Instant::now();
        while let Some(&Reverse((t, id))) = self.timers.peek() {
            if t > now {
                return;
            }
            self.timers.pop();
            let Some(conn) = self.conns.get_mut(&id) else { continue };
            if let ConnState::Parked(p) = &conn.state {
                if p.deadline <= now {
                    let ConnState::Parked(parked) =
                        std::mem::replace(&mut conn.state, ConnState::Busy)
                    else {
                        unreachable!()
                    };
                    self.complete_wait_async(id, parked);
                } else {
                    // Stale entry from an earlier park on this
                    // connection; re-arm for the current deadline.
                    let d = p.deadline;
                    self.timers.push(Reverse((d, id)));
                }
            }
        }
    }

    fn sweep_idle(&mut self, now: Instant) {
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                matches!(c.state, ConnState::Idle)
                    && !c.metrics_channel
                    && c.out.is_empty()
                    && now.duration_since(c.last_activity) >= IDLE_TIMEOUT
            })
            .map(|(id, _)| *id)
            .collect();
        for id in stale {
            self.close_conn(id);
        }
    }

    /// Shutdown path: answer every parked long-poll (`woken = true` —
    /// the client re-checks and observes the shutdown), flush
    /// best-effort, close everything.
    fn shutdown_conns(&mut self) {
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let Some(conn) = self.conns.get_mut(&id) else { continue };
            if matches!(conn.state, ConnState::Parked(_)) {
                let ConnState::Parked(parked) =
                    std::mem::replace(&mut conn.state, ConnState::Idle)
                else {
                    unreachable!()
                };
                let p = *parked;
                let Parked { corr, guard, mut scratch, .. } = p;
                drop(guard);
                codec::begin_response(&mut scratch, corr);
                codec::put_bool(&mut scratch, true);
                codec::finish_frame(&mut scratch);
                conn.out.push_back(Chunk::Owned(scratch));
            }
            // A parked response is a handful of bytes into an empty
            // socket buffer: this all but always completes. A socket
            // mid-backpressure just loses its tail — the client sees
            // EOF and reports the disconnect.
            self.flush_conn(id);
        }
        self.conns.clear();
    }
}

// ---- request handling (worker pool) ----------------------------------------

/// One-way `Metric` frame: validate, decode, bump the counter. No
/// response; a CRC failure still drops the connection like any other
/// corrupt frame.
fn handle_metric(shared: &Arc<Shared>, conn: u64, body: Bytes, crc: u32) {
    if format::crc32(body.as_slice()) != crc {
        shared.post(Event::Close { conn });
        return;
    }
    let mut r = Reader::new(body);
    let (Ok(_corr), Ok(_op)) = (r.u64(), r.u8()) else {
        shared.post(Event::Close { conn });
        return;
    };
    if let Err(e) = metric_payload(shared, &mut r) {
        log::debug!("broker: bad metric frame: {e:#}");
    }
}

fn metric_payload(shared: &Arc<Shared>, r: &mut Reader) -> Result<()> {
    let delta = r.u64()?;
    let name = r.str()?;
    shared.cluster.metrics.counter(&name).add(delta);
    Ok(())
}

/// Handle one request frame end-to-end on a worker thread: CRC check,
/// envelope decode, dispatch, response encode (into the connection's
/// recycled scratch buffer), and a `Respond`/`Park`/`Close` post back
/// to the reactor.
fn handle_request(shared: &Arc<Shared>, conn: u64, body: Bytes, crc: u32, mut scratch: Vec<u8>) {
    if format::crc32(body.as_slice()) != crc {
        shared.post(Event::Close { conn });
        return;
    }
    let mut r = Reader::new(body);
    // If even the envelope is unreadable there is no correlation id to
    // answer on — drop the connection.
    let (Ok(corr), Ok(op_byte)) = (r.u64(), r.u8()) else {
        shared.post(Event::Close { conn });
        return;
    };
    let Some(op) = OpCode::from_u8(op_byte) else {
        codec::encode_response_into(&mut scratch, corr, Err(&format!("unknown opcode {op_byte}")));
        shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
        return;
    };
    match op {
        OpCode::FetchBatch => {
            let chunks = fetch_batch_chunks(shared, &mut r, corr, scratch);
            shared.post(Event::Respond { conn, chunks });
        }
        OpCode::FetchWait => fetch_wait(shared, conn, &mut r, corr, scratch),
        OpCode::Metric => {
            // Normally dispatched one-way straight from the reactor;
            // reaching here (a short body defeated the opcode peek)
            // still completes the request cycle, without a response.
            if let Err(e) = metric_payload(shared, &mut r) {
                log::debug!("broker: bad metric frame: {e:#}");
            }
            scratch.clear();
            shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
        }
        _ => {
            codec::begin_response(&mut scratch, corr);
            match dispatch_simple(op, &mut r, shared, &mut scratch) {
                Ok(()) => codec::finish_frame(&mut scratch),
                Err(e) => codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}"))),
            }
            shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
        }
    }
}

/// `FetchBatch`: bound the response to the frame limit, then encode it
/// as gather-write chunks — header bytes in the scratch buffer, large
/// record values as zero-copy slices of the broker log.
fn fetch_batch_chunks(
    shared: &Arc<Shared>,
    r: &mut Reader,
    corr: u64,
    mut scratch: Vec<u8>,
) -> Vec<Chunk> {
    let fetched = (|| -> Result<_> {
        let partition = r.u32()?;
        let from = r.u64()?;
        let max = r.u32()? as usize;
        let topic = r.str()?;
        let batch =
            shared
                .cluster
                .fetch_batch(&topic, partition, from, max, ClientLocality::Remote)?;
        // Bound the RESPONSE to the frame limit too: the client
        // hard-rejects oversized frames, so an unbounded batch of
        // large records would wedge the consumer forever. Return a
        // prefix instead — fetch's contract is "up to max", and the
        // consumer advances through the rest in later fetches.
        let budget = codec::MAX_FRAME_BYTES as usize - 1024; // envelope headroom
        let mut bytes = 4usize; // record-count prefix
        let mut take = 0usize;
        for (offset, rec) in &batch.records {
            let frame = format::frame_size(rec);
            if bytes + frame > budget {
                if take == 0 {
                    anyhow::bail!(
                        "record at {topic}:{partition}@{offset} ({frame} bytes) \
                         exceeds the wire frame limit"
                    );
                }
                break;
            }
            bytes += frame;
            take += 1;
        }
        Ok((batch, take))
    })();
    match fetched {
        Ok((batch, take)) => codec::encode_fetch_response_chunks(
            scratch,
            corr,
            batch.records.iter().take(take).map(|(o, rec)| (*o, rec)),
        ),
        Err(e) => {
            codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}")));
            vec![Chunk::Owned(scratch)]
        }
    }
}

/// `FetchWait`: register with the cluster's wait-sets (plus the server
/// shutdown set), bridge wakes to the reactor through the waiter hook,
/// and either answer immediately (data already there, or a wake raced
/// registration) or hand the reactor a [`Parked`] to hold. The
/// connection costs a registration and a timer entry while parked —
/// no thread.
fn fetch_wait(shared: &Arc<Shared>, conn: u64, r: &mut Reader, corr: u64, mut scratch: Vec<u8>) {
    let parsed = (|| -> Result<_> {
        let timeout_ms = r.u64()?;
        let group = r.opt(|r| Ok((r.str()?, r.u64()?)))?;
        let n = r.u32()? as usize;
        let mut assignments: Vec<(TopicPartition, u64)> = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let topic = r.str()?;
            let p = r.u32()?;
            let pos = r.u64()?;
            assignments.push(((topic, p), pos));
        }
        Ok((timeout_ms, group, assignments))
    })();
    let (timeout_ms, group, assignments) = match parsed {
        Ok(t) => t,
        Err(e) => {
            codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}")));
            shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
            return;
        }
    };
    let wait = Duration::from_millis(timeout_ms).min(MAX_WAIT_SLICE);
    let waiter = Waiter::new();
    // Install the hook BEFORE registering: every wake after this point
    // posts a reactor wakeup for this connection.
    let hook_shared = shared.clone();
    waiter.set_hook(move || hook_shared.post(Event::PollWake { conn }));
    let (guard, deadline) = shared.cluster.register_data_wait(
        &waiter,
        &assignments,
        group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)),
        Instant::now() + wait,
        Some(&shared.shutdown),
    );
    let seen = waiter.generation();
    // Register → snapshot → check: data (or cancellation) that landed
    // before the snapshot is answered without parking; anything after
    // it has already fired the hook.
    if shared.cancel.is_cancelled()
        || shared
            .cluster
            .data_wait_ready(&assignments, group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)))
    {
        drop(guard);
        codec::begin_response(&mut scratch, corr);
        codec::put_bool(&mut scratch, true);
        codec::finish_frame(&mut scratch);
        shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
        return;
    }
    shared.post(Event::Park {
        conn,
        parked: Box::new(Parked {
            corr,
            assignments,
            group,
            deadline,
            waiter,
            seen,
            guard,
            scratch,
        }),
    });
}

/// Answer a park that completed (wake, timeout, or shutdown): re-check
/// readiness, deregister, encode `woken` into the recycled scratch.
fn complete_wait(shared: &Arc<Shared>, conn: u64, parked: Box<Parked>) {
    let Parked { corr, assignments, group, waiter, seen, guard, mut scratch, .. } = *parked;
    let woken = shared.cancel.is_cancelled()
        || waiter.generation() != seen
        || shared
            .cluster
            .data_wait_ready(&assignments, group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)));
    drop(guard);
    codec::begin_response(&mut scratch, corr);
    codec::put_bool(&mut scratch, woken);
    codec::finish_frame(&mut scratch);
    shared.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)] });
}

/// Decode one request payload and run it against the cluster, writing
/// the response payload straight into the (envelope-prefixed) scratch
/// buffer. Decoding happens *entirely* before the cluster call, so a
/// malformed payload can never leave a partition lock poisoned or a
/// group half-updated. On error the caller re-encodes the buffer as an
/// error response — partial payload bytes are simply discarded.
fn dispatch_simple(op: OpCode, r: &mut Reader, shared: &Arc<Shared>, out: &mut Vec<u8>) -> Result<()> {
    let cluster = &shared.cluster;
    match op {
        OpCode::CreateTopic => {
            let partitions = r.u32()?;
            let topic = r.str()?;
            // Through the SAME trait impl the in-process transport
            // uses (0 = broker default), so the two paths cannot drift.
            let n = BrokerTransport::create_topic(&**cluster, &topic, partitions)?;
            codec::put_u32(out, n);
        }
        OpCode::Metadata => {
            let topic = r.str()?;
            let parts = cluster.topic(&topic).map(|t| t.num_partitions());
            codec::put_opt(out, parts.as_ref(), |o, n| codec::put_u32(o, *n));
        }
        OpCode::ListTopics => {
            codec::put_strings(out, &cluster.topic_names());
        }
        OpCode::Produce => {
            let partition = r.u32()?;
            let seq = r.opt(|r| Ok((r.u64()?, r.u64()?)))?;
            let topic = r.str()?;
            // Zero-copy: each decoded record's payloads are slices of
            // the request buffer; the append below shares them.
            let records: Vec<Record> = r.records()?.into_iter().map(|(_, rec)| rec).collect();
            let base = cluster.produce(&topic, partition, &records, ClientLocality::Remote, seq)?;
            codec::put_u64(out, base);
        }
        OpCode::Offsets => {
            let partition = r.u32()?;
            let topic = r.str()?;
            let (earliest, latest) = cluster.offsets(&topic, partition)?;
            codec::put_u64(out, earliest);
            codec::put_u64(out, latest);
        }
        OpCode::AllocProducerId => {
            codec::put_u64(out, cluster.alloc_producer_id());
        }
        OpCode::JoinGroup => {
            let assignor = codec::assignor_from_u8(r.u8()?)?;
            let gid = r.str()?;
            let member = r.str()?;
            let topics = r.strings()?;
            let m = cluster.join_group(&gid, &member, &topics, assignor);
            codec::put_membership(out, &m);
        }
        OpCode::LeaveGroup => {
            let gid = r.str()?;
            let member = r.str()?;
            cluster.leave_group(&gid, &member);
        }
        OpCode::Heartbeat => {
            let gid = r.str()?;
            let member = r.str()?;
            let m = cluster.heartbeat(&gid, &member);
            codec::put_opt(out, m.as_ref(), codec::put_membership);
        }
        OpCode::CommitOffsets => {
            let gid = r.str()?;
            let n = r.u32()? as usize;
            let mut offsets: Vec<(TopicPartition, u64)> = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let topic = r.str()?;
                let p = r.u32()?;
                let off = r.u64()?;
                offsets.push(((topic, p), off));
            }
            // Same trait impl as the in-process transport — no drift.
            BrokerTransport::commit_offsets(&**cluster, &gid, &offsets)?;
        }
        OpCode::CommittedOffset => {
            let gid = r.str()?;
            let topic = r.str()?;
            let p = r.u32()?;
            let committed = cluster.committed_offset(&gid, &(topic, p));
            codec::put_opt(out, committed.as_ref(), |o, v| codec::put_u64(o, *v));
        }
        // Handled before dispatch_simple is reached.
        OpCode::FetchBatch | OpCode::FetchWait | OpCode::Metric => unreachable!(),
    }
    Ok(())
}
