//! ISSUE-9 acceptance: the hardened HTTP substrate under adversarial
//! input, the authenticated control plane (REST + wire protocol), and
//! two-tenant isolation end-to-end over the remote transport.
//!
//! Everything runs against REAL sockets — a live [`Server`] for the
//! REST surface and a live [`BrokerServer`] for the wire protocol — so
//! the request parsing, the auth guard, and the per-connection wire
//! gate are exercised exactly as a remote peer sees them. The e2e test
//! uses the artifact-less native backend (self-written meta.json), so
//! the suite is checkout-independent: zero skips.

use kafka_ml::broker::wire::codec::{self, OpCode, Reader, STATUS_ERR, STATUS_OK};
use kafka_ml::broker::{
    BrokerHandle, BrokerServer, BrokerTransport, ClientLocality, Producer, ProducerConfig, Record,
    RemoteBroker,
};
use kafka_ml::coordinator::inference::run_inference_replica;
use kafka_ml::coordinator::training::run_training_job;
use kafka_ml::coordinator::{
    ControlMessage, InferenceClient, InferenceReplicaConfig, KafkaMl, KafkaMlConfig, StreamRef,
    TrainingJobConfig, CONTROL_TOPIC,
};
use kafka_ml::exec::CancelToken;
use kafka_ml::json::Json;
use kafka_ml::ml::separable_dataset;
use kafka_ml::registry::{api, BackendClient, Quota, Store};
use kafka_ml::rest::{HttpClient, Server};
use kafka_ml::runtime::{BackendSelect, ModelParams, ParamTensor};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// A REST back-end over a fresh store (auth posture left to the test).
fn serve_store() -> (Server, Arc<Store>, String) {
    let store = Arc::new(Store::new());
    let server = Server::start(0, 4, api::router(store.clone())).unwrap();
    let url = server.base_url();
    (server, store, url)
}

fn host_of(base_url: &str) -> &str {
    base_url.trim_start_matches("http://")
}

/// Write raw bytes to the server and return whatever it answers until
/// close — the adversarial client no [`HttpClient`] would let us be.
fn raw_http(host: &str, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(host).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(payload).unwrap();
    s.shutdown(Shutdown::Write).ok();
    let mut out = Vec::new();
    s.read_to_end(&mut out).ok();
    String::from_utf8_lossy(&out).into_owned()
}

// ---- the hardened HTTP substrate ------------------------------------------

#[test]
fn garbage_request_line_gets_400_and_the_server_survives() {
    let (server, _store, url) = serve_store();
    let host = host_of(&url);
    for garbage in [
        &b"NONSENSE\r\n\r\n"[..],
        &b"GET\r\n\r\n"[..],
        &b"\x00\xff\xfe binary trash\r\n\r\n"[..],
    ] {
        let resp = raw_http(host, garbage);
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:.60}");
    }
    // The pool survived all of it: a well-formed request still works.
    let resp = HttpClient::new(&url).get("/models").unwrap();
    assert_eq!(resp.status.code(), 200);
    server.shutdown();
}

#[test]
fn oversized_header_line_and_header_section_get_400() {
    let (server, _store, url) = serve_store();
    let host = host_of(&url);
    // One header line far past the 8 KiB line bound.
    let mut big_line = b"GET /models HTTP/1.1\r\nx-big: ".to_vec();
    big_line.extend(std::iter::repeat(b'a').take(32 * 1024));
    big_line.extend_from_slice(b"\r\n\r\n");
    let resp = raw_http(host, &big_line);
    assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:.60}");
    // Many modest lines past the 64 KiB section bound.
    let mut big_section = b"GET /models HTTP/1.1\r\n".to_vec();
    for i in 0..200 {
        big_section.extend_from_slice(format!("x-h{i}: {}\r\n", "b".repeat(1024)).as_bytes());
    }
    big_section.extend_from_slice(b"\r\n");
    let resp = raw_http(host, &big_section);
    assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:.60}");
    server.shutdown();
}

#[test]
fn oversized_body_declaration_is_refused_up_front() {
    let (server, _store, url) = serve_store();
    let host = host_of(&url);
    // Declares a body past the 256 MiB cap without sending one: the
    // server must refuse on the declaration, not try to allocate/read.
    let resp = raw_http(
        host,
        b"POST /models HTTP/1.1\r\ncontent-length: 999999999999\r\n\r\n",
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:.60}");
    // And a non-numeric declaration is equally dead.
    let resp = raw_http(
        host,
        b"POST /models HTTP/1.1\r\ncontent-length: a-lot\r\n\r\n",
    );
    assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp:.60}");
    let resp = HttpClient::new(&url).get("/models").unwrap();
    assert_eq!(resp.status.code(), 200);
    server.shutdown();
}

// ---- the REST auth gate ----------------------------------------------------

#[test]
fn rest_demands_keys_401_unknown_403_revoked_200_good() {
    let (server, store, url) = serve_store();
    store.auth().set_require(true);
    let good = store.auth().create_key("alice", false).unwrap();
    let revoked = store.auth().create_key("alice", false).unwrap();
    store.auth().revoke(&revoked);

    // No key: 401 on a real route AND on an unknown path (the guard
    // answers before routing, so probes can't map the route table).
    for path in ["/models", "/definitely/not/a/route"] {
        let resp = HttpClient::new(&url).get(path).unwrap();
        assert_eq!(resp.status.code(), 401, "{path}");
    }
    let resp = HttpClient::new(&url).with_token("kml_bogus").get("/models").unwrap();
    assert_eq!(resp.status.code(), 401);
    let resp = HttpClient::new(&url).with_token(&revoked).get("/models").unwrap();
    assert_eq!(resp.status.code(), 403);
    let resp = HttpClient::new(&url).with_token(&good).get("/models").unwrap();
    assert_eq!(resp.status.code(), 200);
    server.shutdown();
}

#[test]
fn cross_tenant_rest_reads_answer_404_not_403() {
    let (server, store, url) = serve_store();
    store.auth().set_require(true);
    let alice = store.auth().create_key("alice", false).unwrap();
    let bob = store.auth().create_key("bob", false).unwrap();

    let id = BackendClient::new_with_key(&url, Some(&alice))
        .create_model("alice-model", "/nonexistent")
        .unwrap();
    // Bob gets the exact same 404 a missing id would give — not a 403
    // that would leak the row's existence.
    let resp = HttpClient::new(&url)
        .with_token(&bob)
        .get(&format!("/models/{id}"))
        .unwrap();
    assert_eq!(resp.status.code(), 404);
    let missing = HttpClient::new(&url)
        .with_token(&bob)
        .get(&format!("/models/{}", id + 999))
        .unwrap();
    assert_eq!(missing.status.code(), 404);
    for body in [&resp.body, &missing.body] {
        assert!(
            String::from_utf8_lossy(body).contains("unknown model"),
            "cross-tenant and missing-id answers must be indistinguishable"
        );
    }
    // Bob's listing is empty; Alice sees her row.
    let list = HttpClient::new(&url).with_token(&bob).get_json("/models").unwrap();
    assert_eq!(list.as_arr().unwrap().len(), 0);
    let list = HttpClient::new(&url).with_token(&alice).get_json("/models").unwrap();
    assert_eq!(list.as_arr().unwrap().len(), 1);
    server.shutdown();
}

#[test]
fn storage_quota_breach_answers_429_while_the_neighbor_is_unaffected() {
    let (server, store, url) = serve_store();
    store.auth().set_require(true);
    let alice = store.auth().create_key("alice", false).unwrap();
    let bob = store.auth().create_key("bob", false).unwrap();
    store
        .auth()
        .set_quota("alice", Quota { records_per_sec: None, stored_bytes: Some(8) });

    // Both tenants walk the same model → configuration → deployment
    // path; only Alice's 64-byte model upload breaches her ceiling.
    let result_of = |key: &str| {
        let be = BackendClient::new_with_key(&url, Some(key));
        let m = be.create_model("m", "/nonexistent").unwrap();
        let c = be.create_configuration("c", &[m]).unwrap();
        let (_, rids) = be.create_deployment(c, 10, 1).unwrap();
        rids[0]
    };
    let a_rid = result_of(&alice);
    let b_rid = result_of(&bob);

    // A well-formed (but > 8 bytes) model blob: the upload must die on
    // the quota, not on blob validation.
    let blob = ModelParams {
        tensors: vec![ParamTensor { name: "w".into(), shape: vec![4], data: vec![0.0; 4] }],
    }
    .to_bytes();
    let resp = HttpClient::new(&url)
        .with_token(&alice)
        .post_binary(&format!("/results/{a_rid}/model"), blob.clone())
        .unwrap();
    assert_eq!(resp.status.code(), 429, "{}", String::from_utf8_lossy(&resp.body));
    assert!(String::from_utf8_lossy(&resp.body).contains("quota"));
    // Bob, on the same server, is untouched by Alice's ceiling.
    let resp = HttpClient::new(&url)
        .with_token(&bob)
        .post_binary(&format!("/results/{b_rid}/model"), blob)
        .unwrap();
    assert!(resp.status.is_success(), "{}", String::from_utf8_lossy(&resp.body));
    server.shutdown();
}

// ---- the wire-protocol auth gate ------------------------------------------

/// One raw request/response round trip on an already-open socket.
fn wire_call(stream: &mut TcpStream, corr: u64, op: OpCode, payload: &[u8]) -> (u64, u8, String) {
    stream
        .write_all(&codec::encode_request(corr, op, payload))
        .unwrap();
    let body = codec::read_frame(stream).unwrap();
    let mut r = Reader::new(body);
    let rcorr = r.u64().unwrap();
    let status = r.u8().unwrap();
    let msg = if status == STATUS_OK {
        String::new()
    } else {
        r.str().unwrap_or_default()
    };
    (rcorr, status, msg)
}

#[test]
fn wire_rejects_every_opcode_before_authenticate() {
    let store = Arc::new(Store::new());
    store.auth().set_require(true);
    let key = store.auth().create_key("alice", false).unwrap();
    let cluster = kafka_ml::broker::Cluster::new(Default::default());
    let server =
        BrokerServer::start_sharded_auth("127.0.0.1:0", cluster, 2, 1, Some(store.auth().clone()))
            .unwrap();
    let addr = server.addr().to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Every opcode except Authenticate (the gate itself) and Metric
    // (one-way; nothing to answer on) bounces with an error response on
    // the SAME connection — rejection does not tear the socket down.
    let gated = [
        OpCode::CreateTopic,
        OpCode::Metadata,
        OpCode::ListTopics,
        OpCode::Produce,
        OpCode::FetchBatch,
        OpCode::FetchWait,
        OpCode::Offsets,
        OpCode::AllocProducerId,
        OpCode::JoinGroup,
        OpCode::LeaveGroup,
        OpCode::Heartbeat,
        OpCode::CommitOffsets,
        OpCode::CommittedOffset,
    ];
    for (i, op) in gated.into_iter().enumerate() {
        let corr = 100 + i as u64;
        let (rcorr, status, msg) = wire_call(&mut stream, corr, op, &[]);
        assert_eq!(rcorr, corr, "{op:?}");
        assert_eq!(status, STATUS_ERR, "{op:?}");
        assert!(msg.contains("unauthenticated"), "{op:?}: {msg}");
    }
    // A wrong key is a definitive error, and the connection survives…
    let mut p = Vec::new();
    codec::put_str(&mut p, "kml_not_a_key");
    let (_, status, msg) = wire_call(&mut stream, 500, OpCode::Authenticate, &p);
    assert_eq!(status, STATUS_ERR);
    assert!(msg.contains("unknown key"), "{msg}");
    // …so the right key on the same socket opens the gate.
    let mut p = Vec::new();
    codec::put_str(&mut p, &key);
    let (rcorr, status, _) = wire_call(&mut stream, 501, OpCode::Authenticate, &p);
    assert_eq!((rcorr, status), (501, STATUS_OK));
    let (_, status, msg) = wire_call(&mut stream, 502, OpCode::ListTopics, &[]);
    assert_eq!(status, STATUS_OK, "{msg}");
    server.shutdown();
}

#[test]
fn remote_broker_authenticates_automatically() {
    let store = Arc::new(Store::new());
    store.auth().set_require(true);
    let key = store.auth().create_key("alice", false).unwrap();
    let cluster = kafka_ml::broker::Cluster::new(Default::default());
    let server =
        BrokerServer::start_sharded_auth("127.0.0.1:0", cluster, 2, 1, Some(store.auth().clone()))
            .unwrap();
    let addr = server.addr().to_string();

    // A bad key fails at connect (the eager probe runs the handshake).
    let err = RemoteBroker::connect_with_key(&addr, Some("kml_wrong")).unwrap_err();
    assert!(format!("{err:#}").contains("unknown key"), "{err:#}");
    // No key at all fails on the first real call's error answer.
    let anon = RemoteBroker::connect(&addr).unwrap();
    let err = anon.create_topic("t", 1).unwrap_err();
    assert!(format!("{err:#}").contains("unauthenticated"), "{err:#}");
    // The keyed client works end to end: every new connection (main
    // lane, wait lane) authenticates before its first request.
    let broker: BrokerHandle = RemoteBroker::connect_with_key(&addr, Some(&key)).unwrap();
    broker.create_topic("t", 1).unwrap();
    broker
        .produce("t", 0, &[Record::new(b"hello".to_vec())], ClientLocality::Remote, None)
        .unwrap();
    assert_eq!(broker.offsets("t", 0).unwrap(), (0, 1));
    assert!(broker
        .wait_for_data(&[(("t".to_string(), 0), 0)], None, Duration::from_millis(50))
        .unwrap());
    server.shutdown();
}

#[test]
fn wire_produce_quota_rejects_only_the_over_quota_tenant() {
    let store = Arc::new(Store::new());
    store.auth().set_require(true);
    let alice = store.auth().create_key("alice", false).unwrap();
    let bob = store.auth().create_key("bob", false).unwrap();
    store
        .auth()
        .set_quota("alice", Quota { records_per_sec: Some(2), stored_bytes: None });
    let cluster = kafka_ml::broker::Cluster::new(Default::default());
    let server =
        BrokerServer::start_sharded_auth("127.0.0.1:0", cluster, 2, 1, Some(store.auth().clone()))
            .unwrap();
    let addr = server.addr().to_string();

    let a: BrokerHandle = RemoteBroker::connect_with_key(&addr, Some(&alice)).unwrap();
    let b: BrokerHandle = RemoteBroker::connect_with_key(&addr, Some(&bob)).unwrap();
    a.create_topic("q", 1).unwrap();
    let batch3: Vec<Record> = (0..3).map(|i| Record::new(vec![i as u8; 16])).collect();
    // Three records in one batch breach Alice's 2/s window — and the
    // rejection charges nothing, so a smaller batch still fits.
    let err = a
        .produce("q", 0, &batch3, ClientLocality::Remote, None)
        .unwrap_err();
    assert!(format!("{err:#}").contains("quota"), "{err:#}");
    a.produce("q", 0, &batch3[..1], ClientLocality::Remote, None)
        .unwrap();
    // Bob, same broker, same moment: unconstrained.
    b.produce("q", 0, &batch3, ClientLocality::Remote, None).unwrap();
    assert_eq!(b.offsets("q", 0).unwrap(), (0, 4));
    server.shutdown();
}

// ---- two tenants, full pipeline, remote transport --------------------------

fn raw_config() -> Json {
    kafka_ml::json::parse(r#"{"dtype": "f32", "shape": [8]}"#).unwrap()
}

fn write_native_model_spec(dir: &std::path::Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("meta.json"),
        r#"{
          "format_version": 1,
          "spec": {"input_dim": 8, "hidden": [16], "classes": 4, "batch": 10,
                   "lr": 0.01, "beta1": 0.9, "beta2": 0.999, "eps": 1e-07, "seed": 7},
          "params": [
            {"name": "w1", "shape": [8, 16], "dtype": "f32"},
            {"name": "b1", "shape": [16], "dtype": "f32"},
            {"name": "w2", "shape": [16, 4], "dtype": "f32"},
            {"name": "b2", "shape": [4], "dtype": "f32"}
          ],
          "artifacts": {}
        }"#,
    )
    .unwrap();
}

/// Produce `samples` to `topic` and send the deployment's control
/// message, all over `broker` (a tenant's remote connection).
fn stream_samples(
    broker: &BrokerHandle,
    deployment_id: u64,
    topic: &str,
    samples: &[kafka_ml::formats::Sample],
) {
    let format = kafka_ml::formats::registry("RAW", &raw_config()).unwrap();
    broker.create_topic(topic, 1).unwrap();
    let (_, start) = broker.offsets(topic, 0).unwrap();
    let mut producer = Producer::new(
        broker.clone(),
        ProducerConfig { batch_size: 64, locality: ClientLocality::Remote, ..Default::default() },
    );
    for s in samples {
        producer
            .send_to(topic, 0, format.encode(&s.features, s.label).unwrap())
            .unwrap();
    }
    producer.flush().unwrap();
    let (_, end) = broker.offsets(topic, 0).unwrap();
    let msg = ControlMessage {
        deployment_id,
        stream: StreamRef::new(topic, 0, start, end - start),
        input_format: "RAW".into(),
        input_config: raw_config(),
        validation_rate: 0.2,
        total_msg: end - start,
    };
    broker
        .produce(
            CONTROL_TOPIC,
            0,
            &[Record::new(msg.encode())],
            ClientLocality::Remote,
            None,
        )
        .unwrap();
}

#[test]
fn two_tenant_pipeline_end_to_end_with_zero_cross_visibility() {
    let dir = std::env::temp_dir().join(format!("kafka-ml-tenants-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    write_native_model_spec(&dir);
    let dir_str = dir.to_string_lossy().to_string();

    // The platform pod: broker + REST back-end with auth REQUIRED, plus
    // the wire server sharing the same key table.
    let kml = KafkaMl::start(KafkaMlConfig {
        backend: BackendSelect::Native,
        require_auth: true,
        ..Default::default()
    })
    .unwrap();
    let wire = BrokerServer::start_sharded_auth(
        "127.0.0.1:0",
        kml.cluster.clone(),
        4,
        2,
        Some(kml.store.auth().clone()),
    )
    .unwrap();
    let broker_addr = wire.addr().to_string();
    let backend_url = kml.backend_url().to_string();
    let alice_key = kml.store.auth().create_key("alice", false).unwrap();
    let bob_key = kml.store.auth().create_key("bob", false).unwrap();

    // ---- Alice: full produce → train → infer, every hop keyed -----------
    let alice_be = BackendClient::new_with_key(&backend_url, Some(&alice_key));
    let a_model = alice_be.create_model("alice-mlp", &dir_str).unwrap();
    let a_conf = alice_be.create_configuration("alice-conf", &[a_model]).unwrap();
    let (a_dep, a_rids) = alice_be.create_deployment(a_conf, 10, 30).unwrap();
    let a_rid = a_rids[0];

    let a_trainer: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&alice_key)).unwrap();
    let a_cfg = TrainingJobConfig {
        epochs: 30,
        seed: 7,
        locality: ClientLocality::Remote,
        backend: BackendSelect::Native,
        api_key: Some(alice_key.clone()),
        ..TrainingJobConfig::new(a_dep, a_rid, &dir_str, &backend_url)
    };
    let a_thread =
        std::thread::spawn(move || run_training_job(&a_trainer, &a_cfg, &CancelToken::new()));
    let a_ingest: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&alice_key)).unwrap();
    stream_samples(&a_ingest, a_dep, "alice-data", &separable_dataset(260, 8, 4, 1).samples);

    // ---- Bob: his own smaller pipeline on the SAME platform -------------
    let bob_be = BackendClient::new_with_key(&backend_url, Some(&bob_key));
    let b_model = bob_be.create_model("bob-mlp", &dir_str).unwrap();
    let b_conf = bob_be.create_configuration("bob-conf", &[b_model]).unwrap();
    let (b_dep, b_rids) = bob_be.create_deployment(b_conf, 10, 10).unwrap();
    let b_rid = b_rids[0];
    let b_trainer: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&bob_key)).unwrap();
    let b_cfg = TrainingJobConfig {
        epochs: 10,
        seed: 11,
        locality: ClientLocality::Remote,
        backend: BackendSelect::Native,
        api_key: Some(bob_key.clone()),
        ..TrainingJobConfig::new(b_dep, b_rid, &dir_str, &backend_url)
    };
    let b_thread =
        std::thread::spawn(move || run_training_job(&b_trainer, &b_cfg, &CancelToken::new()));
    let b_ingest: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&bob_key)).unwrap();
    stream_samples(&b_ingest, b_dep, "bob-data", &separable_dataset(120, 8, 4, 5).samples);

    // Both jobs finish; Alice's model clears the 90% bar.
    let a_out = a_thread.join().unwrap().expect("alice training job");
    assert!(a_out.metrics.val_accuracy.unwrap() >= 0.9);
    b_thread.join().unwrap().expect("bob training job");

    // ---- zero cross-tenant visibility -----------------------------------
    // Each tenant's listing holds exactly their own row.
    let names = |key: &str| -> Vec<String> {
        HttpClient::new(&backend_url)
            .with_token(key)
            .get_json("/models")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|m| m.req_str("name").unwrap().to_string())
            .collect()
    };
    assert_eq!(names(&alice_key), vec!["alice-mlp".to_string()]);
    assert_eq!(names(&bob_key), vec!["bob-mlp".to_string()]);
    // Bob's probes at Alice's ids answer 404 — the same status a
    // missing id gives, never a 403 that confirms existence.
    for path in [
        format!("/models/{a_model}"),
        format!("/results/{a_rid}"),
        format!("/results/{a_rid}/model"),
        format!("/deployments/{a_dep}"),
    ] {
        let resp = HttpClient::new(&backend_url).with_token(&bob_key).get(&path).unwrap();
        assert_eq!(resp.status.code(), 404, "{path}");
    }
    assert!(bob_be.download_model(a_rid).is_err());
    // The admin service key sees both tenants.
    let admin_names = names(kml.service_key().unwrap());
    assert_eq!(admin_names.len(), 2);
    // Wire usage was metered against Alice's key.
    let alice_usage = kml
        .store
        .auth()
        .list()
        .into_iter()
        .find(|k| k.token == alice_key)
        .unwrap()
        .usage;
    assert!(alice_usage.records_produced >= 260, "{alice_usage:?}");

    // ---- Alice serves inference; Bob cannot even see the row ------------
    kml.wait_control_logged(a_dep, Duration::from_secs(10)).unwrap();
    let a_inf = alice_be
        .create_inference(a_rid, 1, "alice-in", "alice-out")
        .unwrap();
    assert!(bob_be.inference_info(a_inf).is_err());
    let replica: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&alice_key)).unwrap();
    replica.create_topic("alice-in", 1).unwrap();
    replica.create_topic("alice-out", 1).unwrap();
    let cancel = CancelToken::new();
    let r_cfg = InferenceReplicaConfig {
        inference_id: a_inf,
        result_id: a_rid,
        artifact_dir: dir_str.clone(),
        backend_url: backend_url.clone(),
        input_topic: "alice-in".into(),
        output_topic: "alice-out".into(),
        input_format: "RAW".into(),
        input_config: raw_config(),
        locality: ClientLocality::Remote,
        max_poll: 32,
        backend: BackendSelect::Native,
        api_key: Some(alice_key.clone()),
    };
    let r_cancel = cancel.clone();
    let r_thread = std::thread::spawn(move || {
        run_inference_replica(&replica, &r_cfg, "alice-replica-0", &r_cancel)
    });
    let client_conn: BrokerHandle =
        RemoteBroker::connect_with_key(&broker_addr, Some(&alice_key)).unwrap();
    let mut client = InferenceClient::new(
        client_conn,
        "alice-in",
        "alice-out",
        "RAW",
        &raw_config(),
        ClientLocality::Remote,
    )
    .unwrap();
    let test = separable_dataset(20, 8, 4, 2);
    let mut correct = 0usize;
    for s in &test.samples {
        let p = client.request(&s.features, Duration::from_secs(15)).unwrap();
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    assert!(correct >= 16, "only {correct}/20 over the authenticated wire");

    cancel.cancel();
    r_thread.join().unwrap().expect("alice inference replica");
    wire.shutdown();
    kml.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
