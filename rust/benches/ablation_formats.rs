//! Format ablation (§III-D): RAW f32 vs RAW u8 vs Avro — encode + decode
//! throughput and wire size for the HCOPD record shape. Quantifies what
//! the choice of `input_format` costs on the ingestion and inference
//! paths.

use kafka_ml::benchkit::{Bench, Table};
use kafka_ml::formats::registry;
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;

fn main() -> anyhow::Result<()> {
    let n = 20_000usize;
    let ds = hcopd_dataset(n, 8, 42);
    let bench = Bench::new(1, 5);

    let raw_f32 = Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ]);
    let raw_u8 = Json::obj(vec![
        ("dtype", Json::str("u8")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ]);
    let avro = kafka_ml::json::parse(
        r#"{
      "data_scheme": {"type":"record","name":"d","fields":[
        {"name":"age","type":"float"},
        {"name":"gender","type":"float"},
        {"name":"smoking","type":"float"},
        {"name":"sensors","type":{"type":"array","items":"float"}}]},
      "label_scheme": {"type":"record","name":"l","fields":[
        {"name":"diagnosis","type":"int"}]}
    }"#,
    )
    .unwrap();

    let mut t = Table::new(
        &format!("Format ablation — {n} HCOPD samples (8 features + label)"),
        &["format", "encode (s)", "decode (s)", "samples/s (enc+dec)", "bytes/record"],
    );
    for (name, config, lossy) in [
        ("RAW f32", &raw_f32, false),
        ("RAW u8", &raw_u8, true),
        ("AVRO", &avro, false),
    ] {
        let fmt = registry(name.split(' ').next().unwrap(), config)?;
        // Pre-encode once for size + decode input.
        let sample_recs: Vec<_> = ds
            .samples
            .iter()
            .map(|s| {
                // u8 is only valid in [0,1]; squish features for that row.
                if lossy {
                    let f: Vec<f32> = s.features.iter().map(|&v| v.clamp(0.0, 1.0)).collect();
                    fmt.encode(&f, s.label).unwrap()
                } else {
                    fmt.encode(&s.features, s.label).unwrap()
                }
            })
            .collect();
        let bytes = sample_recs[0].size_bytes();

        let enc = bench.run(|| {
            for s in &ds.samples {
                if lossy {
                    let f: Vec<f32> = s.features.iter().map(|&v| v.clamp(0.0, 1.0)).collect();
                    std::hint::black_box(fmt.encode(&f, s.label).unwrap());
                } else {
                    std::hint::black_box(fmt.encode(&s.features, s.label).unwrap());
                }
            }
        });
        let dec = bench.run(|| {
            for r in &sample_recs {
                std::hint::black_box(fmt.decode(r).unwrap());
            }
        });
        let both = enc.mean_secs() + dec.mean_secs();
        t.row(&[
            name.into(),
            format!("{:.4}", enc.mean_secs()),
            format!("{:.4}", dec.mean_secs()),
            format!("{:.0}", n as f64 / both),
            bytes.to_string(),
        ]);
    }
    t.print();
    println!("\nRAW u8 quantizes to [0,1] (lossy, 4x smaller than f32);");
    println!("AVRO pays schema-driven varint/array framing for multi-input records.");
    Ok(())
}
