//! `RemoteBroker`: the socket client side of the wire protocol — a
//! [`BrokerTransport`] whose broker lives in another OS process.
//!
//! The client is **multiplexed**: all ordinary calls share ONE socket.
//! Each caller stamps its request with a fresh correlation id,
//! registers a completion channel in the connection's demux table,
//! writes its frame (a short critical section on the write half), and
//! parks on its channel; a per-connection **reader thread** pulls
//! response frames off the socket and routes each to its caller by
//! correlation id ([`codec::peek_corr`]). N concurrent callers — and a
//! pipelined producer with several batches in flight
//! ([`produce_submit`](BrokerTransport::produce_submit)) — therefore
//! cost one fd and zero per-call connection setup, and responses may
//! complete out of submission order.
//!
//! Long-polls (`FetchWait`) ride a **dedicated lane** — a second
//! multiplexed connection — so a poll parked server-side for seconds
//! never delays a produce's response bytes behind its own (the server
//! interleaves responses per *connection*; separating the lanes keeps
//! the latency path clean even mid-flight). One-way `Metric` frames
//! keep their own fire-and-forget socket.
//!
//! Failure model: a transport-level failure (connect refused, reset,
//! torn or corrupt response frame, response timeout) kills the whole
//! connection — the reader fails every parked caller, the lane opens a
//! fresh connection, and the failed call is retried **once**. A
//! retried produce is at-least-once — exactly like the in-process
//! producer's own retry path — and the idempotent `(producer_id, seq)`
//! dedup keeps exactly-once batches duplicate-free across reconnects.
//! Server-side *answers* (including errors like `duplicate batch`) are
//! definitive and never retried. Connections idle longer than
//! [`CLIENT_IDLE_EXPIRY`] are dropped proactively — the server's idle
//! sweep is about to close them anyway, and burning the one transport
//! retry on a predictably-dead socket would turn every post-quiet-
//! period call into a reconnect.
//!
//! Fetch responses decode zero-copy: every record in one response frame
//! is a [`crate::util::Bytes`] slice view of that frame's single buffer.
//!
//! **Cluster awareness**: a `RemoteBroker` built by
//! [`RemoteBroker::connect`] is a
//! *bootstrap* — on the first partition-addressed call it fetches the
//! broker's [`ClusterView`] (`ClusterMeta`) and caches it. When the
//! view is clustered, produces and fetches are routed straight to each
//! partition's **leader** over a lazily-dialed per-broker connection
//! pool, and every routed request carries the view's epoch so a
//! deposed leader can fence it (`not-leader`). A `not-leader` answer —
//! or an unreachable leader — triggers a metadata refresh and a
//! re-route, so a mid-failover caller converges on the promoted
//! follower without surfacing an error.

use super::codec::{self, OpCode, Reader, STATUS_OK};
use super::server;
use crate::broker::clusterctl::{self, ClusterView};
use crate::broker::group::{Assignor, GroupMembership};
use crate::broker::net::ClientLocality;
use crate::broker::record::{Record, RecordBatch};
use crate::broker::transport::{BrokerTransport, ProduceHandle, ProduceOutcome, ReadyProduce};
use crate::broker::TopicPartition;
use crate::exec::channel::{bounded, Receiver, RecvError, Sender};
use crate::util::bytes::Bytes;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// TCP connect timeout per address candidate.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How long a caller waits for its response (long-polls get their own
/// margin).
const CALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Extra wait slack on top of a long-poll's requested timeout, so a
/// server answering exactly at the deadline is never misread as dead.
const WAIT_MARGIN: Duration = Duration::from_secs(5);

/// Drop a connection this long after its last request instead of
/// reusing it: the server's idle sweep closes connections after
/// [`server::IDLE_TIMEOUT`] (checked every [`server::SWEEP_INTERVAL`]),
/// so anything older than the sweep window minus one full sweep period
/// is presumed dead and not worth burning the one transport retry on.
pub const CLIENT_IDLE_EXPIRY: Duration = Duration::from_secs(
    server::IDLE_TIMEOUT.as_secs() - 2 * server::SWEEP_INTERVAL.as_secs(),
);

/// How many times a partition-addressed call may re-resolve its route
/// (metadata refresh + retry) after a `not-leader` answer or an
/// unreachable leader. Sized to outlast a leader failover: detection
/// plus promotion plus propagation comfortably fits inside
/// `ROUTE_ATTEMPTS × ROUTE_RETRY_PAUSE` at the supervisor's defaults.
const ROUTE_ATTEMPTS: usize = 5;

/// Pause before each routed retry, giving the cluster's supervisor
/// time to converge on a new leader.
const ROUTE_RETRY_PAUSE: Duration = Duration::from_millis(150);

/// Cap on a clustered long-poll whose assignments span more than one
/// leader: the poll parks on one broker only, so it must come up for
/// air often enough to notice data arriving on the others.
const SPLIT_WAIT_CAP: Duration = Duration::from_millis(100);

/// Process-global source of [`MuxConn::epoch`] identities. Global, not
/// per-broker: the producer pins an in-flight window to a connection by
/// epoch alone, and with cluster routing the retry may land on a
/// *different* broker — two brokers' connections must never share an
/// identity.
static CONN_EPOCHS: AtomicU64 = AtomicU64::new(0);

/// What the reader thread delivers to a parked caller: the whole
/// response frame body, or the transport failure that killed the
/// connection.
type Delivery = Result<Bytes, String>;
type PendingMap = HashMap<u64, Sender<Delivery>>;

/// One multiplexed connection: a shared write half, a demux table, and
/// a reader thread routing response frames to registered callers.
struct MuxConn {
    writer: Mutex<TcpStream>,
    /// `None` once the connection has failed — the tombstone that makes
    /// late registrations fail fast instead of parking forever. The
    /// reader thread holds its own `Arc` on this (NOT on the `MuxConn`),
    /// so a discarded connection's memory is not pinned by its reader.
    pending: Arc<Mutex<Option<PendingMap>>>,
    /// Last request submission, for [`CLIENT_IDLE_EXPIRY`].
    last_used: Mutex<Instant>,
    /// Broker-unique connection identity (never 0), for the producer's
    /// window pinning (`produce_submit`'s `window_epoch`).
    epoch: u64,
}

impl MuxConn {
    /// Connect and spawn the reader thread. When the broker was built
    /// with an API key, the connection authenticates *before* the
    /// reader thread exists — the handshake is the one moment a plain
    /// blocking read on the socket is race-free.
    fn open(broker: &RemoteBroker, lane: &'static str) -> Result<Arc<MuxConn>> {
        let mut stream = broker.fresh_stream()?;
        if let Some(key) = &broker.api_key {
            authenticate_stream(&mut stream, key)?;
        }
        let read_half = stream.try_clone().context("cloning broker socket")?;
        let conn = Arc::new(MuxConn {
            writer: Mutex::new(stream),
            pending: Arc::new(Mutex::new(Some(HashMap::new()))),
            last_used: Mutex::new(Instant::now()),
            epoch: CONN_EPOCHS.fetch_add(1, Ordering::Relaxed) + 1,
        });
        let pending = conn.pending.clone();
        std::thread::Builder::new()
            .name(format!("remote-mux-{lane}"))
            .spawn(move || reader_loop(read_half, pending))
            .context("spawning connection reader")?;
        Ok(conn)
    }

    /// Reserve a demux slot for `corr`. Fails if the connection already
    /// died (the caller should grab a fresh one).
    fn register(&self, corr: u64) -> Result<Receiver<Delivery>> {
        let (tx, rx) = bounded(1);
        let mut p = self.pending.lock().unwrap();
        match p.as_mut() {
            Some(map) => {
                map.insert(corr, tx);
                *self.last_used.lock().unwrap() = Instant::now();
                Ok(rx)
            }
            None => bail!("connection already failed"),
        }
    }

    fn is_dead(&self) -> bool {
        self.pending.lock().unwrap().is_none()
    }

    fn idle_expired(&self) -> bool {
        self.last_used.lock().unwrap().elapsed() >= CLIENT_IDLE_EXPIRY
    }

    /// Tear the connection down: fail every parked caller and shut the
    /// socket so the reader thread exits *now* (a plain drop would
    /// leave it blocked in `read` until the server's idle sweep).
    fn kill(&self) {
        fail_all(&self.pending, "connection closed");
        self.writer.lock().unwrap().shutdown(Shutdown::Both).ok();
    }
}

/// Present the API key as the connection's first frame and wait for
/// the server's verdict before any multiplexed traffic starts. A
/// rejected key fails the connect (definitive — retrying won't make
/// the key valid); so does a transport error mid-handshake.
fn authenticate_stream(stream: &mut TcpStream, key: &str) -> Result<()> {
    stream
        .set_read_timeout(Some(CALL_TIMEOUT))
        .context("arming the auth handshake timeout")?;
    let mut p = Vec::new();
    codec::put_str(&mut p, key);
    // Correlation id 0 is reserved for the handshake: the demux table
    // doesn't exist yet, and ordinary corrs start at 1.
    let frame = codec::encode_request(0, OpCode::Authenticate, &p);
    stream
        .write_all(&frame)
        .context("writing Authenticate frame")?;
    let body = codec::read_frame(stream).context("reading Authenticate response")?;
    match decode_response(0, body)? {
        Ok(_) => {
            // Back to a blocking socket: the reader thread must park in
            // `read` indefinitely, not wake up every CALL_TIMEOUT.
            stream
                .set_read_timeout(None)
                .context("disarming the auth handshake timeout")?;
            Ok(())
        }
        Err(server_err) => Err(server_err.context("broker rejected API key")),
    }
}

/// Fail every registered caller and tombstone the map.
fn fail_all(pending: &Arc<Mutex<Option<PendingMap>>>, why: &str) {
    let map = pending.lock().unwrap().take();
    if let Some(map) = map {
        for (_, tx) in map {
            tx.send(Err(why.to_string())).ok();
        }
    }
}

/// The per-connection demux pump: read frames, route by correlation id.
/// Exits (failing all parked callers) on the first transport error —
/// after a torn frame the stream position is unknowable, so the whole
/// connection is condemned rather than resynchronized.
fn reader_loop(mut stream: TcpStream, pending: Arc<Mutex<Option<PendingMap>>>) {
    loop {
        let body = match codec::read_frame(&mut stream) {
            Ok(b) => b,
            Err(e) => {
                fail_all(&pending, &format!("wire read failed: {e}"));
                return;
            }
        };
        let Some(corr) = codec::peek_corr(body.as_slice()) else {
            fail_all(&pending, "response too short for a correlation id");
            return;
        };
        let slot = match pending.lock().unwrap().as_mut() {
            Some(map) => map.remove(&corr),
            None => return, // killed while we were reading
        };
        match slot {
            Some(tx) => {
                tx.send(Ok(body)).ok();
            }
            None => {
                // A caller that timed out and walked away; its answer
                // is stale but the stream is still framed — drop it.
                log::debug!("dropping unmatched response (corr {corr})");
            }
        }
    }
}

/// One named slot holding the current [`MuxConn`] for a traffic class.
struct Lane {
    name: &'static str,
    slot: Mutex<Option<Arc<MuxConn>>>,
}

impl Lane {
    fn new(name: &'static str) -> Lane {
        Lane { name, slot: Mutex::new(None) }
    }

    /// The lane's live connection, opening a fresh one if the slot is
    /// empty, dead, or idle-expired.
    fn get(&self, broker: &RemoteBroker) -> Result<Arc<MuxConn>> {
        let stale = {
            let mut slot = self.slot.lock().unwrap();
            match slot.as_ref() {
                Some(c) if !c.is_dead() && !c.idle_expired() => return Ok(c.clone()),
                Some(_) => slot.take(),
                None => None,
            }
        };
        if let Some(c) = stale {
            c.kill();
        }
        let fresh = MuxConn::open(broker, self.name)?;
        let mut slot = self.slot.lock().unwrap();
        if let Some(c) = slot.as_ref() {
            if !c.is_dead() {
                // Another caller raced a connection in first: share it.
                let theirs = c.clone();
                drop(slot);
                fresh.kill();
                return Ok(theirs);
            }
        }
        *slot = Some(fresh.clone());
        Ok(fresh)
    }

    /// Drop `conn` from the slot (if it is still the resident) and kill
    /// it. Called on any transport failure.
    fn discard(&self, conn: &Arc<MuxConn>) {
        {
            let mut slot = self.slot.lock().unwrap();
            if slot.as_ref().map_or(false, |c| Arc::ptr_eq(c, conn)) {
                slot.take();
            }
        }
        conn.kill();
    }

    fn kill_resident(&self) {
        if let Some(c) = self.slot.lock().unwrap().take() {
            c.kill();
        }
    }
}

/// A socket [`BrokerTransport`]. Cheap to share: clone the `Arc`.
pub struct RemoteBroker {
    addr: String,
    /// Ordinary request/response traffic (everything but long-polls).
    main: Lane,
    /// `FetchWait` long-polls, so a poll parked for seconds shares no
    /// socket with the latency path.
    wait: Lane,
    /// Dedicated connection for one-way `Metric` frames (the server
    /// never answers them), so a counter bump costs one buffered socket
    /// write — it never stalls the latency path and never desyncs the
    /// demux discipline of the mux connections. Timestamped for the
    /// same idle expiry as the lanes.
    metrics_conn: Mutex<Option<(TcpStream, Instant)>>,
    /// API key presented on every new mux connection (`Authenticate`
    /// is each connection's first frame when this is set). The metrics
    /// socket is exempt, matching the server's one-way `Metric` carve-
    /// out.
    api_key: Option<String>,
    corr: AtomicU64,
    /// Whether this instance routes partition traffic by the cluster
    /// metadata map. True for bootstraps built by `connect*`; false for
    /// the per-broker pool entries they dial (a routed call must go
    /// exactly where it was aimed) and for broker-to-broker handles
    /// ([`RemoteBroker::connect_peer`]).
    cluster_aware: bool,
    /// Cached cluster metadata. `None` until the first
    /// partition-addressed call probes `ClusterMeta`; a solo answer
    /// (empty roster) caches too, disabling routing against
    /// single-broker deployments at the cost of one round trip, ever.
    view: Mutex<Option<ClusterView>>,
    /// Lazily-dialed connections to the other brokers in the view,
    /// keyed by advertised address.
    peers: Mutex<HashMap<String, Arc<RemoteBroker>>>,
}

impl std::fmt::Debug for RemoteBroker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteBroker").field("addr", &self.addr).finish()
    }
}

impl Drop for RemoteBroker {
    fn drop(&mut self) {
        // Shut the sockets so the reader threads exit immediately.
        self.main.kill_resident();
        self.wait.kill_resident();
        if let Some((c, _)) = self.metrics_conn.lock().unwrap().take() {
            c.shutdown(Shutdown::Both).ok();
        }
    }
}

impl RemoteBroker {
    /// Connect to a [`super::BrokerServer`] at `addr`
    /// (e.g. `127.0.0.1:9092`). Fails fast when the broker is
    /// unreachable; afterwards, individual calls reconnect as needed.
    pub fn connect(addr: &str) -> Result<Arc<RemoteBroker>> {
        RemoteBroker::connect_with_key(addr, None)
    }

    /// [`connect`](RemoteBroker::connect), presenting `api_key` as each
    /// connection's first frame (for brokers running `--require-auth`).
    /// A bad key fails here, at connect time — the eager probe opens a
    /// connection, and the handshake is part of opening one.
    pub fn connect_with_key(addr: &str, api_key: Option<&str>) -> Result<Arc<RemoteBroker>> {
        RemoteBroker::connect_inner(addr, api_key, true)
    }

    /// A *pinned* connection for broker-to-broker traffic (replication
    /// pulls, supervisor heartbeats, metadata pushes): never consults
    /// the metadata map, never routes — every call lands on `addr`.
    pub fn connect_peer(addr: &str, api_key: Option<&str>) -> Result<Arc<RemoteBroker>> {
        RemoteBroker::connect_inner(addr, api_key, false)
    }

    fn connect_inner(
        addr: &str,
        api_key: Option<&str>,
        cluster_aware: bool,
    ) -> Result<Arc<RemoteBroker>> {
        let broker = Arc::new(RemoteBroker {
            addr: addr.to_string(),
            main: Lane::new("main"),
            wait: Lane::new("wait"),
            metrics_conn: Mutex::new(None),
            api_key: api_key.map(str::to_string),
            corr: AtomicU64::new(1),
            cluster_aware,
            view: Mutex::new(None),
            peers: Mutex::new(HashMap::new()),
        });
        broker.main.get(&broker)?; // eager probe: unreachable (or rejected) fails here
        Ok(broker)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    // ---- cluster routing ----------------------------------------------------

    /// The cached metadata view, probing `ClusterMeta` on first use.
    /// `None` disables routing (pinned handle, or the probe failed).
    fn cached_view(&self) -> Option<ClusterView> {
        if !self.cluster_aware {
            return None;
        }
        let mut slot = self.view.lock().unwrap();
        if slot.is_none() {
            match self.fetch_cluster_meta() {
                Ok(v) => *slot = Some(v),
                Err(e) => {
                    // Cache a solo view anyway: a broker that can't
                    // answer ClusterMeta can't route either, and a
                    // later `not-leader` answer forces a real refresh.
                    log::debug!("cluster metadata probe against {} failed: {e:#}", self.addr);
                    *slot = Some(ClusterView::solo());
                }
            }
        }
        slot.clone()
    }

    /// Drop the cache and re-fetch the view from the bootstrap broker.
    /// Best-effort: on failure the stale view stays (a later attempt
    /// refreshes again).
    fn refresh_view(&self) {
        if !self.cluster_aware {
            return;
        }
        match self.fetch_cluster_meta() {
            Ok(v) => {
                log::debug!("refreshed cluster view from {}: epoch {}", self.addr, v.epoch);
                *self.view.lock().unwrap() = Some(v);
            }
            Err(e) => log::debug!("cluster metadata refresh against {} failed: {e:#}", self.addr),
        }
    }

    fn fetch_cluster_meta(&self) -> Result<ClusterView> {
        let mut r = self.call_on(&self.main, OpCode::ClusterMeta, &[], CALL_TIMEOUT)?;
        Ok(r.cluster_view()?)
    }

    fn is_clustered_cached(&self) -> bool {
        self.view
            .lock()
            .unwrap()
            .as_ref()
            .map_or(false, |v| v.is_clustered())
    }

    /// The pooled connection to a peer broker, dialing on first use.
    /// The dial happens outside the pool lock so a slow peer never
    /// stalls routes to healthy ones.
    fn peer(&self, addr: &str) -> Result<Arc<RemoteBroker>> {
        if let Some(p) = self.peers.lock().unwrap().get(addr) {
            return Ok(p.clone());
        }
        let fresh = RemoteBroker::connect_peer(addr, self.api_key.as_deref())?;
        let mut peers = self.peers.lock().unwrap();
        Ok(peers.entry(addr.to_string()).or_insert(fresh).clone())
    }

    /// Evict a (presumed dead) pooled peer so the next route re-dials.
    fn forget_peer(&self, addr: &str) {
        self.peers.lock().unwrap().remove(addr);
    }

    /// Resolve `topic:partition` against the cached view: the broker to
    /// send to (`None` = this one) and the epoch to stamp the request
    /// with (`None` = unclustered, no fencing). A peer that won't dial
    /// falls back to the bootstrap — whose `not-leader` answer then
    /// drives a refresh.
    fn route(&self, topic: &str, partition: u32) -> (Option<Arc<RemoteBroker>>, Option<u64>) {
        let Some(view) = self.cached_view() else {
            return (None, None);
        };
        if !view.is_clustered() {
            return (None, None);
        }
        let epoch = Some(view.epoch);
        let Some(leader) = view.leader_of(topic, partition) else {
            return (None, epoch);
        };
        let Some(addr) = view.addr_of(leader) else {
            return (None, epoch);
        };
        if addr == self.addr {
            return (None, epoch);
        }
        match self.peer(addr) {
            Ok(p) => (Some(p), epoch),
            Err(e) => {
                log::debug!("dialing leader {addr} for {topic}:{partition} failed: {e:#}");
                (None, epoch)
            }
        }
    }

    /// Run a partition-addressed call against its current leader,
    /// refreshing the metadata and re-routing on `not-leader` answers
    /// and unreachable brokers. Any other error is definitive.
    fn routed<T>(
        &self,
        topic: &str,
        partition: u32,
        f: impl Fn(&RemoteBroker, Option<u64>) -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..ROUTE_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(ROUTE_RETRY_PAUSE);
                self.refresh_view();
            }
            let (target, epoch) = self.route(topic, partition);
            let b = target.as_deref().unwrap_or(self);
            match f(b, epoch) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let rendered = format!("{e:#}");
                    // `not-leader` is always a cluster signal; a
                    // transport-dead broker only warrants a re-route
                    // when the view says there is somewhere else to go.
                    let reroute = clusterctl::is_not_leader(&rendered)
                        || (self.is_clustered_cached() && rendered.contains("unreachable"));
                    if !reroute || !self.cluster_aware {
                        return Err(e);
                    }
                    if let Some(t) = &target {
                        self.forget_peer(t.addr());
                    }
                    log::debug!("re-routing {topic}:{partition} (attempt {attempt}): {rendered}");
                    last = Some(e);
                }
            }
        }
        let last = last.expect("routed loop exits early without an error");
        Err(last.context(format!(
            "no reachable leader for {topic}:{partition} after {ROUTE_ATTEMPTS} attempts"
        )))
    }

    fn fresh_stream(&self) -> Result<TcpStream> {
        let mut last: Option<std::io::Error> = None;
        let addrs = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving broker address '{}'", self.addr))?;
        for sa in addrs {
            match TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => {
                anyhow::Error::from(e).context(format!("connecting to broker {}", self.addr))
            }
            None => anyhow!("broker address '{}' resolved to nothing", self.addr),
        })
    }

    /// Submit one frame on `conn` and return the demux channel its
    /// response will arrive on. Register-then-write: the slot exists
    /// before the first response byte can possibly come back.
    fn submit(
        &self,
        conn: &MuxConn,
        op: OpCode,
        payload: &[u8],
    ) -> Result<(u64, Receiver<Delivery>)> {
        let corr = self.corr.fetch_add(1, Ordering::SeqCst);
        let rx = conn.register(corr)?;
        let frame = codec::encode_request(corr, op, payload);
        conn.writer
            .lock()
            .unwrap()
            .write_all(&frame)
            .with_context(|| format!("writing {op:?} frame"))?;
        Ok((corr, rx))
    }

    /// One request/response round trip on `lane`. Transport failures
    /// (including a response timeout) kill the connection and are
    /// retried once on a fresh one; a decoded server answer (ok *or*
    /// error) ends the call.
    fn call_on(
        &self,
        lane: &Lane,
        op: OpCode,
        payload: &[u8],
        wait_for: Duration,
    ) -> Result<Reader> {
        // Reject a frame the server is guaranteed to refuse before
        // shipping (and retrying!) megabytes of it: the peer would just
        // drop the connection without a response.
        if payload.len() as u64 + 9 > u64::from(codec::MAX_FRAME_BYTES) {
            bail!(
                "request payload of {} bytes exceeds the wire frame limit ({} bytes)",
                payload.len(),
                codec::MAX_FRAME_BYTES
            );
        }
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            let outcome = lane
                .get(self)
                .and_then(|conn| match self.try_call(&conn, op, payload, wait_for) {
                    Ok(answer) => Ok(answer),
                    Err(e) => {
                        lane.discard(&conn);
                        Err(e)
                    }
                });
            match outcome {
                Ok(answer) => return answer.map(Reader::new),
                Err(e) if attempt == 1 => {
                    log::debug!("broker call {op:?} failed ({e:#}); reconnecting to {}", self.addr);
                }
                Err(e) => {
                    return Err(e.context(format!("broker {} unreachable ({op:?})", self.addr)));
                }
            }
        }
    }

    /// Outer `Err` = transport failure (retryable); inner `Err` = the
    /// server's answer was an error (definitive).
    fn try_call(
        &self,
        conn: &MuxConn,
        op: OpCode,
        payload: &[u8],
        wait_for: Duration,
    ) -> Result<Result<Bytes, anyhow::Error>> {
        let (corr, rx) = self.submit(conn, op, payload)?;
        let body = match rx.recv_deadline(Instant::now() + wait_for) {
            Ok(Ok(body)) => body,
            Ok(Err(why)) => bail!("{why}"),
            Err(RecvError::Timeout) => bail!("no response within {wait_for:?}"),
            Err(RecvError::Disconnected) => bail!("connection reader exited"),
        };
        decode_response(corr, body)
    }
}

/// Split a response frame body into the definitive server answer.
/// Outer `Err` = the body itself was unreadable (transport-grade: the
/// connection is condemned); inner `Err` = the server answered with an
/// error message.
fn decode_response(corr: u64, body: Bytes) -> Result<Result<Bytes, anyhow::Error>> {
    let mut r = Reader::new(body.clone());
    let rcorr = r
        .u64()
        .map_err(|_| anyhow!("response too short for a correlation id"))?;
    if rcorr != corr {
        // The demux routes by corr, so this can only mean memory
        // corruption or a bug — but check anyway: it's one compare.
        bail!("correlation mismatch: sent {corr}, got {rcorr}");
    }
    let status = r.u8().map_err(|_| anyhow!("response missing status byte"))?;
    if status == STATUS_OK {
        Ok(Ok(body.slice(9..)))
    } else {
        let msg = r
            .str()
            .unwrap_or_else(|_| "unreadable error message".to_string());
        Ok(Err(anyhow!("{msg}")))
    }
}

/// An in-flight windowed produce on a [`RemoteBroker`]: the frame is
/// already written; `wait` parks on the demux channel for the answer.
struct RemoteProduceHandle {
    conn: Arc<MuxConn>,
    rx: Receiver<Delivery>,
    corr: u64,
    deadline: Instant,
}

impl ProduceHandle for RemoteProduceHandle {
    fn wait(&mut self) -> ProduceOutcome {
        let body = match self.rx.recv_deadline(self.deadline) {
            Ok(Ok(body)) => body,
            Ok(Err(why)) => return ProduceOutcome::TransportFailed(anyhow!("{why}")),
            Err(RecvError::Timeout) => {
                // The connection is wedged (or the server is): condemn
                // it so every sibling in-flight batch fails fast too.
                self.conn.kill();
                return ProduceOutcome::TransportFailed(anyhow!(
                    "no produce response within {:?}",
                    CALL_TIMEOUT
                ));
            }
            Err(RecvError::Disconnected) => {
                return ProduceOutcome::TransportFailed(anyhow!("connection reader exited"))
            }
        };
        match decode_response(self.corr, body) {
            Ok(Ok(payload)) => {
                let mut r = Reader::new(payload);
                match r.u64() {
                    Ok(base) => ProduceOutcome::Acked(base),
                    Err(_) => ProduceOutcome::TransportFailed(anyhow!(
                        "produce ack missing its base offset"
                    )),
                }
            }
            Ok(Err(server_err)) => ProduceOutcome::Rejected(format!("{server_err:#}")),
            Err(e) => {
                self.conn.kill();
                ProduceOutcome::TransportFailed(e)
            }
        }
    }

    fn epoch(&self) -> u64 {
        self.conn.epoch
    }
}

fn produce_payload(
    topic: &str,
    partition: u32,
    records: &[Record],
    producer_seq: Option<(u64, u64)>,
    epoch: Option<u64>,
) -> Vec<u8> {
    let mut p = Vec::new();
    codec::put_u32(&mut p, partition);
    codec::put_opt(&mut p, producer_seq.as_ref(), |o, (pid, seq)| {
        codec::put_u64(o, *pid);
        codec::put_u64(o, *seq);
    });
    codec::put_str(&mut p, topic);
    codec::put_records(
        &mut p,
        records.iter().enumerate().map(|(i, rec)| (i as u64, rec)),
    );
    // Metadata epoch rides at the tail so pre-cluster payloads parse
    // unchanged (the server reads it only if bytes remain).
    codec::put_opt(&mut p, epoch.as_ref(), |o, e| codec::put_u64(o, *e));
    p
}

impl RemoteBroker {
    /// The pipelined produce write, aimed at *this* broker (routing, if
    /// any, already happened). `route_epoch` is the metadata epoch the
    /// request gets fenced under.
    fn submit_produce(
        &self,
        topic: &str,
        partition: u32,
        records: &[Record],
        producer_seq: Option<(u64, u64)>,
        window_epoch: Option<u64>,
        route_epoch: Option<u64>,
    ) -> Box<dyn ProduceHandle> {
        let p = produce_payload(topic, partition, records, producer_seq, route_epoch);
        if p.len() as u64 + 9 > u64::from(codec::MAX_FRAME_BYTES) {
            // Definitive — no transport involved, and no retry could
            // ever make the frame fit.
            return Box::new(ReadyProduce::new(ProduceOutcome::Rejected(format!(
                "produce payload of {} bytes exceeds the wire frame limit ({} bytes)",
                p.len(),
                codec::MAX_FRAME_BYTES
            ))));
        }
        // With in-flight window neighbors (`window_epoch`), the batch
        // must go out on the exact connection that carried them — the
        // server's per-connection serial ordering is what makes a
        // failed window re-drivable without tripping the idempotent
        // dedup. Submitting on any *other* connection could land this
        // batch (higher seq) while a predecessor never arrives, turning
        // that predecessor's re-drive into a silently-swallowed
        // "duplicate". So on a mismatch or a dead connection we fail
        // the handle fast and let the producer drain + re-drive FIFO.
        // With an empty window the write is free to retry once on a
        // fresh connection (nothing has reached the broker if the write
        // itself fails).
        let attempts = if window_epoch.is_some() { 1 } else { 2 };
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            let submitted = self.main.get(self).and_then(|conn| {
                if let Some(we) = window_epoch {
                    if conn.epoch != we {
                        bail!(
                            "connection changed mid-window (epoch {} -> {}); \
                             draining the window before re-driving",
                            we,
                            conn.epoch
                        );
                    }
                }
                match self.submit(&conn, OpCode::Produce, &p) {
                    Ok((corr, rx)) => Ok((conn, corr, rx)),
                    Err(e) => {
                        self.main.discard(&conn);
                        Err(e)
                    }
                }
            });
            match submitted {
                Ok((conn, corr, rx)) => {
                    return Box::new(RemoteProduceHandle {
                        conn,
                        rx,
                        corr,
                        deadline: Instant::now() + CALL_TIMEOUT,
                    });
                }
                Err(e) if attempt < attempts => {
                    log::debug!(
                        "produce submit failed ({e:#}); reconnecting to {}",
                        self.addr
                    );
                }
                Err(e) => {
                    return Box::new(ReadyProduce::new(ProduceOutcome::TransportFailed(
                        e.context(format!("broker {} unreachable (Produce)", self.addr)),
                    )));
                }
            }
        }
    }
}

impl BrokerTransport for RemoteBroker {
    fn produce(
        &self,
        topic: &str,
        partition: u32,
        records: &[Record],
        _locality: ClientLocality,
        producer_seq: Option<(u64, u64)>,
    ) -> Result<u64> {
        self.routed(topic, partition, |b, epoch| {
            let p = produce_payload(topic, partition, records, producer_seq, epoch);
            let mut r = b.call_on(&b.main, OpCode::Produce, &p, CALL_TIMEOUT)?;
            Ok(r.u64()?)
        })
    }

    fn produce_submit(
        &self,
        topic: &str,
        partition: u32,
        records: &[Record],
        _locality: ClientLocality,
        producer_seq: Option<(u64, u64)>,
        window_epoch: Option<u64>,
    ) -> Box<dyn ProduceHandle> {
        // One route resolution, no refresh loop: a submit that lands on
        // a deposed leader comes back `Rejected(not-leader)`, and the
        // producer drains its window and re-drives through the sync
        // [`produce`](BrokerTransport::produce) path — which *does*
        // refresh and re-route.
        let (target, epoch) = self.route(topic, partition);
        let b = target.as_deref().unwrap_or(self);
        b.submit_produce(topic, partition, records, producer_seq, window_epoch, epoch)
    }

    fn fetch_batch(
        &self,
        topic: &str,
        partition: u32,
        from: u64,
        max: usize,
        _locality: ClientLocality,
    ) -> Result<RecordBatch> {
        let records = self.routed(topic, partition, |b, epoch| {
            let mut p = Vec::new();
            codec::put_u32(&mut p, partition);
            codec::put_u64(&mut p, from);
            codec::put_u32(&mut p, max.min(u32::MAX as usize) as u32);
            codec::put_str(&mut p, topic);
            codec::put_opt(&mut p, epoch.as_ref(), |o, e| codec::put_u64(o, *e));
            let mut r = b.call_on(&b.main, OpCode::FetchBatch, &p, CALL_TIMEOUT)?;
            // Zero-copy on this side of the wire too: every record is a
            // slice of the one response buffer.
            Ok(r.records()?)
        })?;
        Ok(RecordBatch {
            topic: Arc::from(topic),
            partition,
            records,
        })
    }

    fn offsets(&self, topic: &str, partition: u32) -> Result<(u64, u64)> {
        self.routed(topic, partition, |b, _epoch| {
            let mut p = Vec::new();
            codec::put_u32(&mut p, partition);
            codec::put_str(&mut p, topic);
            let mut r = b.call_on(&b.main, OpCode::Offsets, &p, CALL_TIMEOUT)?;
            Ok((r.u64()?, r.u64()?))
        })
    }

    fn create_topic(&self, topic: &str, partitions: u32) -> Result<u32> {
        let mut p = Vec::new();
        codec::put_u32(&mut p, partitions);
        codec::put_str(&mut p, topic);
        let mut r = self.call_on(&self.main, OpCode::CreateTopic, &p, CALL_TIMEOUT)?;
        let assigned = r.u32()?;
        // The server applies CreateTopic locally only (fanning out
        // server-side would ping-pong between brokers), so a clustered
        // *client* declares the topic on every alive broker — each one
        // may lead some of its partitions. Best-effort beyond the
        // bootstrap: replication's discovery sweep backfills any broker
        // the fan-out missed.
        if let Some(view) = self.cached_view() {
            if view.is_clustered() {
                for b in view.brokers.iter().filter(|b| b.alive && b.addr != self.addr) {
                    let fanned = self
                        .peer(&b.addr)
                        .and_then(|peer| {
                            peer.call_on(&peer.main, OpCode::CreateTopic, &p, CALL_TIMEOUT)
                        });
                    if let Err(e) = fanned {
                        log::warn!("declaring topic '{topic}' on broker {}: {e:#}", b.id);
                    }
                }
            }
        }
        Ok(assigned)
    }

    fn topic_partitions(&self, topic: &str) -> Result<Option<u32>> {
        let mut p = Vec::new();
        codec::put_str(&mut p, topic);
        let mut r = self.call_on(&self.main, OpCode::Metadata, &p, CALL_TIMEOUT)?;
        Ok(r.opt(|r| r.u32())?)
    }

    fn topic_names(&self) -> Result<Vec<String>> {
        let mut r = self.call_on(&self.main, OpCode::ListTopics, &[], CALL_TIMEOUT)?;
        Ok(r.strings()?)
    }

    fn alloc_producer_id(&self) -> Result<u64> {
        let mut r = self.call_on(&self.main, OpCode::AllocProducerId, &[], CALL_TIMEOUT)?;
        Ok(r.u64()?)
    }

    fn join_group(
        &self,
        group_id: &str,
        member_id: &str,
        topics: &[String],
        assignor: Assignor,
    ) -> Result<GroupMembership> {
        let mut p = Vec::new();
        codec::put_u8(&mut p, codec::assignor_to_u8(assignor));
        codec::put_str(&mut p, group_id);
        codec::put_str(&mut p, member_id);
        codec::put_strings(&mut p, topics);
        let mut r = self.call_on(&self.main, OpCode::JoinGroup, &p, CALL_TIMEOUT)?;
        Ok(r.membership()?)
    }

    fn leave_group(&self, group_id: &str, member_id: &str) -> Result<()> {
        let mut p = Vec::new();
        codec::put_str(&mut p, group_id);
        codec::put_str(&mut p, member_id);
        self.call_on(&self.main, OpCode::LeaveGroup, &p, CALL_TIMEOUT)?;
        Ok(())
    }

    fn heartbeat(&self, group_id: &str, member_id: &str) -> Result<Option<GroupMembership>> {
        let mut p = Vec::new();
        codec::put_str(&mut p, group_id);
        codec::put_str(&mut p, member_id);
        let mut r = self.call_on(&self.main, OpCode::Heartbeat, &p, CALL_TIMEOUT)?;
        Ok(r.opt(|r| r.membership())?)
    }

    fn commit_offsets(&self, group_id: &str, offsets: &[(TopicPartition, u64)]) -> Result<()> {
        let mut p = Vec::new();
        codec::put_str(&mut p, group_id);
        codec::put_u32(&mut p, offsets.len() as u32);
        for ((topic, partition), off) in offsets {
            codec::put_str(&mut p, topic);
            codec::put_u32(&mut p, *partition);
            codec::put_u64(&mut p, *off);
        }
        self.call_on(&self.main, OpCode::CommitOffsets, &p, CALL_TIMEOUT)?;
        Ok(())
    }

    fn committed_offset(&self, group_id: &str, tp: &TopicPartition) -> Result<Option<u64>> {
        let mut p = Vec::new();
        codec::put_str(&mut p, group_id);
        codec::put_str(&mut p, &tp.0);
        codec::put_u32(&mut p, tp.1);
        let mut r = self.call_on(&self.main, OpCode::CommittedOffset, &p, CALL_TIMEOUT)?;
        Ok(r.opt(|r| r.u64())?)
    }

    fn wait_for_data(
        &self,
        assignments: &[(TopicPartition, u64)],
        group: Option<(&str, u64)>,
        timeout: Duration,
    ) -> Result<bool> {
        // Clustered routing: the poll parks on ONE broker, so aim it at
        // the broker leading the most assigned partitions — with group
        // coordination it must stay on the bootstrap (that's where the
        // group's wait-set lives). Either way, when some assignments
        // are led elsewhere the park is capped so data arriving there
        // turns into a prompt wake instead of a full-timeout stall.
        let mut target: Option<Arc<RemoteBroker>> = None;
        let mut timeout = timeout;
        if let Some(view) = self.cached_view() {
            if view.is_clustered() {
                let mut per_addr: HashMap<&str, usize> = HashMap::new();
                for ((t, p), _) in assignments {
                    if let Some(addr) = view.leader_of(t, *p).and_then(|l| view.addr_of(l)) {
                        *per_addr.entry(addr).or_insert(0) += 1;
                    }
                }
                let best = per_addr
                    .iter()
                    .max_by_key(|(_, n)| **n)
                    .map(|(addr, _)| *addr)
                    .unwrap_or(self.addr.as_str());
                let split = per_addr.len() > 1
                    || (per_addr.len() == 1 && group.is_some() && best != self.addr);
                let aim = if group.is_some() { self.addr.as_str() } else { best };
                if split || aim != best {
                    timeout = timeout.min(SPLIT_WAIT_CAP);
                }
                if aim != self.addr {
                    if let Ok(peer) = self.peer(aim) {
                        target = Some(peer);
                    }
                }
            }
        }
        let b = target.as_deref().unwrap_or(self);
        let mut p = Vec::new();
        codec::put_u64(&mut p, timeout.as_millis().min(u64::MAX as u128) as u64);
        codec::put_opt(&mut p, group.as_ref(), |o, (gid, gen)| {
            codec::put_str(o, gid);
            codec::put_u64(o, *gen);
        });
        codec::put_u32(&mut p, assignments.len() as u32);
        for ((topic, partition), pos) in assignments {
            codec::put_str(&mut p, topic);
            codec::put_u32(&mut p, *partition);
            codec::put_u64(&mut p, *pos);
        }
        // The server clamps the park (its MAX_WAIT_SLICE); our wait
        // just needs to outlast whatever it grants. The dedicated wait
        // lane means this parked call shares no socket with produces.
        let wait_for = timeout.min(Duration::from_secs(3600)) + WAIT_MARGIN;
        let mut r = b.call_on(&b.wait, OpCode::FetchWait, &p, wait_for)?;
        Ok(r.bool()?)
    }

    fn cluster_meta(&self) -> Result<ClusterView> {
        self.fetch_cluster_meta()
    }

    fn cluster_update(&self, view: &ClusterView) -> Result<()> {
        let mut p = Vec::new();
        codec::put_cluster_view(&mut p, view);
        self.call_on(&self.main, OpCode::ClusterUpdate, &p, CALL_TIMEOUT)?;
        Ok(())
    }

    fn replica_fetch(
        &self,
        topic: &str,
        partition: u32,
        from: u64,
        max: usize,
        ack: u64,
    ) -> Result<(u64, Vec<(u64, Record)>)> {
        // Deliberately unrouted: a replication pull is aimed at the
        // specific broker this handle was dialed for.
        let mut p = Vec::new();
        codec::put_u32(&mut p, partition);
        codec::put_u64(&mut p, from);
        codec::put_u32(&mut p, max.min(u32::MAX as usize) as u32);
        codec::put_u64(&mut p, ack);
        codec::put_str(&mut p, topic);
        let mut r = self.call_on(&self.main, OpCode::ReplicaFetch, &p, CALL_TIMEOUT)?;
        let hwm = r.u64()?;
        let records = r.records()?;
        Ok((hwm, records))
    }

    fn add_metric(&self, name: &str, delta: u64) {
        // One-way by protocol: write the frame on the dedicated metrics
        // connection and return — no response to wait for. Best-effort:
        // one reconnect attempt, then the delta is dropped (and logged).
        let mut p = Vec::new();
        codec::put_u64(&mut p, delta);
        codec::put_str(&mut p, name);
        let corr = self.corr.fetch_add(1, Ordering::SeqCst);
        let frame = codec::encode_request(corr, OpCode::Metric, &p);
        let mut conn = self.metrics_conn.lock().unwrap();
        // Proactive idle expiry, same reasoning as the mux lanes: the
        // server's sweep is about to close a quiet metrics channel, and
        // a one-way write down a dead socket is silently lost.
        if conn
            .as_ref()
            .map_or(false, |(_, at)| at.elapsed() >= CLIENT_IDLE_EXPIRY)
        {
            *conn = None;
        }
        for _ in 0..2 {
            if conn.is_none() {
                match self.fresh_stream() {
                    Ok(c) => *conn = Some((c, Instant::now())),
                    Err(e) => {
                        log::debug!("dropping metric '{name}' (+{delta}): {e:#}");
                        return;
                    }
                }
            }
            if let Some((c, at)) = conn.as_mut() {
                if c.write_all(&frame).is_ok() {
                    *at = Instant::now();
                    return;
                }
            }
            // Stale connection (e.g. idle-timed-out server side):
            // reconnect once and retry the write.
            *conn = None;
        }
        log::debug!("dropping metric '{name}' (+{delta}): connection lost");
    }
}
