//! Fig 8 walkthrough: data-stream reuse over the distributed log (§V).
//!
//! * stream C1 ("green data") is ingested once for deployment D1, then
//!   *reused* by D2 via a control-message re-send (tens of bytes);
//! * stream C2 is reused by two more deployments (the paper's D3/D5);
//! * the broker runs on a ManualClock, so we then fast-forward past the
//!   retention window, sweep the log, and show C1 turning into Fig 8's
//!   "expiring data stream" that can no longer be reused.
//!
//! ```sh
//! make artifacts && cargo run --release --example stream_reuse
//! ```

use kafka_ml::broker::{BrokerConfig, CleanupPolicy, ClientLocality, LogConfig};
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::json::Json;
use kafka_ml::ml::hcopd_dataset;
use kafka_ml::util::clock::ManualClock;
use std::sync::Arc;
use std::time::Duration;

fn raw() -> Json {
    Json::obj(vec![
        ("dtype", Json::str("f32")),
        ("shape", Json::arr(vec![Json::from(8u64)])),
    ])
}

fn main() -> anyhow::Result<()> {
    // Small segments + 1 h retention, on a hand-advanced clock.
    let clock = ManualClock::new(1_700_000_000_000);
    let kml = KafkaMl::start(KafkaMlConfig {
        broker: BrokerConfig {
            log: LogConfig {
                segment_bytes: 2048,
                retention_ms: Some(3_600_000),
                retention_bytes: None,
                cleanup_policy: CleanupPolicy::Delete,
                ..LogConfig::default()
            },
            ..Default::default()
        },
        clock: Some(Arc::new(clock.clone())),
        ..Default::default()
    })?;
    let model = kml.create_model("reuse-mlp")?;
    let conf = kml.create_configuration("reuse", &[model])?;
    let quick = TrainParams { epochs: 2, ..Default::default() };

    // ---- stream C1 -> D1, reused by D2 --------------------------------
    let d1 = kml.deploy_training(conf, &quick)?;
    let green = hcopd_dataset(120, 8, 1);
    let c1 = kml.send_stream(
        d1.id,
        &green.samples,
        "stream-1",
        "RAW",
        &raw(),
        0.0,
        ClientLocality::External,
    )?;
    kml.wait_training(&d1, Duration::from_secs(300))?;
    kml.wait_control_logged(d1.id, Duration::from_secs(10))?;
    println!("D1 trained from fresh stream C1 = {}", c1.stream.format());

    let records_before = kml.cluster.offsets("stream-1", 0)?.1;
    let d2 = kml.deploy_training(conf, &quick)?;
    let resent = kml.reuse().resend(d1.id, d2.id, ClientLocality::External)?;
    kml.wait_training(&d2, Duration::from_secs(300))?;
    let records_after = kml.cluster.offsets("stream-1", 0)?.1;
    println!(
        "D2 trained by REUSING C1: {} re-sent as a {}-byte control message;\n\
         data topic unchanged ({} -> {} records)",
        resent.stream.format(),
        resent.encode().len(),
        records_before,
        records_after
    );
    assert_eq!(records_before, records_after);

    // ---- stream C2 -> D3, reused by D4 and D5 --------------------------
    let d3 = kml.deploy_training(conf, &quick)?;
    let blue = hcopd_dataset(100, 8, 2);
    kml.send_stream(
        d3.id,
        &blue.samples,
        "stream-2",
        "RAW",
        &raw(),
        0.0,
        ClientLocality::External,
    )?;
    kml.wait_training(&d3, Duration::from_secs(300))?;
    kml.wait_control_logged(d3.id, Duration::from_secs(10))?;
    for _ in 0..2 {
        let dn = kml.deploy_training(conf, &quick)?;
        kml.reuse().resend(d3.id, dn.id, ClientLocality::External)?;
        kml.wait_training(&dn, Duration::from_secs(300))?;
    }
    println!("D3 trained from stream C2; D4 and D5 reused it (1 ingest, 3 trainings)");

    // ---- expiry: fast-forward past retention ---------------------------
    println!("\nfast-forwarding the broker clock 2 hours…");
    clock.advance_ms(2 * 3_600_000);
    // Fresh records close the old segments, then the cleaner sweeps.
    let fmt = kafka_ml::formats::registry("RAW", &raw())?;
    let fresh = hcopd_dataset(60, 8, 3);
    for s in &fresh.samples {
        kml.cluster.produce(
            "stream-1",
            0,
            &[fmt.encode(&s.features, s.label)?],
            ClientLocality::External,
            None,
        )?;
    }
    let removed = kml.cluster.run_retention();
    println!("retention sweep removed {removed} records");

    println!("\nstream registry (the paper's Web-UI reuse list):");
    for (e, avail) in kml.reuse().list_streams() {
        println!(
            "  deployment {:>2} -> [{}:{}:{}:{}] : {:?}",
            e.deployment_id, e.topic, e.partition, e.offset, e.length, avail
        );
    }

    // Reusing the expired C1 now fails loudly.
    let d_late = kml.deploy_training(conf, &quick)?;
    match kml.reuse().resend(d1.id, d_late.id, ClientLocality::External) {
        Err(e) => println!("\nreuse of expired C1 correctly refused:\n  {e}"),
        Ok(_) => anyhow::bail!("expired stream should not be reusable"),
    }

    kml.shutdown();
    Ok(())
}
