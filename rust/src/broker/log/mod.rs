//! The distributed log: an append-only, segmented, offset-addressed
//! record store with Kafka's retention semantics and **tiered, durable
//! segment storage**.
//!
//! This is the substrate under the paper's §V contribution: because
//! records survive consumption until retention expires them, a data
//! stream identified by `[topic:partition:offset:length]` can be re-read
//! by any number of later deployments. With `StorageMode::Tiered` that
//! promise also survives a broker restart — the log recovers from its
//! segment files, so `ReuseManager`'s availability answers are as
//! durable as the retention policy, not as the process lifetime.
//!
//! # Tiers
//!
//! * **Active segment** — always in memory ([`segment::MemSegment`]).
//!   Appends and tail reads never touch the disk, and fetched payloads
//!   share the producer's original allocation (the PR-1 zero-copy path).
//! * **Sealed segments** — when the active segment exceeds
//!   `segment_bytes` (counting the incoming record) it is *sealed*:
//!   encoded into the framed on-disk format ([`format`]) and written
//!   atomically (tmp + rename + fsync) as
//!   `data_dir/<topic>/<partition>/<base-offset>.seg`. Only the index
//!   (offset → frame position) stays in memory.
//! * **Resident buffers** — reading a sealed segment makes its
//!   validated prefix *resident*: one shared [`Bytes`] allocation from
//!   which every record is an O(1) slice view (`Bytes::ptr_eq`
//!   observable). On Linux residency is a read-only `mmap(2)` of the
//!   segment file — becoming resident copies nothing; pages fault in
//!   from the page cache as frames are decoded — with a plain-read
//!   fallback off Linux or under `KAFKA_ML_NO_MMAP=1`. An LRU bounded
//!   by `max_resident_bytes` caps how much stays resident, charging
//!   each buffer's full backing length (mapped region or heap vector);
//!   eviction hints the kernel with `madvise(DONTNEED)` and drops the
//!   broker's handle, so the address space unmaps as soon as the last
//!   consumer slice drops. Residency therefore moves through three
//!   tiers: in-memory (active) → mapped (sealed, resident) → evicted
//!   (sealed, index only).
//!
//! In `StorageMode::InMemory` (the default; tests and benches) closed
//! segments simply stay in memory — exactly the pre-tiered behaviour.
//!
//! # Crash recovery
//!
//! [`SegmentedLog::open`] rescans the partition directory: segment
//! files are walked frame-by-frame, each frame proven by its CRC-32; a
//! torn tail frame (crash mid-write) is truncated away and
//! `next_offset` resumes after the last valid frame. The active segment
//! is sealed on [`SegmentedLog::flush`]/drop, so a clean shutdown loses
//! nothing and a hard crash loses at most the unsealed active tail.
//!
//! # Retention (the paper's §V list)
//!
//! * `retention.bytes` — drop whole old segments once the partition
//!   exceeds the cap (default: unlimited, as in Kafka);
//! * `retention.ms` — drop segments whose newest record is older
//!   (default 7 days, as in Kafka);
//! * cleanup policy `Delete` (Kafka-ML's choice) or `Compact` (keep the
//!   last value per key — implemented for completeness; the paper
//!   explains why Kafka-ML prefers delete).
//!
//! Deletion happens at *segment* granularity, exactly like Kafka: the
//! active (last) segment is never deleted. On the disk tier, deletion
//! removes segment *files* and compaction atomically rewrites them.

// `pub(crate)`: the wire protocol ([`crate::broker::wire`]) reuses this
// framing discipline (length prefix + CRC-32 + zero-copy `Bytes` decode)
// for records travelling over the socket.
pub(crate) mod format;
mod segment;

use super::record::Record;
use crate::util::bytes::Bytes;
use crate::util::clock::{SharedClock, TimestampMs};
use anyhow::{bail, Context, Result};
use segment::{MemSegment, SealedSegment};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CleanupPolicy {
    Delete,
    Compact,
}

/// Where closed segments live.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageMode {
    /// Every segment stays in memory (tests, benches, ephemeral runs).
    InMemory,
    /// The active segment stays in memory; rolled segments are sealed
    /// to files under `data_dir/<topic>/<partition>/` and recovered on
    /// open.
    Tiered { data_dir: PathBuf },
}

impl StorageMode {
    /// Convenience constructor for the tiered mode.
    pub fn tiered(data_dir: impl Into<PathBuf>) -> StorageMode {
        StorageMode::Tiered {
            data_dir: data_dir.into(),
        }
    }

    /// `data_dir/<sanitized topic>` (None in memory mode).
    pub fn topic_dir(&self, topic: &str) -> Option<PathBuf> {
        match self {
            StorageMode::InMemory => None,
            StorageMode::Tiered { data_dir } => Some(data_dir.join(sanitize_topic(topic))),
        }
    }

    /// `data_dir/<sanitized topic>/<partition>` (None in memory mode).
    pub fn partition_dir(&self, topic: &str, partition: u32) -> Option<PathBuf> {
        self.topic_dir(topic).map(|d| d.join(partition.to_string()))
    }
}

/// Make a topic name safe as a directory name. Kafka restricts topic
/// names to `[a-zA-Z0-9._-]` already; anything outside that set maps to
/// `_` (the raw name is preserved in the topic's `topic.meta` file).
pub fn sanitize_topic(name: &str) -> String {
    if name.is_empty() {
        return "_".to_string();
    }
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogConfig {
    /// Roll to a new segment once appending would push it past this
    /// many bytes (the incoming record's size counts).
    pub segment_bytes: usize,
    /// `retention.bytes` (None = unlimited, Kafka default).
    pub retention_bytes: Option<u64>,
    /// `retention.ms` (None = keep forever; Kafka default 7 days).
    pub retention_ms: Option<u64>,
    pub cleanup_policy: CleanupPolicy,
    /// In-memory only, or spill sealed segments to disk.
    pub storage: StorageMode,
    /// Budget (per partition) for resident sealed-segment buffers. The
    /// LRU keeps at least the most recently touched buffer even when a
    /// single segment exceeds the budget.
    pub max_resident_bytes: usize,
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig {
            segment_bytes: 1 << 20, // 1 MiB
            retention_bytes: None,
            retention_ms: Some(7 * 24 * 3600 * 1000),
            cleanup_policy: CleanupPolicy::Delete,
            storage: StorageMode::InMemory,
            max_resident_bytes: 64 << 20, // 64 MiB
        }
    }
}

/// The persisted face of a topic: what `topic.meta` records next to the
/// partition directories so a restarted broker re-creates the topic
/// *as configured*, not with broker defaults.
///
/// Two formats coexist on disk:
///
/// * **legacy** — the whole file is the raw topic name (what early
///   tiered-storage builds wrote). Decodes to a name with no overrides.
/// * **v2** — first line `v2`, then `key=value` lines for the name, the
///   partition count, and every [`LogConfig`] knob except `storage`
///   (storage placement is the *recovering* broker's own concern — a
///   data dir moved to another host must not resurrect old paths).
///
/// Decoding never fails: unknown keys and malformed values are ignored
/// (forward compatibility), and a file that is not v2 is read as a
/// legacy raw name.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TopicMeta {
    pub name: String,
    pub partitions: Option<u32>,
    pub segment_bytes: Option<usize>,
    /// `Some(inner)` = the file specified `retention_bytes` (inner
    /// `None` encodes as the literal `none` = unlimited); outer `None`
    /// = unspecified, keep the recovering broker's default.
    pub retention_bytes: Option<Option<u64>>,
    pub retention_ms: Option<Option<u64>>,
    pub cleanup_policy: Option<CleanupPolicy>,
    pub max_resident_bytes: Option<usize>,
}

impl TopicMeta {
    /// The meta for a topic created with `config` — everything pinned.
    pub fn of(name: &str, partitions: u32, config: &LogConfig) -> TopicMeta {
        TopicMeta {
            name: name.to_string(),
            partitions: Some(partitions),
            segment_bytes: Some(config.segment_bytes),
            retention_bytes: Some(config.retention_bytes),
            retention_ms: Some(config.retention_ms),
            cleanup_policy: Some(config.cleanup_policy),
            max_resident_bytes: Some(config.max_resident_bytes),
        }
    }

    pub fn encode(&self) -> String {
        fn opt_u64(v: Option<u64>) -> String {
            v.map_or_else(|| "none".to_string(), |n| n.to_string())
        }
        let mut s = String::from("v2\n");
        s.push_str(&format!("name={}\n", self.name));
        if let Some(p) = self.partitions {
            s.push_str(&format!("partitions={p}\n"));
        }
        if let Some(b) = self.segment_bytes {
            s.push_str(&format!("segment_bytes={b}\n"));
        }
        if let Some(b) = self.retention_bytes {
            s.push_str(&format!("retention_bytes={}\n", opt_u64(b)));
        }
        if let Some(ms) = self.retention_ms {
            s.push_str(&format!("retention_ms={}\n", opt_u64(ms)));
        }
        if let Some(c) = self.cleanup_policy {
            let c = match c {
                CleanupPolicy::Delete => "delete",
                CleanupPolicy::Compact => "compact",
            };
            s.push_str(&format!("cleanup={c}\n"));
        }
        if let Some(b) = self.max_resident_bytes {
            s.push_str(&format!("max_resident_bytes={b}\n"));
        }
        s
    }

    pub fn decode(raw: &str) -> TopicMeta {
        let mut lines = raw.lines();
        if lines.next().map(str::trim) != Some("v2") {
            // Legacy file: the whole content is the raw topic name.
            return TopicMeta {
                name: raw.trim().to_string(),
                ..TopicMeta::default()
            };
        }
        fn opt_u64(v: &str) -> Option<Option<u64>> {
            if v == "none" {
                Some(None)
            } else {
                v.parse::<u64>().ok().map(Some)
            }
        }
        let mut meta = TopicMeta::default();
        for line in lines {
            let Some((key, value)) = line.split_once('=') else {
                continue;
            };
            let v = value.trim();
            match key.trim() {
                // The name is the one value that may legitimately
                // contain '=' or spaces — take the rest of the line raw.
                "name" => meta.name = value.to_string(),
                "partitions" => meta.partitions = v.parse().ok(),
                "segment_bytes" => meta.segment_bytes = v.parse().ok(),
                "retention_bytes" => meta.retention_bytes = opt_u64(v),
                "retention_ms" => meta.retention_ms = opt_u64(v),
                "cleanup" => {
                    meta.cleanup_policy = match v {
                        "delete" => Some(CleanupPolicy::Delete),
                        "compact" => Some(CleanupPolicy::Compact),
                        _ => None,
                    }
                }
                "max_resident_bytes" => meta.max_resident_bytes = v.parse().ok(),
                _ => {} // forward compatibility
            }
        }
        meta
    }

    /// `base` (the recovering broker's config, which supplies `storage`
    /// and any knob this meta leaves unspecified) overridden by every
    /// knob the meta pins.
    pub fn apply_to(&self, base: &LogConfig) -> LogConfig {
        let mut cfg = base.clone();
        if let Some(b) = self.segment_bytes {
            cfg.segment_bytes = b;
        }
        if let Some(b) = self.retention_bytes {
            cfg.retention_bytes = b;
        }
        if let Some(ms) = self.retention_ms {
            cfg.retention_ms = ms;
        }
        if let Some(c) = self.cleanup_policy {
            cfg.cleanup_policy = c;
        }
        if let Some(b) = self.max_resident_bytes {
            cfg.max_resident_bytes = b;
        }
        cfg
    }
}

#[derive(Debug)]
enum Segment {
    Mem(MemSegment),
    Sealed(SealedSegment),
}

impl Segment {
    fn first_offset(&self) -> Option<u64> {
        match self {
            Segment::Mem(m) => m.first_offset(),
            Segment::Sealed(s) => s.first_offset(),
        }
    }

    fn last_offset(&self) -> Option<u64> {
        match self {
            Segment::Mem(m) => m.last_offset(),
            Segment::Sealed(s) => s.last_offset(),
        }
    }

    fn record_count(&self) -> usize {
        match self {
            Segment::Mem(m) => m.records.len(),
            Segment::Sealed(s) => s.record_count(),
        }
    }

    fn size_bytes(&self) -> usize {
        match self {
            Segment::Mem(m) => m.size_bytes,
            Segment::Sealed(s) => s.size_bytes,
        }
    }

    fn max_timestamp(&self) -> TimestampMs {
        match self {
            Segment::Mem(m) => m.max_timestamp,
            Segment::Sealed(s) => s.max_timestamp,
        }
    }

    fn is_empty(&self) -> bool {
        self.record_count() == 0
    }
}

/// A tiered segmented log for one partition.
#[derive(Debug)]
pub struct SegmentedLog {
    config: LogConfig,
    clock: SharedClock,
    /// Partition data directory (None in memory mode).
    dir: Option<PathBuf>,
    /// Invariant: the back segment (the active one) is always `Mem`.
    segments: VecDeque<Segment>,
    next_offset: u64,
    /// Bases of resident sealed segments, least recently used first.
    resident_order: VecDeque<u64>,
    resident_bytes: usize,
}

impl SegmentedLog {
    /// An anonymous log (tests/benches). For tiered storage prefer
    /// [`SegmentedLog::open`] with the real topic/partition identity —
    /// this constructor files segments under `<data_dir>/log/0`.
    pub fn new(config: LogConfig, clock: SharedClock) -> SegmentedLog {
        SegmentedLog::open(config, clock, "log", 0).expect("opening segmented log")
    }

    /// Open the log of `topic`:`partition`, recovering sealed segments
    /// from disk in tiered mode (see the module docs for the recovery
    /// protocol). In memory mode this never fails and never touches the
    /// filesystem.
    pub fn open(
        config: LogConfig,
        clock: SharedClock,
        topic: &str,
        partition: u32,
    ) -> Result<SegmentedLog> {
        let dir = config.storage.partition_dir(topic, partition);
        let mut log = SegmentedLog {
            config,
            clock,
            dir: dir.clone(),
            segments: VecDeque::new(),
            next_offset: 0,
            resident_order: VecDeque::new(),
            resident_bytes: 0,
        };
        if let Some(dir) = &dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating partition dir {}", dir.display()))?;
            log.recover_segments(dir)?;
        }
        log.segments.push_back(Segment::Mem(MemSegment::new()));
        Ok(log)
    }

    fn recover_segments(&mut self, dir: &PathBuf) -> Result<()> {
        let mut files: Vec<(u64, PathBuf)> = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("scanning partition dir {}", dir.display()))?;
        for entry in entries.flatten() {
            let path = entry.path();
            let base = path
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(format::parse_segment_file_name);
            if let Some(base) = base {
                files.push((base, path));
            }
        }
        files.sort();
        let mut prev_last: Option<u64> = None;
        for (base, path) in files {
            let Some(recovered) = SealedSegment::recover(&path, base)? else {
                // Not one decodable frame: a fully torn file.
                log::warn!("removing unrecoverable segment {}", path.display());
                let _ = std::fs::remove_file(&path);
                continue;
            };
            let seg = recovered.segment;
            if let (Some(prev), Some(first)) = (prev_last, seg.first_offset()) {
                if first <= prev {
                    log::warn!(
                        "segment {} overlaps recovered offsets ({first} <= {prev}); skipping",
                        seg.path.display()
                    );
                    continue;
                }
            }
            if recovered.torn {
                log::warn!(
                    "recovered {} with a truncated tail ({} records kept)",
                    seg.path.display(),
                    seg.record_count()
                );
            }
            prev_last = seg.last_offset().or(prev_last);
            // No buffer is retained from the scan: recovery validates,
            // reads re-load lazily, so boot memory stays flat however
            // much retention sits on disk.
            self.segments.push_back(Segment::Sealed(seg));
        }
        self.next_offset = prev_last.map(|l| l + 1).unwrap_or(0);
        Ok(())
    }

    /// Append one record; returns its offset. Stamps the record with the
    /// broker clock if the producer left timestamp 0.
    ///
    /// The roll check accounts for the *incoming* record: a segment
    /// rolls before an append that would push it past `segment_bytes`,
    /// so segments cannot overshoot the cap by one arbitrarily large
    /// record (an empty active segment always accepts, however big the
    /// record).
    pub fn append(&mut self, mut record: Record) -> u64 {
        if record.timestamp_ms == 0 {
            record.timestamp_ms = self.clock.now_ms();
        }
        let offset = self.next_offset;
        self.next_offset += 1;

        let incoming = record.size_bytes();
        let roll = match self.segments.back() {
            Some(Segment::Mem(m)) => {
                !m.records.is_empty() && m.size_bytes + incoming > self.config.segment_bytes
            }
            _ => false,
        };
        if roll {
            self.roll_active();
        }
        match self.segments.back_mut() {
            Some(Segment::Mem(m)) => m.push(offset, record),
            _ => unreachable!("the active segment is always in memory"),
        }
        offset
    }

    /// Close the active segment: seal it to disk in tiered mode (in
    /// memory mode it just stays as a closed in-memory segment), then
    /// start a fresh active segment.
    fn roll_active(&mut self) {
        if self.dir.is_some() {
            if let Err(e) = self.seal_active() {
                // Degrade to the in-memory tier rather than losing the
                // append or poisoning the partition: the segment stays
                // a closed MemSegment.
                log::error!("sealing rolled segment failed (kept in memory): {e:#}");
            }
        }
        self.segments.push_back(Segment::Mem(MemSegment::new()));
    }

    /// Seal the (non-empty, in-memory) active segment to its file.
    fn seal_active(&mut self) -> Result<()> {
        let dir = self.dir.clone().context("sealing requires tiered storage")?;
        let idx = self.segments.len() - 1;
        let (base, records) = match &self.segments[idx] {
            Segment::Mem(m) => {
                let base = m.first_offset().context("sealing an empty segment")?;
                let records: Vec<(u64, Record)> = m
                    .offsets
                    .iter()
                    .copied()
                    .zip(m.records.iter().cloned())
                    .collect();
                (base, records)
            }
            Segment::Sealed(_) => bail!("active segment is not in memory"),
        };
        let (sealed, buf) = SealedSegment::write(&dir, base, &records)?;
        self.segments[idx] = Segment::Sealed(sealed);
        self.admit_resident(idx, buf);
        Ok(())
    }

    /// Persist the active segment (tiered mode): seal it and start a
    /// fresh one. No-op in memory mode or when the active segment is
    /// empty. Called on drop, so a clean shutdown loses nothing.
    pub fn flush(&mut self) -> Result<()> {
        if self.dir.is_none() {
            return Ok(());
        }
        if self.segments.back().map(|s| s.is_empty()).unwrap_or(true) {
            return Ok(());
        }
        self.seal_active()?;
        self.segments.push_back(Segment::Mem(MemSegment::new()));
        Ok(())
    }

    /// Read up to `max` records starting at `from` (inclusive). Records
    /// below the log-start offset are skipped (they were retained away).
    ///
    /// Zero-copy on both tiers: records from in-memory segments share
    /// the producer's payload allocations (`Record::clone` is an Arc
    /// bump); records from one sealed segment are slice views of that
    /// segment's single resident buffer.
    pub fn read(&mut self, from: u64, max: usize) -> Vec<(u64, Record)> {
        let mut out = Vec::new();
        for i in 0..self.segments.len() {
            if out.len() >= max {
                break;
            }
            if self.segments[i].last_offset().map(|l| l < from).unwrap_or(true) {
                continue;
            }
            if matches!(self.segments[i], Segment::Sealed(_)) {
                let Some(buf) = self.ensure_resident(i) else {
                    // Unreadable file: logged inside; serve what we can.
                    continue;
                };
                if let Segment::Sealed(s) = &self.segments[i] {
                    s.read_into(&buf, from, max, &mut out);
                }
            } else if let Segment::Mem(m) = &self.segments[i] {
                m.read_into(from, max, &mut out);
            }
        }
        out
    }

    // ---- residency (LRU of sealed-segment buffers) -------------------------

    /// Load (or touch) the resident buffer of the sealed segment at
    /// `idx`. Returns None for in-memory segments and on IO errors.
    ///
    /// A cold load maps exactly the validated prefix (`file_len`), so
    /// bytes past it — e.g. a torn tail whose truncation failed on open
    /// — are never part of the view, and a file that shrank below the
    /// prefix (impossible without external tampering: sealed files are
    /// immutable in place) is refused inside `load_resident`.
    fn ensure_resident(&mut self, idx: usize) -> Option<Bytes> {
        let (base, cached) = match &self.segments[idx] {
            Segment::Sealed(s) => (s.base, s.resident.clone()),
            Segment::Mem(_) => return None,
        };
        if let Some(buf) = cached {
            self.touch_resident(base);
            return Some(buf);
        }
        let buf = match &self.segments[idx] {
            Segment::Sealed(s) => match s.load_resident() {
                Ok(b) => b,
                Err(e) => {
                    log::error!("{e:#}");
                    return None;
                }
            },
            Segment::Mem(_) => unreachable!("checked sealed above"),
        };
        self.admit_resident(idx, buf.clone());
        Some(buf)
    }

    /// Account a freshly loaded buffer and evict down to the budget.
    /// The charge is the buffer's full *backing* length — what the
    /// mapping (or heap vector) actually pins — not the window length,
    /// so a sliced admit cannot under-count against the budget.
    fn admit_resident(&mut self, idx: usize, buf: Bytes) {
        let len = buf.backing_len();
        let base = match &mut self.segments[idx] {
            Segment::Sealed(s) => {
                debug_assert!(s.resident.is_none(), "double admit");
                s.resident = Some(buf);
                s.base
            }
            Segment::Mem(_) => return,
        };
        self.resident_bytes += len;
        self.resident_order.push_back(base);
        self.evict_residents(base);
    }

    fn touch_resident(&mut self, base: u64) {
        if let Some(p) = self.resident_order.iter().position(|&b| b == base) {
            self.resident_order.remove(p);
            self.resident_order.push_back(base);
        }
    }

    /// Drop least-recently-used buffers until under budget, always
    /// keeping `keep` (the buffer a read is about to use). Outstanding
    /// consumer handles on an evicted buffer stay valid — eviction only
    /// drops the broker's reference. For a mapped buffer the demote is
    /// `madvise(DONTNEED)` (physical pages released immediately, even
    /// while consumer slices are still live — they re-fault from the
    /// immutable file) and the address range itself unmaps when the
    /// last handle drops.
    fn evict_residents(&mut self, keep: u64) {
        let budget = self.config.max_resident_bytes;
        while self.resident_bytes > budget && self.resident_order.len() > 1 {
            if self.resident_order[0] == keep {
                self.resident_order.rotate_left(1);
            }
            let victim = self.resident_order[0];
            if victim == keep {
                break;
            }
            self.resident_order.pop_front();
            let freed = self
                .segments
                .iter_mut()
                .find_map(|seg| match seg {
                    Segment::Sealed(s) if s.base == victim => s.resident.take(),
                    _ => None,
                })
                .map(|b| {
                    b.advise_dont_need();
                    b.backing_len()
                })
                .unwrap_or(0);
            self.resident_bytes = self.resident_bytes.saturating_sub(freed);
        }
    }

    /// Forget residency accounting for a segment about to be removed.
    fn forget_resident(&mut self, base: u64, resident: &Option<Bytes>) {
        if let Some(buf) = resident {
            self.resident_bytes = self.resident_bytes.saturating_sub(buf.backing_len());
            self.resident_order.retain(|&b| b != base);
        }
    }

    /// Bytes of sealed-segment buffers currently resident (bounded by
    /// `max_resident_bytes`, modulo the always-kept most recent buffer).
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// The effective log configuration (for inspection: recovery tests,
    /// admin surfaces).
    pub fn config(&self) -> &LogConfig {
        &self.config
    }

    /// Number of sealed-segment buffers currently resident.
    pub fn resident_count(&self) -> usize {
        self.resident_order.len()
    }

    // ---- offsets & accounting ----------------------------------------------

    /// First retained offset.
    pub fn earliest_offset(&self) -> u64 {
        self.segments
            .iter()
            .find_map(|s| s.first_offset())
            .unwrap_or(self.next_offset)
    }

    /// Offset that will be assigned to the next record (= "latest").
    pub fn latest_offset(&self) -> u64 {
        self.next_offset
    }

    pub fn len(&self) -> u64 {
        self.segments.iter().map(|s| s.record_count() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.size_bytes() as u64).sum()
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Number of segments sealed to disk.
    pub fn sealed_count(&self) -> usize {
        let mut n = 0;
        for s in &self.segments {
            if matches!(s, Segment::Sealed(_)) {
                n += 1;
            }
        }
        n
    }

    // ---- retention ----------------------------------------------------------

    /// Apply the retention policy; returns the number of records removed.
    /// Mirrors Kafka's log cleaner: `Delete` drops whole expired/oversize
    /// segments (never the active one) — deleting their files on the
    /// disk tier; `Compact` rewrites closed segments (and their files)
    /// keeping only the most recent value per key.
    pub fn enforce_retention(&mut self) -> u64 {
        match self.config.cleanup_policy {
            CleanupPolicy::Delete => self.enforce_delete(),
            CleanupPolicy::Compact => self.compact(),
        }
    }

    fn enforce_delete(&mut self) -> u64 {
        let now = self.clock.now_ms();
        let mut removed = 0u64;
        // Time-based: drop closed segments whose newest record expired.
        if let Some(ret_ms) = self.config.retention_ms {
            while self.segments.len() > 1 {
                let first = self.segments.front().unwrap();
                if now.saturating_sub(first.max_timestamp()) > ret_ms {
                    removed += self.remove_front_segment();
                } else {
                    break;
                }
            }
        }
        // Size-based: drop oldest closed segments until under the cap.
        if let Some(cap) = self.config.retention_bytes {
            while self.segments.len() > 1 && self.size_bytes() > cap {
                removed += self.remove_front_segment();
            }
        }
        removed
    }

    /// Pop the oldest segment, deleting its file on the disk tier.
    /// Returns the number of records removed.
    fn remove_front_segment(&mut self) -> u64 {
        let seg = self.segments.pop_front().expect("removing from an empty log");
        match seg {
            Segment::Mem(m) => m.records.len() as u64,
            Segment::Sealed(s) => {
                self.forget_resident(s.base, &s.resident);
                if let Err(e) = std::fs::remove_file(&s.path) {
                    log::warn!("removing retained-away segment {}: {e}", s.path.display());
                }
                s.record_count() as u64
            }
        }
    }

    /// Keep the last value for each key across *closed* segments (the
    /// active segment is left untouched, as in Kafka). Records without a
    /// key are retained (Kafka requires keys for compacted topics; we are
    /// lenient and treat key-less records as unique). Sealed segments
    /// are atomically rewritten with only their surviving frames.
    fn compact(&mut self) -> u64 {
        if self.segments.len() <= 1 {
            return 0;
        }
        // Latest offset per key across the whole log (active included —
        // a newer value in the active segment supersedes older ones).
        // Keys are shared `Bytes`, so building the index copies nothing.
        let mut latest: HashMap<Bytes, u64> = HashMap::new();
        for i in 0..self.segments.len() {
            if matches!(self.segments[i], Segment::Sealed(_)) {
                let Some(buf) = self.ensure_resident(i) else {
                    log::error!("compaction skipped: a sealed segment is unreadable");
                    return 0;
                };
                let Segment::Sealed(s) = &self.segments[i] else {
                    unreachable!()
                };
                match s.decode_all(&buf) {
                    Ok(records) => {
                        for (off, r) in records {
                            if let Some(k) = r.key {
                                latest.insert(k, off);
                            }
                        }
                    }
                    Err(e) => {
                        log::error!("compaction skipped: {e:#}");
                        return 0;
                    }
                }
            } else if let Segment::Mem(m) = &self.segments[i] {
                for (j, r) in m.records.iter().enumerate() {
                    if let Some(k) = &r.key {
                        latest.insert(k.clone(), m.offsets[j]);
                    }
                }
            }
        }
        let mut removed = 0u64;
        let closed = self.segments.len() - 1;
        for i in 0..closed {
            if matches!(self.segments[i], Segment::Sealed(_)) {
                removed += self.compact_sealed(i, &latest);
            } else if let Segment::Mem(m) = &mut self.segments[i] {
                removed += compact_mem(m, &latest);
            }
        }
        // Drop fully-compacted-away segments (keep at least the active).
        while self.segments.len() > 1 && self.segments.front().unwrap().is_empty() {
            self.segments.pop_front();
        }
        removed
    }

    /// Rewrite one sealed segment with only its surviving frames
    /// (tmp + rename over the same file). Returns records removed.
    fn compact_sealed(&mut self, idx: usize, latest: &HashMap<Bytes, u64>) -> u64 {
        let Some(buf) = self.ensure_resident(idx) else {
            return 0;
        };
        let (base, path, old_resident, kept, removed) = {
            let Segment::Sealed(s) = &self.segments[idx] else {
                return 0;
            };
            let records = match s.decode_all(&buf) {
                Ok(r) => r,
                Err(e) => {
                    log::error!("compaction of {}: {e:#}", s.path.display());
                    return 0;
                }
            };
            let total = records.len();
            let kept: Vec<(u64, Record)> = records
                .into_iter()
                .filter(|(off, r)| match &r.key {
                    Some(k) => latest.get(k) == Some(off),
                    None => true,
                })
                .collect();
            let removed = (total - kept.len()) as u64;
            (s.base, s.path.clone(), s.resident.clone(), kept, removed)
        };
        if removed == 0 {
            return 0;
        }
        if kept.is_empty() {
            // The whole segment compacted away: delete the file and
            // leave an empty placeholder (popped by the caller when it
            // reaches the log's front).
            self.forget_resident(base, &old_resident);
            if let Err(e) = std::fs::remove_file(&path) {
                log::warn!("removing compacted-away segment {}: {e}", path.display());
            }
            self.segments[idx] = Segment::Mem(MemSegment::new());
            return removed;
        }
        let Some(dir) = path.parent().map(|p| p.to_path_buf()) else {
            return 0;
        };
        match SealedSegment::write(&dir, base, &kept) {
            Ok((new_seg, new_buf)) => {
                self.forget_resident(base, &old_resident);
                self.segments[idx] = Segment::Sealed(new_seg);
                self.admit_resident(idx, new_buf);
                removed
            }
            Err(e) => {
                log::error!("rewriting compacted segment {}: {e:#}", path.display());
                0
            }
        }
    }
}

/// Compact one closed in-memory segment in place.
fn compact_mem(m: &mut MemSegment, latest: &HashMap<Bytes, u64>) -> u64 {
    let mut offsets = Vec::new();
    let mut records = Vec::new();
    let mut size = 0usize;
    let mut removed = 0u64;
    for (i, r) in m.records.iter().enumerate() {
        let keep = match &r.key {
            Some(k) => latest.get(k) == Some(&m.offsets[i]),
            None => true,
        };
        if keep {
            size += r.size_bytes();
            offsets.push(m.offsets[i]);
            records.push(r.clone());
        } else {
            removed += 1;
        }
    }
    m.offsets = offsets;
    m.records = records;
    m.size_bytes = size;
    removed
}

impl Drop for SegmentedLog {
    fn drop(&mut self) {
        if self.dir.is_some() {
            if let Err(e) = self.flush() {
                log::warn!("flushing log on drop: {e:#}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::sync::Arc;

    fn log_with(config: LogConfig) -> (SegmentedLog, ManualClock) {
        let clock = ManualClock::new(1_000_000);
        (SegmentedLog::new(config, Arc::new(clock.clone())), clock)
    }

    fn rec(n: u8) -> Record {
        Record::new(vec![n; 10])
    }

    fn data_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("kafka-ml-log-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn tiered(mut config: LogConfig, dir: &PathBuf) -> LogConfig {
        config.storage = StorageMode::tiered(dir);
        config
    }

    fn seg_files(dir: &PathBuf) -> usize {
        let Ok(entries) = std::fs::read_dir(dir.join("log").join("0")) else {
            return 0;
        };
        entries
            .flatten()
            .filter(|e| e.path().extension().map(|x| x == "seg").unwrap_or(false))
            .count()
    }

    #[test]
    fn offsets_dense_and_monotonic() {
        let (mut log, _) = log_with(LogConfig::default());
        for i in 0..100u8 {
            assert_eq!(log.append(rec(i)), i as u64);
        }
        assert_eq!(log.latest_offset(), 100);
        assert_eq!(log.earliest_offset(), 0);
    }

    #[test]
    fn read_range_and_bounds() {
        let (mut log, _) = log_with(LogConfig::default());
        for i in 0..50u8 {
            log.append(rec(i));
        }
        let got = log.read(10, 5);
        assert_eq!(got.len(), 5);
        assert_eq!(got[0].0, 10);
        assert_eq!(got[4].0, 14);
        assert_eq!(got[0].1.value, vec![10u8; 10]);
        assert!(log.read(50, 10).is_empty());
        assert_eq!(log.read(48, 10).len(), 2);
    }

    #[test]
    fn segments_roll_at_size() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 100,
            ..LogConfig::default()
        });
        for i in 0..20u8 {
            log.append(rec(i)); // 26 bytes each
        }
        assert!(log.segment_count() > 2, "{}", log.segment_count());
        // All records still readable across segments.
        assert_eq!(log.read(0, 100).len(), 20);
    }

    #[test]
    fn roll_accounts_for_incoming_record_size() {
        // A record that would overshoot the cap rolls the segment FIRST,
        // so no closed segment exceeds segment_bytes (an oversized
        // record still lands alone in its own fresh segment).
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 100,
            ..LogConfig::default()
        });
        log.append(Record::new(vec![1u8; 40])); // 56 bytes
        assert_eq!(log.segment_count(), 1);
        // 56 + 56 > 100: must roll rather than overshoot to 112.
        log.append(Record::new(vec![2u8; 40]));
        assert_eq!(log.segment_count(), 2);
        // An oversized record: the (non-empty) active rolls, then the
        // record lands alone in the new segment.
        log.append(Record::new(vec![3u8; 500]));
        assert_eq!(log.segment_count(), 3);
        // Every closed segment respects the cap.
        let (mut log2, _) = log_with(LogConfig {
            segment_bytes: 100,
            ..LogConfig::default()
        });
        for i in 0..50u8 {
            log2.append(rec(i));
        }
        // 26-byte records, cap 100 => exactly 3 records per closed
        // segment (78 bytes); the pre-fix behaviour packed 4 (104).
        assert_eq!(log2.read(0, 1000).len(), 50);
        assert_eq!(log2.segment_count(), (50 + 2) / 3);
    }

    #[test]
    fn time_retention_drops_old_segments_not_active() {
        let (mut log, clock) = log_with(LogConfig {
            segment_bytes: 100,
            retention_ms: Some(1000),
            ..LogConfig::default()
        });
        for i in 0..10u8 {
            log.append(rec(i));
        }
        clock.advance_ms(10_000);
        for i in 10..14u8 {
            log.append(rec(i)); // fresh records in newer segments
        }
        let removed = log.enforce_retention();
        assert!(removed > 0);
        // Old records gone; fresh ones retained.
        assert!(log.earliest_offset() > 0);
        let earliest = log.earliest_offset();
        let all = log.read(0, 100);
        assert!(all.iter().all(|(o, _)| *o >= earliest));
        assert!(all.iter().any(|(_, r)| r.value == vec![13u8; 10]));
    }

    #[test]
    fn size_retention_caps_log() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 100,
            retention_bytes: Some(300),
            retention_ms: None,
            ..LogConfig::default()
        });
        for i in 0..100u8 {
            log.append(rec(i));
            log.enforce_retention();
        }
        assert!(log.size_bytes() <= 300 + 100 + 26, "{}", log.size_bytes());
        assert!(log.earliest_offset() > 0);
    }

    #[test]
    fn retention_never_removes_unexpired_data() {
        let (mut log, clock) = log_with(LogConfig {
            segment_bytes: 50,
            retention_ms: Some(60_000),
            ..LogConfig::default()
        });
        for i in 0..30u8 {
            log.append(rec(i));
        }
        clock.advance_ms(1000); // well within retention
        assert_eq!(log.enforce_retention(), 0);
        assert_eq!(log.read(0, 100).len(), 30);
    }

    #[test]
    fn compaction_keeps_last_value_per_key() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 60,
            cleanup_policy: CleanupPolicy::Compact,
            retention_ms: None,
            ..LogConfig::default()
        });
        for round in 0..5u8 {
            for key in 0..3u8 {
                log.append(Record::with_key(vec![key], vec![round; 4]));
            }
        }
        let removed = log.enforce_retention();
        assert!(removed > 0);
        // For each key, the newest surviving value must be the last round.
        let survivors = log.read(0, 1000);
        for key in 0..3u8 {
            let newest = survivors
                .iter()
                .filter(|(_, r)| r.key.as_deref() == Some(&[key]))
                .map(|(o, _)| *o)
                .max()
                .unwrap();
            let (_, r) = survivors.iter().find(|(o, _)| *o == newest).unwrap();
            assert_eq!(r.value, vec![4u8; 4], "key {key}");
        }
        // Offsets remain strictly increasing after compaction.
        let offsets: Vec<u64> = survivors.iter().map(|(o, _)| *o).collect();
        let mut sorted = offsets.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(offsets, sorted);
    }

    #[test]
    fn read_skips_compacted_holes() {
        let (mut log, _) = log_with(LogConfig {
            segment_bytes: 40,
            cleanup_policy: CleanupPolicy::Compact,
            retention_ms: None,
            ..LogConfig::default()
        });
        for i in 0..10u8 {
            log.append(Record::with_key(vec![0], vec![i]));
        }
        log.enforce_retention();
        // Reading from 0 must not loop or return stale offsets < start.
        let got = log.read(0, 100);
        assert!(!got.is_empty());
        for w in got.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    // ---- tiered-mode tests --------------------------------------------------

    #[test]
    fn tiered_roundtrip_survives_reopen() {
        let dir = data_dir("reopen");
        let config = tiered(
            LogConfig {
                segment_bytes: 100,
                retention_ms: None,
                ..LogConfig::default()
            },
            &dir,
        );
        {
            let (mut log, _) = log_with(config.clone());
            for i in 0..20u8 {
                log.append(rec(i));
            }
            assert!(log.sealed_count() > 0, "rolls must seal to disk");
            assert_eq!(log.read(0, 100).len(), 20);
            // Dropped here: the active segment is sealed by Drop.
        }
        assert!(seg_files(&dir) > 0);
        let (mut log, _) = log_with(config);
        assert_eq!(log.latest_offset(), 20);
        assert_eq!(log.earliest_offset(), 0);
        let got = log.read(0, 100);
        assert_eq!(got.len(), 20);
        for (i, (off, r)) in got.iter().enumerate() {
            assert_eq!(*off, i as u64);
            assert_eq!(r.value, vec![i as u8; 10], "byte-identical after recovery");
        }
        // Appends continue after the recovered offset.
        assert_eq!(log.append(rec(99)), 20);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiered_sealed_reads_share_one_buffer() {
        let dir = data_dir("zero-copy");
        let config = tiered(
            LogConfig {
                segment_bytes: 1 << 20,
                retention_ms: None,
                ..LogConfig::default()
            },
            &dir,
        );
        {
            let (mut log, _) = log_with(config.clone());
            for i in 0..8u8 {
                log.append(rec(i));
            }
            log.flush().unwrap();
        }
        let (mut log, _) = log_with(config);
        let got = log.read(0, 100);
        assert_eq!(got.len(), 8);
        let first = &got[0].1.value;
        for (_, r) in &got {
            assert!(
                Bytes::ptr_eq(first, &r.value),
                "records from one sealed segment must share one buffer"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiered_lru_bounds_resident_memory() {
        let dir = data_dir("lru");
        // Budget of 1 byte: at most one sealed buffer may stay resident.
        let config = tiered(
            LogConfig {
                segment_bytes: 64,
                retention_ms: None,
                max_resident_bytes: 1,
                ..LogConfig::default()
            },
            &dir,
        );
        let (mut log, _) = log_with(config);
        for i in 0..30u8 {
            log.append(rec(i));
        }
        assert!(log.sealed_count() > 3);
        let got = log.read(0, 100);
        assert_eq!(got.len(), 30);
        for (i, (off, r)) in got.iter().enumerate() {
            assert_eq!(*off, i as u64);
            assert_eq!(r.value, vec![i as u8; 10]);
        }
        assert!(log.resident_count() <= 1, "{}", log.resident_count());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Per sealed segment: (base, resident?, mapped?, backing bytes).
    fn sealed_residency(log: &SegmentedLog) -> Vec<(u64, bool, bool, usize)> {
        log.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Sealed(seg) => Some((
                    seg.base,
                    seg.resident.is_some(),
                    seg.resident.as_ref().map(Bytes::is_mapped).unwrap_or(false),
                    seg.resident.as_ref().map(Bytes::backing_len).unwrap_or(0),
                )),
                Segment::Mem(_) => None,
            })
            .collect()
    }

    #[test]
    fn tiered_eviction_drops_residency_and_accounts_backing_length() {
        let dir = data_dir("evict-unmap");
        // 1-byte budget: every admit evicts down to a single survivor.
        let config = tiered(
            LogConfig {
                segment_bytes: 64,
                retention_ms: None,
                max_resident_bytes: 1,
                ..LogConfig::default()
            },
            &dir,
        );
        let (mut log, _) = log_with(config);
        for i in 0..30u8 {
            log.append(rec(i));
        }
        assert!(log.sealed_count() > 3);
        let first: Vec<(u64, Vec<u8>)> = log
            .read(0, 100)
            .into_iter()
            .map(|(o, r)| (o, r.value.to_vec()))
            .collect();
        assert_eq!(first.len(), 30);
        // Eviction really dropped the victims' residency (the broker
        // handle is gone — for a mapped buffer that is the unmap), and
        // the LRU bookkeeping agrees with the per-segment state.
        let state = sealed_residency(&log);
        let survivors: Vec<_> = state.iter().filter(|(_, res, _, _)| *res).collect();
        assert!(survivors.len() <= 1, "{state:?}");
        assert_eq!(log.resident_count(), survivors.len());
        // Accounting charges exactly the survivors' backing length.
        let charged: usize = state.iter().map(|(_, _, _, n)| n).sum();
        assert_eq!(log.resident_bytes(), charged);
        // Residency is the mapped tier wherever mmap is available.
        let expect_mapped = cfg!(target_os = "linux") && !crate::util::bytes::mmap_disabled();
        for (base, res, mapped, _) in &state {
            if *res {
                assert_eq!(*mapped, expect_mapped, "segment {base}");
            }
        }
        // Evicted segments re-load on the next read, byte-identically.
        let second: Vec<(u64, Vec<u8>)> = log
            .read(0, 100)
            .into_iter()
            .map(|(o, r)| (o, r.value.to_vec()))
            .collect();
        assert_eq!(first, second);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiered_retention_deletes_segment_files() {
        let dir = data_dir("retention");
        let config = tiered(
            LogConfig {
                segment_bytes: 30,
                retention_bytes: Some(150),
                retention_ms: None,
                ..LogConfig::default()
            },
            &dir,
        );
        let (mut log, _) = log_with(config);
        for i in 0..30u8 {
            log.append(rec(i)); // 26 bytes: one record per segment
        }
        let before = seg_files(&dir);
        assert!(before > 10, "{before}");
        let removed = log.enforce_retention();
        assert!(removed > 0);
        let after = seg_files(&dir);
        assert!(after < before, "{after} < {before}");
        assert!(log.size_bytes() <= 150 + 30 + 26);
        assert!(log.earliest_offset() > 0);
        // What survives is still readable and correct.
        let earliest = log.earliest_offset();
        let got = log.read(0, 100);
        assert_eq!(got[0].0, earliest);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiered_compaction_rewrites_files_and_survives_reopen() {
        let dir = data_dir("compact");
        let config = tiered(
            LogConfig {
                segment_bytes: 60,
                cleanup_policy: CleanupPolicy::Compact,
                retention_ms: None,
                ..LogConfig::default()
            },
            &dir,
        );
        {
            let (mut log, _) = log_with(config.clone());
            for round in 0..5u8 {
                for key in 0..3u8 {
                    log.append(Record::with_key(vec![key], vec![round; 4]));
                }
            }
            let removed = log.enforce_retention();
            assert!(removed > 0);
        }
        // Reopen: compacted files recover; the newest value per key is
        // still the last round, and next_offset is preserved.
        let (mut log, _) = log_with(config);
        assert_eq!(log.latest_offset(), 15);
        let survivors = log.read(0, 1000);
        for key in 0..3u8 {
            let newest = survivors
                .iter()
                .filter(|(_, r)| r.key.as_deref() == Some(&[key]))
                .max_by_key(|(o, _)| *o)
                .unwrap();
            assert_eq!(newest.1.value, vec![4u8; 4], "key {key}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn storage_mode_paths_and_sanitization() {
        let mode = StorageMode::tiered("/data");
        assert_eq!(
            mode.partition_dir("hcopd-data", 3),
            Some(PathBuf::from("/data/hcopd-data/3"))
        );
        assert_eq!(
            mode.partition_dir("weird topic/¹", 0),
            Some(PathBuf::from("/data/weird_topic__/0"))
        );
        assert_eq!(StorageMode::InMemory.partition_dir("t", 0), None);
        assert_eq!(sanitize_topic(""), "_");
        assert_eq!(sanitize_topic("a.b_c-D9"), "a.b_c-D9");
    }

    #[test]
    fn topic_meta_round_trips_every_config_knob() {
        let config = LogConfig {
            segment_bytes: 4096,
            retention_bytes: Some(1 << 20),
            retention_ms: None, // keep forever — encodes as "none"
            cleanup_policy: CleanupPolicy::Compact,
            storage: StorageMode::tiered("/data"), // NOT persisted
            max_resident_bytes: 8 << 20,
        };
        let meta = TopicMeta::of("sensor/¹ readings", 7, &config);
        let decoded = TopicMeta::decode(&meta.encode());
        assert_eq!(decoded, meta);
        assert_eq!(decoded.name, "sensor/¹ readings");
        assert_eq!(decoded.partitions, Some(7));

        // Applying onto a base with a *different* storage keeps the
        // base's storage but every persisted knob wins.
        let base = LogConfig {
            storage: StorageMode::tiered("/elsewhere"),
            ..LogConfig::default()
        };
        let applied = decoded.apply_to(&base);
        assert_eq!(applied.segment_bytes, 4096);
        assert_eq!(applied.retention_bytes, Some(1 << 20));
        assert_eq!(applied.retention_ms, None);
        assert_eq!(applied.cleanup_policy, CleanupPolicy::Compact);
        assert_eq!(applied.max_resident_bytes, 8 << 20);
        assert_eq!(applied.storage, StorageMode::tiered("/elsewhere"));
    }

    #[test]
    fn topic_meta_reads_legacy_raw_name_files() {
        let meta = TopicMeta::decode("plain old topic name\n");
        assert_eq!(meta.name, "plain old topic name");
        assert_eq!(meta.partitions, None);
        // No overrides: applying is the identity on the base config.
        let base = LogConfig::default();
        assert_eq!(meta.apply_to(&base), base);
    }

    #[test]
    fn topic_meta_ignores_unknown_keys_and_junk_values() {
        let meta = TopicMeta::decode(
            "v2\nname=t\npartitions=3\nfuture_knob=whatever\nsegment_bytes=not-a-number\n",
        );
        assert_eq!(meta.name, "t");
        assert_eq!(meta.partitions, Some(3));
        assert_eq!(meta.segment_bytes, None);
    }
}
