//! Adam with bias correction — the pure-Rust twin of the fused Pallas
//! kernel in `python/compile/kernels/adam.py`.
//!
//! Exactly like that kernel, the bias correction is folded into a
//! per-step scalar step size
//! `lr_t = lr · √(1 − β₂ᵗ) / (1 − β₁ᵗ)` (scalar math, identical result
//! to the `m̂`/`v̂` formulation), then one pass over each tensor updates
//! `(p, m, v)` together:
//!
//! ```text
//! m ← β₁·m + (1−β₁)·g
//! v ← β₂·v + (1−β₂)·g²
//! p ← p − lr_t · m / (√v + ε)
//! ```

/// Adam hyper-parameters, fixed per model (meta.json `spec`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamHyper {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for AdamHyper {
    /// Keras `Adam()` defaults (the paper's Listing 2 overrides only lr).
    fn default() -> Self {
        AdamHyper { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-7 }
    }
}

impl AdamHyper {
    /// The bias-corrected step size for 1-based step `t`.
    pub fn lr_t(&self, t: u64) -> f32 {
        let t = t as i32;
        (self.lr * (1.0 - self.beta2.powi(t)).sqrt() / (1.0 - self.beta1.powi(t))) as f32
    }
}

/// One Adam step for a single flat tensor. `t` is the 1-based step
/// count; all four buffers must share a length.
pub fn adam_step(h: &AdamHyper, t: u64, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
    debug_assert!(t >= 1, "Adam step count is 1-based");
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), m.len());
    debug_assert_eq!(p.len(), v.len());
    let lr_t = h.lr_t(t);
    let (b1, b2, eps) = (h.beta1 as f32, h.beta2 as f32, h.eps as f32);
    for i in 0..p.len() {
        let gi = g[i];
        let mi = b1 * m[i] + (1.0 - b1) * gi;
        let vi = b2 * v[i] + (1.0 - b2) * gi * gi;
        m[i] = mi;
        v[i] = vi;
        p[i] -= lr_t * mi / (vi.sqrt() + eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_against_gradient() {
        let h = AdamHyper { lr: 0.1, ..Default::default() };
        let mut p = vec![1.0f32, -1.0];
        let g = vec![2.0f32, -3.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam_step(&h, 1, &mut p, &g, &mut m, &mut v);
        // At t=1 the bias-corrected update is ≈ lr·sign(g) regardless of
        // gradient magnitude (m̂/√v̂ = g/|g| when moments start at zero).
        assert!((p[0] - (1.0 - 0.1)).abs() < 1e-3, "p0 {}", p[0]);
        assert!((p[1] - (-1.0 + 0.1)).abs() < 1e-3, "p1 {}", p[1]);
        assert!((m[0] - 0.2).abs() < 1e-6);
        assert!((v[0] - 0.004).abs() < 1e-6);
    }

    #[test]
    fn zero_gradient_is_a_fixed_point_from_rest() {
        let h = AdamHyper::default();
        let mut p = vec![0.5f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        adam_step(&h, 1, &mut p, &[0.0], &mut m, &mut v);
        assert_eq!(p[0], 0.5);
    }

    #[test]
    fn lr_t_decays_toward_lr() {
        let h = AdamHyper { lr: 1e-2, ..Default::default() };
        // Early steps get a larger corrected rate; by t→∞ it settles at lr.
        assert!(h.lr_t(1) > h.lr_t(1000));
        assert!((h.lr_t(100_000) as f64 - h.lr).abs() < 1e-6);
    }
}
