//! The PJRT execution engine.
//!
//! Compiles every HLO-text artifact once at load time; the training loop
//! and the inference hot path then call `execute` on the pre-compiled
//! executables with `Literal` inputs. The interchange is HLO **text**
//! (see `python/compile/aot.py` for why — xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos).

use super::meta::ArtifactMeta;
use super::params::{ModelParams, ParamTensor};
use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// Mutable training state: parameters + Adam moments + step count, kept
/// as XLA literals between steps so the hot loop does no re-marshalling
/// of the model.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    /// 1-based step count (Adam bias correction).
    pub t: u64,
}

pub struct Engine {
    client: xla::PjRtClient,
    meta: ArtifactMeta,
    /// Lazily-compiled executables (§Perf: eager compilation of all five
    /// artifacts cost ~1 s of pod startup; a training Job never touches
    /// the predict artifacts and an inference replica never touches
    /// train_step, so each is compiled on first use and cached).
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Load the artifact metadata and create the PJRT client. HLO
    /// compilation happens lazily, per artifact, on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let meta = ArtifactMeta::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Engine { client, meta, execs: RefCell::new(HashMap::new()) })
    }

    /// Force-compile every artifact now (benches that must exclude
    /// compile time from the measured region call this first).
    pub fn warmup_all(&self) -> Result<()> {
        let names: Vec<String> = self.meta.artifacts.keys().cloned().collect();
        for name in names {
            self.exec(&name)?;
        }
        Ok(())
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exec(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.execs.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.meta.artifact(name)?;
        let path = self.meta.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.execs
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Run an artifact and decompose its (return_tuple=True) result.
    fn run(&self, name: &str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exec(name)?;
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("{name}: not a tuple: {e:?}"))
    }

    // ---- init ------------------------------------------------------------------

    /// Fresh Glorot-initialized parameters (runs the `init` artifact; the
    /// seed was fixed at AOT time, mirroring the paper's "model defined
    /// once in the Web UI").
    pub fn init_params(&self) -> Result<ModelParams> {
        let outs = self.run("init", &[])?;
        if outs.len() != self.meta.n_params() {
            bail!(
                "init returned {} tensors, meta expects {}",
                outs.len(),
                self.meta.n_params()
            );
        }
        let tensors = outs
            .iter()
            .zip(&self.meta.params)
            .map(|(lit, pm)| {
                Ok(ParamTensor {
                    name: pm.name.clone(),
                    shape: pm.shape.clone(),
                    data: lit
                        .to_vec::<f32>()
                        .map_err(|e| anyhow!("init tensor {}: {e:?}", pm.name))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelParams { tensors })
    }

    // ---- state <-> params ----------------------------------------------------------

    fn tensor_literal(&self, t: &ParamTensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshaping {}: {e:?}", t.name))
    }

    fn zeros_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let numel: usize = shape.iter().product();
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&vec![0f32; numel])
            .reshape(&dims)
            .map_err(|e| anyhow!("zeros: {e:?}"))
    }

    /// Start training from `params` with zeroed Adam moments.
    pub fn train_state(&self, params: &ModelParams) -> Result<TrainState> {
        params.check_against(&self.meta.params)?;
        let p = params
            .tensors
            .iter()
            .map(|t| self.tensor_literal(t))
            .collect::<Result<Vec<_>>>()?;
        let m = params
            .tensors
            .iter()
            .map(|t| self.zeros_literal(&t.shape))
            .collect::<Result<Vec<_>>>()?;
        let v = params
            .tensors
            .iter()
            .map(|t| self.zeros_literal(&t.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { params: p, m, v, t: 0 })
    }

    /// Extract host-side parameters from a training state (for upload).
    pub fn params_of(&self, state: &TrainState) -> Result<ModelParams> {
        let tensors = state
            .params
            .iter()
            .zip(&self.meta.params)
            .map(|(lit, pm)| {
                Ok(ParamTensor {
                    name: pm.name.clone(),
                    shape: pm.shape.clone(),
                    data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelParams { tensors })
    }

    /// Parameter literals for inference (no optimizer state).
    pub fn inference_params(&self, params: &ModelParams) -> Result<Vec<xla::Literal>> {
        params.check_against(&self.meta.params)?;
        params
            .tensors
            .iter()
            .map(|t| self.tensor_literal(t))
            .collect()
    }

    // ---- training ---------------------------------------------------------------------

    /// One optimizer step on one batch. `x` is `batch × input_dim`
    /// row-major, `y` is `batch` labels. Returns `(loss, accuracy)`.
    pub fn train_step(&self, state: &mut TrainState, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let n = self.meta.n_params();
        let b = self.meta.batch;
        if x.len() != b * self.meta.input_dim || y.len() != b {
            bail!(
                "train_step batch mismatch: x {} (want {}), y {} (want {})",
                x.len(),
                b * self.meta.input_dim,
                y.len(),
                b
            );
        }
        state.t += 1;
        let xl = xla::Literal::vec1(x)
            .reshape(&[b as i64, self.meta.input_dim as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let yl = xla::Literal::vec1(y);
        let tl = xla::Literal::scalar(state.t as f32);

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 3);
        args.extend(state.params.iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&tl);
        args.push(&xl);
        args.push(&yl);

        let mut outs = self.run("train_step", &args)?;
        if outs.len() != 3 * n + 2 {
            bail!("train_step returned {} outputs, want {}", outs.len(), 3 * n + 2);
        }
        let acc = scalar_f32(&outs.pop().unwrap())?;
        let loss = scalar_f32(&outs.pop().unwrap())?;
        state.v = outs.split_off(2 * n);
        state.m = outs.split_off(n);
        state.params = outs;
        Ok((loss, acc))
    }

    /// Loss + accuracy on one batch without updating parameters.
    pub fn eval_step(&self, params: &[xla::Literal], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.meta.batch;
        if x.len() != b * self.meta.input_dim || y.len() != b {
            bail!("eval_step batch mismatch");
        }
        let xl = xla::Literal::vec1(x)
            .reshape(&[b as i64, self.meta.input_dim as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let yl = xla::Literal::vec1(y);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let outs = self.run("eval_step", &args)?;
        Ok((scalar_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }

    // ---- inference -----------------------------------------------------------------------

    /// Class probabilities for `rows` samples (`rows × input_dim` f32).
    /// Uses the batch artifact for full batches and the single-record
    /// artifact for remainders, so any row count works.
    pub fn predict(&self, params: &[xla::Literal], x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let f = self.meta.input_dim;
        if x.len() != rows * f {
            bail!("predict shape mismatch: {} vs {rows}×{f}", x.len());
        }
        let bs = self.meta.artifact("predict")?.batch.unwrap_or(self.meta.batch);
        let mut probs = Vec::with_capacity(rows * self.meta.classes);
        let mut row = 0;
        while row < rows {
            let (art, take) = if rows - row >= bs {
                ("predict", bs)
            } else {
                ("predict_single", 1)
            };
            let xl = xla::Literal::vec1(&x[row * f..(row + take) * f])
                .reshape(&[take as i64, f as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let mut args: Vec<&xla::Literal> = params.iter().collect();
            args.push(&xl);
            let outs = self.run(art, &args)?;
            probs.extend(outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?);
            row += take;
        }
        Ok(probs)
    }

    /// Argmax class per row of `predict` output.
    pub fn classify(&self, probs: &[f32]) -> Vec<usize> {
        probs
            .chunks(self.meta.classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("{e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar"))
}

// Engine tests live in rust/tests/runtime_integration.rs because they
// need the real artifacts (built by `make artifacts`).
