//! Apache Avro substrate: schemas (parsed from JSON) and the binary
//! encoding, as used by Kafka-ML for "complex and multi-input datasets
//! where a scheme specifies how the data stream is decoded" (§III-D).
//!
//! Implemented subset (everything the HCOPD validation needs, faithful
//! to the Avro 1.11 spec encoding):
//! primitives `boolean`/`int`/`long`/`float`/`double`/`string`/`bytes`,
//! `array` of any supported type, and (nested) `record`s. Ints/longs are
//! zigzag-varint; arrays are block-encoded with a zero terminator.

mod codec;
mod schema;

pub use codec::{decode, decode_prefix, encode};
pub use schema::{AvroType, Field, Schema};

/// A decoded Avro value.
#[derive(Debug, Clone, PartialEq)]
pub enum AvroValue {
    Boolean(bool),
    Int(i32),
    Long(i64),
    Float(f32),
    Double(f64),
    Str(String),
    Bytes(Vec<u8>),
    Array(Vec<AvroValue>),
    Record(Vec<(String, AvroValue)>),
}

impl AvroValue {
    /// Numeric coercion to f32 — Kafka-ML flattens decoded records into
    /// model feature vectors.
    pub fn as_f32(&self) -> Option<f32> {
        match self {
            AvroValue::Boolean(b) => Some(if *b { 1.0 } else { 0.0 }),
            AvroValue::Int(v) => Some(*v as f32),
            AvroValue::Long(v) => Some(*v as f32),
            AvroValue::Float(v) => Some(*v),
            AvroValue::Double(v) => Some(*v as f32),
            _ => None,
        }
    }

    pub fn field(&self, name: &str) -> Option<&AvroValue> {
        match self {
            AvroValue::Record(fields) => {
                fields.iter().find(|(n, _)| n == name).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Depth-first flatten of all numeric leaves into `out` (record
    /// fields in schema order, arrays in element order).
    pub fn flatten_numeric(&self, out: &mut Vec<f32>) {
        match self {
            AvroValue::Record(fields) => {
                for (_, v) in fields {
                    v.flatten_numeric(out);
                }
            }
            AvroValue::Array(items) => {
                for v in items {
                    v.flatten_numeric(out);
                }
            }
            other => {
                if let Some(f) = other.as_f32() {
                    out.push(f);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn as_f32_coercions() {
        assert_eq!(AvroValue::Boolean(true).as_f32(), Some(1.0));
        assert_eq!(AvroValue::Int(-3).as_f32(), Some(-3.0));
        assert_eq!(AvroValue::Double(2.5).as_f32(), Some(2.5));
        assert_eq!(AvroValue::Str("x".into()).as_f32(), None);
    }

    #[test]
    fn flatten_recurses_in_order() {
        let v = AvroValue::Record(vec![
            ("age".into(), AvroValue::Int(64)),
            (
                "sensors".into(),
                AvroValue::Array(vec![AvroValue::Float(0.5), AvroValue::Float(1.5)]),
            ),
            ("name".into(), AvroValue::Str("skip".into())),
            ("smoker".into(), AvroValue::Boolean(false)),
        ]);
        let mut out = Vec::new();
        v.flatten_numeric(&mut out);
        assert_eq!(out, vec![64.0, 0.5, 1.5, 0.0]);
    }

    #[test]
    fn field_lookup() {
        let v = AvroValue::Record(vec![("a".into(), AvroValue::Int(1))]);
        assert_eq!(v.field("a"), Some(&AvroValue::Int(1)));
        assert_eq!(v.field("b"), None);
        assert_eq!(AvroValue::Int(1).field("a"), None);
    }
}
