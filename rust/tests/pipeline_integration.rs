//! End-to-end pipeline tests: the full Fig-1 flow (A–F) over the real
//! broker, orchestrator, REST back-end and model runtime. These run on
//! **every** checkout — training Jobs and inference replicas load the
//! PJRT backend when real AOT artifacts exist and the pure-Rust native
//! backend otherwise (see `common::engine_for_tests`); nothing here
//! skips.

use kafka_ml::broker::ClientLocality;
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::json::Json;
use kafka_ml::ml::{hcopd_dataset, separable_dataset};
use kafka_ml::registry::TrainingStatus;
use kafka_ml::runtime::BackendSelect;
use std::time::Duration;

fn avro_config() -> Json {
    kafka_ml::json::parse(
        r#"{
      "data_scheme": {"type":"record","name":"copd","fields":[
        {"name":"age","type":"float"},
        {"name":"gender","type":"float"},
        {"name":"smoking","type":"float"},
        {"name":"sensors","type":{"type":"array","items":"float"}}]},
      "label_scheme": {"type":"record","name":"label","fields":[
        {"name":"diagnosis","type":"int"}]}
    }"#,
    )
    .unwrap()
}

fn raw_config() -> Json {
    kafka_ml::json::parse(r#"{"dtype": "f32", "shape": [8]}"#).unwrap()
}

fn platform() -> KafkaMl {
    KafkaMl::start(KafkaMlConfig::default()).expect("platform boot")
}

mod common;

/// The suite-level guarantee the old `pjrt_available()` skip gate has
/// been replaced with: a runtime backend ALWAYS loads (panics inside
/// the helper otherwise), so every test below runs unconditionally.
#[test]
fn runtime_backend_is_available_for_the_pipeline() {
    let e = common::engine_for_tests();
    assert!(matches!(e.backend_name(), "pjrt" | "native"));
    assert_eq!(e.meta().input_dim, 8);
}

/// Steps A–D: define, configure, deploy, ingest, wait for training.
fn train_one(kml: &KafkaMl, format: &str, config: &Json, validation_rate: f64) -> u64 {
    let model = kml.create_model("hcopd-mlp").unwrap();
    let conf = kml.create_configuration("hcopd", &[model]).unwrap();
    let dep = kml
        .deploy_training(conf, &TrainParams { epochs: 3, ..Default::default() })
        .unwrap();
    let ds = hcopd_dataset(220, 8, 42);
    kml.send_stream(
        dep.id,
        &ds.samples,
        "hcopd-data",
        format,
        config,
        validation_rate,
        ClientLocality::External,
    )
    .unwrap();
    let results = kml.wait_training(&dep, Duration::from_secs(120)).unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert_eq!(r.status, TrainingStatus::Finished);
    assert!(r.metrics.loss > 0.0 && r.metrics.loss.is_finite());
    assert_eq!(r.metrics.loss_curve.len(), 3);
    r.id
}

/// The ISSUE-4 acceptance pipeline: a **deterministic** end-to-end run
/// — produce a training stream of the seeded separable dataset, train
/// to a falling loss curve, deploy for inference, stream requests over
/// the broker, and assert ≥90% accuracy on fresh draws from the same
/// rule. The model spec is written by the test itself (meta.json with
/// no HLO artifacts + `--backend native`), so the outcome is identical
/// on a clean checkout and on a checkout with real AOT artifacts.
#[test]
fn full_pipeline_end_to_end_native_deterministic() {
    let dir = std::env::temp_dir().join(format!("kafka-ml-e2e-native-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("meta.json"),
        r#"{
          "format_version": 1,
          "spec": {"input_dim": 8, "hidden": [16], "classes": 4, "batch": 10,
                   "lr": 0.01, "beta1": 0.9, "beta2": 0.999, "eps": 1e-07, "seed": 7},
          "params": [
            {"name": "w1", "shape": [8, 16], "dtype": "f32"},
            {"name": "b1", "shape": [16], "dtype": "f32"},
            {"name": "w2", "shape": [16, 4], "dtype": "f32"},
            {"name": "b2", "shape": [4], "dtype": "f32"}
          ],
          "artifacts": {}
        }"#,
    )
    .unwrap();

    let kml = KafkaMl::start(KafkaMlConfig {
        backend: BackendSelect::Native,
        ..Default::default()
    })
    .unwrap();
    let model = kml
        .create_model_from("separable-mlp", &dir.to_string_lossy())
        .unwrap();
    let conf = kml.create_configuration("separable", &[model]).unwrap();
    let dep = kml
        .deploy_training(conf, &TrainParams { epochs: 30, seed: 7, ..Default::default() })
        .unwrap();

    // D: produce the training stream (held-out tail becomes validation).
    let train = separable_dataset(260, 8, 4, 1);
    kml.send_stream(
        dep.id,
        &train.samples,
        "sep-data",
        "RAW",
        &raw_config(),
        0.2,
        ClientLocality::External,
    )
    .unwrap();
    let results = kml.wait_training(&dep, Duration::from_secs(120)).unwrap();
    let r = &results[0];
    assert_eq!(r.status, TrainingStatus::Finished);

    // Train to loss decrease: the curve must fall hard, not wiggle.
    assert_eq!(r.metrics.loss_curve.len(), 30);
    let (first, last) = (r.metrics.loss_curve[0], *r.metrics.loss_curve.last().unwrap());
    assert!(
        last < first * 0.5,
        "loss curve did not fall: {first:.4} -> {last:.4}"
    );
    // The held-out validation stream must already classify well.
    let val_acc = r.metrics.val_accuracy.expect("validation_rate > 0");
    assert!(val_acc >= 0.9, "validation accuracy only {val_acc:.3}");

    // E/F: deploy replicas, stream fresh requests over the broker.
    // (§IV-E auto-configuration reads the control log for the input
    // format — wait for the logger before deploying.)
    kml.wait_control_logged(dep.id, Duration::from_secs(10)).unwrap();
    let inf = kml
        .deploy_inference(r.id, 2, "sep-in", "sep-out")
        .unwrap();
    let mut client = kml
        .inference_client(&inf, ClientLocality::External)
        .unwrap();
    let test = separable_dataset(40, 8, 4, 2);
    let mut correct = 0usize;
    for s in &test.samples {
        let p = client
            .request(&s.features, Duration::from_secs(10))
            .unwrap();
        assert_eq!(p.probs.len(), 4);
        let sum: f32 = p.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    assert!(
        correct >= 36,
        "end-to-end accuracy {correct}/40 below the 90% bar"
    );
    kml.stop_inference(inf.id).unwrap();
    kml.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_pipeline_avro_training_and_inference() {
    let kml = platform();
    let result_id = train_one(&kml, "AVRO", &avro_config(), 0.2);

    // Validation metrics exist because validation_rate > 0.
    let r = kml.store.result(result_id).unwrap();
    assert!(r.metrics.val_loss.is_some());
    assert!(r.metrics.val_accuracy.is_some());

    // §IV-E auto-configuration: the inference deployment inherits the
    // AVRO format from the control log without us specifying it.
    let inf = kml
        .deploy_inference(result_id, 2, "infer-in", "infer-out")
        .unwrap();
    assert_eq!(inf.input_format, "AVRO");

    // Step F: stream requests, get predictions.
    let mut client = kml
        .inference_client(&inf, ClientLocality::External)
        .unwrap();
    let ds = hcopd_dataset(20, 8, 77);
    for s in &ds.samples {
        let p = client
            .request(&s.features, Duration::from_secs(10))
            .unwrap();
        assert_eq!(p.probs.len(), 4);
        let sum: f32 = p.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
        assert!(p.class < 4);
    }
    // 3 epochs won't classify the noisy HCOPD rule well (accuracy is
    // asserted by the deterministic separable pipeline test); here the
    // contract is that every request flowed through a replica.
    assert!(
        kml.cluster
            .metrics
            .counter("kafka_ml.inference.predictions")
            .get()
            >= 20
    );
    kml.stop_inference(inf.id).unwrap();
    kml.shutdown();
}

#[test]
fn raw_format_pipeline_works_too() {
    let kml = platform();
    let result_id = train_one(&kml, "RAW", &raw_config(), 0.0);
    let r = kml.store.result(result_id).unwrap();
    assert!(r.metrics.val_loss.is_none()); // no validation stream
    kml.shutdown();
}

#[test]
fn configuration_with_two_models_trains_both_from_one_stream() {
    // §III-B's selling point: n models, ONE data stream.
    let kml = platform();
    let m1 = kml.create_model("mlp-a").unwrap();
    let m2 = kml.create_model("mlp-b").unwrap();
    let conf = kml.create_configuration("pair", &[m1, m2]).unwrap();
    let dep = kml
        .deploy_training(
            conf,
            &TrainParams { epochs: 2, seed: 1, ..Default::default() },
        )
        .unwrap();
    assert_eq!(dep.result_ids.len(), 2);
    let ds = hcopd_dataset(100, 8, 5);
    kml.send_stream(
        dep.id,
        &ds.samples,
        "pair-data",
        "RAW",
        &raw_config(),
        0.0,
        ClientLocality::External,
    )
    .unwrap();
    let results = kml.wait_training(&dep, Duration::from_secs(120)).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.status, TrainingStatus::Finished);
    }
    // The data stream was produced exactly once (100 records + the
    // control message) — not once per model.
    let (_, latest) = kml.cluster.offsets("pair-data", 0).unwrap();
    assert_eq!(latest, 100);
    kml.shutdown();
}

#[test]
fn stream_reuse_trains_second_deployment_without_resend() {
    // §V / Fig 8: D1 trains from the stream; D2 reuses it via a
    // control-message re-send.
    let kml = platform();
    let model = kml.create_model("reuse-model").unwrap();
    let conf = kml.create_configuration("reuse", &[model]).unwrap();

    // D1: full ingest.
    let dep1 = kml
        .deploy_training(conf, &TrainParams { epochs: 1, ..Default::default() })
        .unwrap();
    let ds = hcopd_dataset(120, 8, 8);
    kml.send_stream(
        dep1.id,
        &ds.samples,
        "reuse-data",
        "RAW",
        &raw_config(),
        0.0,
        ClientLocality::External,
    )
    .unwrap();
    kml.wait_training(&dep1, Duration::from_secs(120)).unwrap();
    kml.wait_control_logged(dep1.id, Duration::from_secs(10)).unwrap();
    let (_, data_end) = kml.cluster.offsets("reuse-data", 0).unwrap();
    assert_eq!(data_end, 120);

    // D2: deploy, then *reuse* D1's stream — no data re-send.
    let dep2 = kml
        .deploy_training(conf, &TrainParams { epochs: 1, ..Default::default() })
        .unwrap();
    let msg = kml
        .reuse()
        .resend(dep1.id, dep2.id, ClientLocality::External)
        .unwrap();
    assert_eq!(msg.stream.format(), "[reuse-data:0:0:120]");
    let results = kml.wait_training(&dep2, Duration::from_secs(120)).unwrap();
    assert_eq!(results[0].status, TrainingStatus::Finished);

    // The data topic did NOT grow — the whole point of §V.
    let (_, data_end_after) = kml.cluster.offsets("reuse-data", 0).unwrap();
    assert_eq!(data_end_after, 120);
    kml.shutdown();
}

#[test]
fn inference_replicas_load_balance_and_survive_kill() {
    let kml = platform();
    let result_id = train_one(&kml, "RAW", &raw_config(), 0.0);
    let inf = kml
        .deploy_inference(result_id, 3, "lb-in", "lb-out")
        .unwrap();

    let mut client = kml
        .inference_client(&inf, ClientLocality::External)
        .unwrap();
    let ds = hcopd_dataset(30, 8, 13);
    for s in ds.samples.iter().take(10) {
        client.request(&s.features, Duration::from_secs(10)).unwrap();
    }

    // Kill one replica; the RC reconciler must replace it and service
    // must continue (§IV-D fault tolerance).
    let pods = kml.orch.pods_of_rc(&format!("inference-{}", inf.id));
    assert_eq!(pods.len(), 3);
    kml.orch.kill_pod(&pods[0]);
    for s in ds.samples.iter().skip(10) {
        client.request(&s.features, Duration::from_secs(15)).unwrap();
    }
    kml.orch
        .wait_rc_ready(&format!("inference-{}", inf.id), Duration::from_secs(30))
        .unwrap();
    // At-least-once: the killed replica may not have committed its last
    // poll, so the replacement can re-predict a few requests — the count
    // must cover every request, duplicates allowed.
    assert!(
        kml.cluster
            .metrics
            .counter("kafka_ml.inference.predictions")
            .get()
            >= 30
    );
    kml.stop_inference(inf.id).unwrap();
    kml.shutdown();
}

#[test]
fn pipeline_survives_broker_failover() {
    // §II/§IV-F fault tolerance: kill the leader broker of the data
    // topic mid-pipeline; partition replicas take over and training +
    // inference still complete.
    let kml = platform();
    let model = kml.create_model("failover").unwrap();
    let conf = kml.create_configuration("failover", &[model]).unwrap();
    let dep = kml
        .deploy_training(conf, &TrainParams { epochs: 2, ..Default::default() })
        .unwrap();
    let ds = hcopd_dataset(100, 8, 21);
    kml.cluster.create_topic("fo-data", 1);
    // Kill the leader of fo-data:0 BEFORE the stream is sent.
    let leader = {
        let t = kml.cluster.topic("fo-data").unwrap();
        let p = t.partition(0).unwrap().lock().unwrap();
        p.leader
    };
    kml.cluster.kill_broker(leader);
    kml.send_stream(
        dep.id,
        &ds.samples,
        "fo-data",
        "RAW",
        &raw_config(),
        0.0,
        ClientLocality::External,
    )
    .unwrap();
    let results = kml.wait_training(&dep, Duration::from_secs(120)).unwrap();
    assert_eq!(results[0].status, TrainingStatus::Finished);
    // The partition failed over to a replica.
    let t = kml.cluster.topic("fo-data").unwrap();
    let p = t.partition(0).unwrap().lock().unwrap();
    assert_ne!(p.leader, leader);
    drop(p);
    kml.cluster.restart_broker(leader);
    kml.shutdown();
}

#[test]
fn training_job_fails_cleanly_without_stream() {
    // A deployed job whose control message never arrives times out and
    // the back-end records the failure.
    let kml = platform();
    let model = kml.create_model("starved").unwrap();
    let conf = kml.create_configuration("starved", &[model]).unwrap();
    // Short control timeout via direct TrainingJobConfig (inline run,
    // no orchestrator — keeps the test fast and covers the inline path).
    let dep = kml.store.create_deployment(conf, 10, 1, true).unwrap();
    let config = kafka_ml::coordinator::TrainingJobConfig {
        control_timeout: Duration::from_millis(100),
        ..kafka_ml::coordinator::TrainingJobConfig::new(
            dep.id,
            dep.result_ids[0],
            "artifacts",
            kml.backend_url(),
        )
    };
    let err = kafka_ml::coordinator::training::run_training_job(
        &kml.broker(),
        &config,
        &kafka_ml::exec::CancelToken::new(),
    )
    .unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");
    kml.shutdown();
}
