//! `BrokerServer`: the broker as a TCP service, built on a sharded
//! event-loop network core.
//!
//! **N reactor shards** (`serve --reactors N`, default `min(4, cores)`)
//! each own an independent readiness poller ([`super::reactor::Poller`]
//! — epoll on Linux), wake fd, timer heap and read-staging buffer;
//! shard 0 owns the accept socket and round-robins accepted connections
//! across all shards (an `SO_REUSEPORT` listener per shard is the
//! natural follow-on once one accept loop saturates). A small fixed
//! **worker pool** (`broker-io`) is shared by every shard and runs
//! request handlers, which may block on disk (produce, fetch) or on
//! cluster locks. Thread count is O(reactors + worker pool), not
//! O(connections): ten thousand idle consumers cost ten thousand fd
//! registrations and per-connection buffers, never ten thousand stacks.
//!
//! Per connection, two state machines driven by readiness events:
//!
//! * **read**: bytes accumulate in a per-connection buffer across
//!   readiness events until full `len | crc | body` frames are present
//!   ([`super::codec`]). The connection is **pipelined**: every
//!   complete frame in the buffer is accepted per readability wake —
//!   read interest no longer gates off after one request — bounded by
//!   [`MAX_INFLIGHT_PER_CONN`] decoded-but-unanswered requests, so a
//!   torrential sender still backpressures through TCP. Ordinary
//!   requests execute **strictly serially per connection** (a FIFO
//!   queue feeds one worker at a time), which is what keeps a
//!   pipelined producer's batches appending in submission order — the
//!   invariant the idempotent `(producer_id, seq)` dedup needs to stay
//!   exact under client retries. `FetchWait` long-polls bypass the
//!   serial queue entirely (they park, below) and one-way `Metric`
//!   frames dispatch immediately, so a parked poll never head-of-line
//!   blocks a produce sharing the socket; responses therefore complete
//!   *out of order* and clients demultiplex them by correlation id.
//! * **write**: response chunks queue per-connection and drain on
//!   writability via vectored writes ([`super::reactor::writev`]). A
//!   fetch response is a header chunk plus zero-copy
//!   [`Bytes`](crate::util::Bytes) slices of the broker log
//!   ([`codec::encode_fetch_response_chunks`]), so a large batch goes
//!   from log to socket without ever being copied into a contiguous
//!   response buffer. Plain responses are encoded into a recycled
//!   per-connection scratch buffer — no steady-state allocation.
//!
//! **Long-polls park as registrations, not threads.** A `FetchWait`
//! registers a [`Waiter`] with the cluster's wait-sets
//! ([`Cluster::register_data_wait`]) whose wake hook posts a wakeup to
//! the owning shard through its eventfd ([`super::reactor::WakeFd`]);
//! the park is then held in a per-connection map keyed by correlation
//! id (one multiplexed client connection can hold several parked polls
//! at once) with a shard-timer entry for its
//! (group-liveness-capped) deadline. A produce wakes it in one eventfd
//! write + one response frame; an idle parked consumer costs zero
//! threads and zero CPU. The server's shutdown wait-set is an extra
//! wakeup source of every park, so stopping the server answers all of
//! them immediately.
//!
//! [`Cluster::register_data_wait`]: crate::broker::Cluster::register_data_wait
//! [`Waiter`]: crate::broker::notify::Waiter
//!
//! **Shutdown is deterministic**: the cancel token flips, one eventfd
//! write per shard wakes every reactor, every parked long-poll is
//! answered (`woken = true`) and every socket closed, then the
//! reactors and the worker pool are joined — no dummy self-connect, no
//! per-connection thread sweep.
//!
//! **Corruption never propagates**: a frame that fails its length bound
//! or CRC, or an unreadable envelope, drops the connection; an unknown
//! opcode or malformed payload answers with an error response — the
//! broker state and its locks are untouched either way, because
//! decoding completes before any cluster call.
//!
//! **Clustered deployments** add two concerns handled entirely here at
//! dispatch: *fencing* — partition-addressed requests (`Produce`,
//! `FetchBatch`) may carry the caller's metadata epoch, and a broker
//! that no longer leads the partition (or sees a stale epoch) answers
//! `not-leader` instead of touching the log, so a deposed leader can
//! never accept writes its successor won't have; and *tenant
//! namespacing* — with auth enforced, a non-admin key's topic names are
//! silently prefixed `{tenant}::` on ingress and stripped on egress,
//! so two tenants can each own an `mnist-train` without ever seeing
//! each other's data (admins and unscoped callers see the flat
//! internal view). Placement hashes the *bare* name
//! ([`ClusterCtl`](crate::broker::clusterctl::ClusterCtl)), so client
//! routing by visible name and server fencing by internal name agree.

use super::codec::{self, Chunk, OpCode, Reader};
use super::reactor::{self, Poller, PollerEvent, WakeFd, MAX_WRITEV_SEGMENTS};
use crate::broker::cluster::{ClusterHandle, DataWaitGuard};
use crate::registry::auth::{AuthKeys, AuthOutcome, Identity};
use crate::broker::log::format;
use crate::broker::net::ClientLocality;
use crate::broker::notify::{WaitSet, Waiter};
use crate::broker::record::Record;
use crate::broker::transport::BrokerTransport;
use crate::broker::TopicPartition;
use crate::exec::{CancelToken, ThreadPool};
use crate::util::bytes::Bytes;
use anyhow::{Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Hygiene ceiling on one `FetchWait` park — NOT a poll interval. A
/// parked connection wakes on data, rebalance, *or server shutdown*
/// (the shutdown wait-set is one of its wakeup sources), so the server
/// can honor the client's full long-poll deadline with zero polling on
/// the wire; this cap only bounds a wait whose client named an absurd
/// timeout.
pub const MAX_WAIT_SLICE: Duration = Duration::from_secs(600);

/// Idle connections are dropped after this long without a request; the
/// client reconnects transparently on its next call (and expires its
/// own side proactively — see `client::CLIENT_IDLE_EXPIRY`). Parked
/// long-polls, in-flight requests and the metrics channel are exempt.
pub const IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// How often each reactor shard sweeps for idle connections.
pub const SWEEP_INTERVAL: Duration = Duration::from_secs(5);

/// Request handlers that may block (disk appends, segment loads,
/// cluster locks) run on this many `broker-io` threads by default.
pub const DEFAULT_IO_WORKERS: usize = 4;

/// Backpressure bound on request pipelining: at most this many
/// decoded-but-unanswered requests (queued, executing, or parked) per
/// connection. Once reached, the shard parks the connection's read
/// interest and the sender backpressures through TCP until responses
/// drain.
pub const MAX_INFLIGHT_PER_CONN: usize = 32;

/// Poller token of the accept socket (shard 0 only).
const TOKEN_LISTENER: u64 = 0;
/// Poller token of each shard's wake fd.
const TOKEN_WAKE: u64 = 1;
/// Connection ids count up from here (per shard, never reused), so a
/// stale timer or event can never hit a different connection.
const FIRST_CONN_TOKEN: u64 = 2;

/// Shard-owned read staging buffer: one per reactor shard, not per
/// connection, so ten thousand idle connections hold only their (tiny)
/// pending-frame buffers.
const READ_BUF_BYTES: usize = 64 * 1024;

/// An empty, fully-parsed connection buffer above this capacity is
/// released rather than kept hot (one huge produce should not pin 64
/// MiB to an otherwise idle connection).
const RBUF_KEEP_BYTES: usize = 256 * 1024;

/// Default reactor shard count: one per core up to four — past that the
/// accept path and the shared worker pool, not the event loops, are the
/// measured bottleneck.
pub fn default_reactors() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// State shared between every reactor shard, the worker pool and
/// shutdown.
struct Shared {
    cluster: ClusterHandle,
    /// Shared API-key table (same `Arc` the REST layer guards with).
    /// `None` means no key table at all; with `Some` but
    /// `require_auth() == false`, keys are validated and metered when
    /// presented but never demanded.
    auth: Option<Arc<AuthKeys>>,
    cancel: CancelToken,
    /// Notified once at shutdown: every parked long-poll registration
    /// wakes (its hook posts a shard wakeup) and is answered.
    shutdown: Arc<WaitSet>,
    /// One mailbox per reactor shard; workers and waiter hooks post to
    /// the shard owning the connection.
    shards: Vec<Arc<ShardMailbox>>,
    /// Round-robin cursor distributing accepted fds across shards.
    next_shard: AtomicUsize,
    /// Live connection count per shard (observability; the
    /// shard-distribution soak asserts on it).
    conn_counts: Vec<AtomicUsize>,
}

/// A shard's inbox + wakeup fd. Lives in an `Arc` held by worker
/// closures and waiter hooks — not on the reactor thread — so a worker
/// finishing after shutdown still writes to a live fd.
struct ShardMailbox {
    inbox: Mutex<Vec<Event>>,
    wake: WakeFd,
}

impl ShardMailbox {
    fn post(&self, ev: Event) {
        self.inbox.lock().unwrap().push(ev);
        self.wake.wake();
    }
}

/// Messages to a reactor shard, from worker threads, waiter wake hooks
/// and (for `Accept`) the listener-owning shard. Workers never touch
/// sockets; all socket I/O happens on the owning shard.
enum Event {
    /// Shard 0 accepted a connection and round-robined it here.
    Accept { stream: TcpStream, peer: String },
    /// A request finished: queue these chunks. `serial` requests
    /// release the connection's serial execution slot (the next queued
    /// ordinary request dispatches); parked-poll completions do not
    /// hold one.
    Respond { conn: u64, chunks: Vec<Chunk>, serial: bool },
    /// A `FetchWait` found nothing ready: park it on the connection.
    Park { conn: u64, parked: Box<Parked> },
    /// A waiter wake hook fired for one parked poll.
    PollWake { conn: u64, corr: u64 },
    /// Protocol violation (bad CRC, unreadable envelope): drop the
    /// connection.
    Close { conn: u64 },
}

/// A parked `FetchWait`: everything needed to answer the long-poll
/// later. Dropping it deregisters the waiter from every wait-set (the
/// `guard`), so an abandoned park can never leak registrations.
struct Parked {
    corr: u64,
    assignments: Vec<(TopicPartition, u64)>,
    group: Option<(String, u64)>,
    /// Already capped by [`Cluster::register_data_wait`] for group
    /// liveness; the shard's timer heap fires it.
    ///
    /// [`Cluster::register_data_wait`]: crate::broker::Cluster::register_data_wait
    deadline: Instant,
    waiter: Waiter,
    /// Generation snapshot taken after registration; a wake that raced
    /// the park has already moved it.
    seen: u64,
    guard: DataWaitGuard,
    /// Scratch buffer for the eventual response frame.
    scratch: Vec<u8>,
}

struct Conn {
    stream: TcpStream,
    peer: String,
    /// Partial-frame accumulation across readiness events.
    rbuf: Vec<u8>,
    /// Outgoing chunks; `front_written` bytes of the front chunk are
    /// already in the socket.
    out: VecDeque<Chunk>,
    front_written: usize,
    /// Ordinary requests decoded but not yet dispatched — the serial
    /// queue. One entry at a time is on the worker pool (`busy`), so
    /// same-connection produces append in arrival order even though
    /// the read side keeps accepting frames.
    pending: VecDeque<(Bytes, u32)>,
    busy: bool,
    /// Parked long-polls keyed by correlation id — a multiplexed
    /// client can hold several at once on one socket.
    parks: HashMap<u64, Box<Parked>>,
    /// Decoded-but-unanswered request count (pending + busy + parked +
    /// completing). Gates read interest at [`MAX_INFLIGHT_PER_CONN`].
    inflight: usize,
    metrics_channel: bool,
    /// Set by a successful `Authenticate`; cloned into workers for
    /// quota charges. `None` on servers without auth enforcement.
    identity: Option<Identity>,
    eof: bool,
    last_activity: Instant,
    /// Interest currently registered with the poller.
    reg_read: bool,
    reg_write: bool,
    /// Recycled response scratch buffer (the codec encode path reuses
    /// it instead of allocating a fresh `Vec` per response frame).
    spare: Vec<u8>,
}

impl Conn {
    fn new(stream: TcpStream, peer: String) -> Conn {
        Conn {
            stream,
            peer,
            rbuf: Vec::new(),
            out: VecDeque::new(),
            front_written: 0,
            pending: VecDeque::new(),
            busy: false,
            parks: HashMap::new(),
            inflight: 0,
            metrics_channel: false,
            identity: None,
            eof: false,
            last_activity: Instant::now(),
            reg_read: true,
            reg_write: false,
            spare: Vec::new(),
        }
    }
}

/// The broker's TCP front door. See the module docs.
pub struct BrokerServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    reactors: Vec<std::thread::JoinHandle<()>>,
    workers: Option<Arc<ThreadPool>>,
}

impl BrokerServer {
    /// Bind `listen` (e.g. `127.0.0.1:9092`; port 0 = ephemeral) and
    /// serve `cluster` until [`BrokerServer::shutdown`], with
    /// [`DEFAULT_IO_WORKERS`] request workers and
    /// [`default_reactors`] reactor shards.
    pub fn start(listen: &str, cluster: ClusterHandle) -> Result<BrokerServer> {
        BrokerServer::start_with(listen, cluster, DEFAULT_IO_WORKERS)
    }

    /// [`BrokerServer::start`] with an explicit worker-pool size (the
    /// `--io-workers` CLI flag). The pool bounds concurrent request
    /// *handling*; connection count is bounded only by fds.
    pub fn start_with(listen: &str, cluster: ClusterHandle, io_workers: usize) -> Result<BrokerServer> {
        BrokerServer::start_sharded(listen, cluster, io_workers, default_reactors())
    }

    /// Fully explicit constructor: `reactors` event-loop shards (the
    /// `--reactors` CLI flag) sharing one `io_workers`-sized request
    /// pool. Accepted connections are round-robined across shards.
    pub fn start_sharded(
        listen: &str,
        cluster: ClusterHandle,
        io_workers: usize,
        reactors: usize,
    ) -> Result<BrokerServer> {
        BrokerServer::start_sharded_auth(listen, cluster, io_workers, reactors, None)
    }

    /// [`BrokerServer::start_sharded`] with an API-key table. When the
    /// table enforces auth ([`AuthKeys::require_auth`]), a connection's
    /// first accepted opcode must be [`OpCode::Authenticate`]; every
    /// other request before a successful authentication is answered
    /// with an error response (`Metric` frames, which are one-way on a
    /// dedicated socket, stay exempt). Produce and CreateTopic charge
    /// the authenticated tenant's quota.
    pub fn start_sharded_auth(
        listen: &str,
        cluster: ClusterHandle,
        io_workers: usize,
        reactors: usize,
        auth: Option<Arc<AuthKeys>>,
    ) -> Result<BrokerServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding broker on {listen}"))?;
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let addr = listener.local_addr()?;
        let n_shards = reactors.max(1);
        let mut mailboxes = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            mailboxes.push(Arc::new(ShardMailbox {
                inbox: Mutex::new(Vec::new()),
                wake: WakeFd::new().context("creating shard wake fd")?,
            }));
        }
        let shared = Arc::new(Shared {
            cluster,
            auth,
            cancel: CancelToken::new(),
            shutdown: Arc::new(WaitSet::new()),
            shards: mailboxes,
            next_shard: AtomicUsize::new(0),
            conn_counts: (0..n_shards).map(|_| AtomicUsize::new(0)).collect(),
        });
        let io_workers = io_workers.max(1);
        let workers = Arc::new(ThreadPool::new(io_workers, "broker-io"));
        let mut handles = Vec::with_capacity(n_shards);
        let mut listener = Some(listener);
        for shard in 0..n_shards {
            let mut poller = Poller::new().context("creating readiness poller")?;
            let shard_listener = if shard == 0 { listener.take() } else { None };
            if let Some(l) = &shard_listener {
                poller
                    .register(l.as_raw_fd(), TOKEN_LISTENER, true, false)
                    .context("registering listener")?;
            }
            let mailbox = shared.shards[shard].clone();
            poller
                .register(mailbox.wake.raw(), TOKEN_WAKE, true, false)
                .context("registering wake fd")?;
            let reactor = Reactor {
                shard,
                shared: shared.clone(),
                mailbox,
                workers: workers.clone(),
                listener: shard_listener,
                poller,
                conns: HashMap::new(),
                timers: BinaryHeap::new(),
                next_id: FIRST_CONN_TOKEN,
                read_buf: vec![0u8; READ_BUF_BYTES],
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("broker-reactor-{shard}"))
                    .spawn(move || reactor.run())?,
            );
        }
        log::info!(
            "broker wire protocol serving on {addr} ({n_shards} reactor shards + {io_workers} io workers)"
        );
        Ok(BrokerServer { addr, shared, reactors: handles, workers: Some(workers) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of reactor shards serving connections.
    pub fn reactors(&self) -> usize {
        self.shared.shards.len()
    }

    /// Live connection count per reactor shard (round-robin makes these
    /// near-uniform under load; the shard-distribution soak asserts it).
    pub fn shard_conn_counts(&self) -> Vec<usize> {
        self.shared
            .conn_counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.reactors.is_empty() {
            return;
        }
        self.shared.cancel.cancel();
        // Wake every parked long-poll registration (their hooks post
        // shard wakeups) and every reactor shard; each answers its
        // parked connections and exits.
        self.shared.shutdown.notify_all();
        for mb in &self.shared.shards {
            mb.wake.wake();
        }
        for handle in self.reactors.drain(..) {
            handle.join().ok();
        }
        // Drain in-flight request handlers: once the pool is joined, no
        // cluster call started by this server is still running. Late
        // posts from those handlers land in dead inboxes (each wake fd
        // stays alive inside its mailbox Arc) and are simply dropped.
        if let Some(workers) = self.workers.take() {
            match Arc::try_unwrap(workers) {
                Ok(pool) => pool.shutdown(),
                Err(arc) => drop(arc), // last ref joins via Drop
            }
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---- the reactor shards ----------------------------------------------------

/// What one carved frame is, decided by peeking the opcode byte — it
/// picks the dispatch lane before any decoding happens.
enum FrameKind {
    /// One-way; dispatches immediately, no response, no in-flight slot.
    Metric,
    /// `Authenticate`: handled synchronously on the reactor thread so
    /// the connection's identity is set before any later frame in the
    /// same buffer is parsed — no in-flight slot, no worker round-trip.
    Auth,
    /// Long-poll; dispatches immediately (parks instead of occupying
    /// the serial slot), so it can never head-of-line block a produce.
    Wait,
    /// Everything else: strictly serial per connection.
    Ordinary,
}

struct Reactor {
    shard: usize,
    shared: Arc<Shared>,
    mailbox: Arc<ShardMailbox>,
    workers: Arc<ThreadPool>,
    /// Some only on shard 0, which owns the accept loop.
    listener: Option<TcpListener>,
    poller: Poller,
    conns: HashMap<u64, Conn>,
    /// `(deadline, conn, corr)` min-heap for parked long-polls. Entries
    /// can go stale (the park completed early); firing one against a
    /// corr that is no longer parked is a no-op.
    timers: BinaryHeap<Reverse<(Instant, u64, u64)>>,
    next_id: u64,
    read_buf: Vec<u8>,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<PollerEvent> = Vec::new();
        let mut next_sweep = Instant::now() + SWEEP_INTERVAL;
        loop {
            if self.shared.cancel.is_cancelled() {
                break;
            }
            let now = Instant::now();
            let mut wake_at = next_sweep;
            if let Some(&Reverse((t, _, _))) = self.timers.peek() {
                wake_at = wake_at.min(t);
            }
            let timeout = wake_at.saturating_duration_since(now);
            events.clear();
            if let Err(e) = self.poller.wait(&mut events, Some(timeout)) {
                log::warn!("broker reactor {} poll error: {e}", self.shard);
            }
            if self.shared.cancel.is_cancelled() {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.mailbox.wake.drain(),
                    id => self.conn_ready(id, &ev),
                }
            }
            // Posts can land without the wake event racing into this
            // batch — always drain.
            self.drain_inbox();
            self.fire_timers();
            let now = Instant::now();
            if now >= next_sweep {
                self.sweep_idle(now);
                next_sweep = now + SWEEP_INTERVAL;
            }
        }
        self.shutdown_conns();
    }

    /// Shard 0 only: accept everything ready and round-robin it across
    /// shards — local registration for our own share, an `Accept` post
    /// for the rest.
    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nodelay(true).ok();
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let n = self.shared.shards.len();
                    let target = self.shared.next_shard.fetch_add(1, Ordering::Relaxed) % n;
                    if target == self.shard {
                        self.adopt_conn(stream, peer.to_string());
                    } else {
                        self.shared.shards[target]
                            .post(Event::Accept { stream, peer: peer.to_string() });
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("broker accept error: {e}");
                    return;
                }
            }
        }
    }

    /// Register an accepted connection with this shard's poller.
    fn adopt_conn(&mut self, stream: TcpStream, peer: String) {
        let id = self.next_id;
        self.next_id += 1;
        if let Err(e) = self.poller.register(stream.as_raw_fd(), id, true, false) {
            log::warn!("broker: registering {peer}: {e}");
            return;
        }
        self.conns.insert(id, Conn::new(stream, peer));
        self.shared.conn_counts[self.shard].fetch_add(1, Ordering::Relaxed);
    }

    fn conn_ready(&mut self, id: u64, ev: &PollerEvent) {
        if ev.writable {
            self.flush_conn(id);
        }
        if ev.readable || ev.hangup {
            self.read_conn(id);
            self.parse_frames(id);
        }
        self.finish_io(id);
    }

    /// Pull everything the socket has into the connection's frame
    /// buffer (via the shard's one staging buffer).
    fn read_conn(&mut self, id: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            match conn.stream.read(&mut self.read_buf) {
                Ok(0) => {
                    conn.eof = true;
                    return;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&self.read_buf[..n]);
                    conn.last_activity = Instant::now();
                    if n < self.read_buf.len() {
                        return;
                    }
                    // A torrential sender must not starve the loop: one
                    // max-size frame buffered is enough for one round.
                    if conn.rbuf.len() > codec::MAX_FRAME_BYTES as usize {
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::debug!("broker: reading from {}: {e}", conn.peer);
                    self.close_conn(id);
                    return;
                }
            }
        }
    }

    /// Carve every complete frame out of the connection buffer and
    /// dispatch it down its lane (pipelining). Stops only on incomplete
    /// bytes or the in-flight cap; the cap re-opens as responses drain.
    fn parse_frames(&mut self, id: u64) {
        enum Next {
            Frame { body: Bytes, crc: u32, kind: FrameKind },
            /// Unauthenticated request answered inline with an error.
            Rejected,
            Close,
            Done,
        }
        loop {
            let next = {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.rbuf.len() < codec::WIRE_HEADER_BYTES {
                    Next::Done
                } else {
                    let len = u32::from_le_bytes(conn.rbuf[0..4].try_into().unwrap());
                    let total = codec::WIRE_HEADER_BYTES + len as usize;
                    if len > codec::MAX_FRAME_BYTES {
                        log::debug!(
                            "broker: dropping {}: wire frame claims {len} bytes (max {})",
                            conn.peer,
                            codec::MAX_FRAME_BYTES
                        );
                        Next::Close
                    } else if conn.rbuf.len() < total {
                        Next::Done
                    } else {
                        // Peek the opcode (after the correlation id) to
                        // pick the dispatch lane. `Metric` is one-way —
                        // fire-and-forget, exempt from the in-flight
                        // cap — and marks the connection as the
                        // client's dedicated metrics channel.
                        let op = codec::peek_op(&conn.rbuf[codec::WIRE_HEADER_BYTES..total]);
                        let kind = match op {
                            Some(v) if v == OpCode::Metric as u8 => FrameKind::Metric,
                            Some(v) if v == OpCode::Authenticate as u8 => FrameKind::Auth,
                            Some(v) if v == OpCode::FetchWait as u8 => FrameKind::Wait,
                            _ => FrameKind::Ordinary,
                        };
                        // With auth enforced, an unauthenticated
                        // connection may speak only `Authenticate`
                        // (and one-way `Metric`): everything else is
                        // answered with an error, never dispatched.
                        let unauthenticated = conn.identity.is_none()
                            && matches!(kind, FrameKind::Wait | FrameKind::Ordinary)
                            && self
                                .shared
                                .auth
                                .as_ref()
                                .is_some_and(|a| a.require_auth());
                        if !matches!(kind, FrameKind::Metric | FrameKind::Auth)
                            && !unauthenticated
                            && conn.inflight >= MAX_INFLIGHT_PER_CONN
                        {
                            // Backpressure: leave the frame buffered;
                            // the Respond that drains the cap re-parses.
                            Next::Done
                        } else {
                            let crc = u32::from_le_bytes(conn.rbuf[4..8].try_into().unwrap());
                            let body = Bytes::copy_from_slice(
                                &conn.rbuf[codec::WIRE_HEADER_BYTES..total],
                            );
                            conn.rbuf.drain(..total);
                            conn.last_activity = Instant::now();
                            if unauthenticated {
                                // Corruption still drops the socket;
                                // an intact frame gets a decodable
                                // error on its own correlation id.
                                if format::crc32(body.as_slice()) != crc || body.len() < 9 {
                                    Next::Close
                                } else {
                                    let corr = u64::from_le_bytes(
                                        body.as_slice()[0..8].try_into().unwrap(),
                                    );
                                    let mut buf = Vec::new();
                                    codec::encode_response_into(
                                        &mut buf,
                                        corr,
                                        Err("unauthenticated: present an API key with Authenticate first"),
                                    );
                                    conn.out.push_back(Chunk::Owned(buf));
                                    Next::Rejected
                                }
                            } else {
                                match kind {
                                    FrameKind::Metric => conn.metrics_channel = true,
                                    FrameKind::Auth => {}
                                    FrameKind::Wait => conn.inflight += 1,
                                    FrameKind::Ordinary => {
                                        conn.inflight += 1;
                                        conn.pending.push_back((body.clone(), crc));
                                    }
                                }
                                Next::Frame { body, crc, kind }
                            }
                        }
                    }
                }
            };
            match next {
                Next::Done => break,
                Next::Rejected => continue,
                Next::Close => {
                    self.close_conn(id);
                    return;
                }
                Next::Frame { body, crc, kind } => {
                    let shared = self.shared.clone();
                    let mailbox = self.mailbox.clone();
                    match kind {
                        // Synchronous: identity must be visible to the
                        // very next frame in this buffer.
                        FrameKind::Auth => self.handle_auth_frame(id, body, crc),
                        FrameKind::Metric => self
                            .workers
                            .execute(move || handle_metric(&shared, &mailbox, id, body, crc)),
                        // Long-polls bypass the serial queue: they park
                        // rather than occupy a worker, so dispatch now.
                        FrameKind::Wait => {
                            let identity = self.conns.get(&id).and_then(|c| c.identity.clone());
                            self.workers.execute(move || {
                                handle_request(
                                    &shared, &mailbox, id, body, crc, Vec::new(), false, identity,
                                )
                            })
                        }
                        FrameKind::Ordinary => {} // dispatched below, serially
                    }
                }
            }
        }
        self.maybe_dispatch(id);
        self.update_interest(id);
    }

    /// `Authenticate`, handled inline on the reactor thread: validate
    /// the frame, resolve the key, set the connection's identity, and
    /// queue the response. A server without a key table accepts any
    /// credential (auth is a no-op); unknown and revoked keys answer
    /// distinct errors but keep the connection open, so a client can
    /// retry with a better key.
    fn handle_auth_frame(&mut self, id: u64, body: Bytes, crc: u32) {
        if format::crc32(body.as_slice()) != crc {
            self.close_conn(id);
            return;
        }
        let mut r = Reader::new(body);
        let (Ok(corr), Ok(_op)) = (r.u64(), r.u8()) else {
            self.close_conn(id);
            return;
        };
        let Ok(token) = r.str() else {
            self.close_conn(id);
            return;
        };
        let mut identity = None;
        let outcome: Result<(), &str> = match &self.shared.auth {
            Some(auth) => match auth.authenticate(&token) {
                AuthOutcome::Accepted(ident) => {
                    identity = Some(ident);
                    Ok(())
                }
                AuthOutcome::Revoked => Err("key revoked"),
                AuthOutcome::Expired => Err("key expired"),
                AuthOutcome::Unknown => Err("unknown key"),
            },
            None => Ok(()),
        };
        let Some(conn) = self.conns.get_mut(&id) else { return };
        let mut buf = Vec::new();
        match outcome {
            Ok(()) => {
                if identity.is_some() {
                    conn.identity = identity;
                }
                codec::begin_response(&mut buf, corr);
                codec::finish_frame(&mut buf);
            }
            Err(msg) => codec::encode_response_into(&mut buf, corr, Err(msg)),
        }
        conn.out.push_back(Chunk::Owned(buf));
        conn.last_activity = Instant::now();
    }

    /// Feed the serial lane: if no ordinary request is executing for
    /// this connection, put the oldest queued one on the worker pool.
    fn maybe_dispatch(&mut self, id: u64) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        if conn.busy {
            return;
        }
        let Some((body, crc)) = conn.pending.pop_front() else { return };
        conn.busy = true;
        let scratch = std::mem::take(&mut conn.spare);
        let identity = conn.identity.clone();
        let shared = self.shared.clone();
        let mailbox = self.mailbox.clone();
        self.workers
            .execute(move || handle_request(&shared, &mailbox, id, body, crc, scratch, true, identity));
    }

    /// Drain the outgoing chunk queue with vectored writes until the
    /// socket blocks or the queue empties.
    fn flush_conn(&mut self, id: u64) {
        loop {
            let outcome = {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.out.is_empty() {
                    return;
                }
                let mut slices: Vec<&[u8]> =
                    Vec::with_capacity(conn.out.len().min(MAX_WRITEV_SEGMENTS));
                for (i, c) in conn.out.iter().take(MAX_WRITEV_SEGMENTS).enumerate() {
                    let s = c.as_slice();
                    slices.push(if i == 0 { &s[conn.front_written..] } else { s });
                }
                reactor::writev(conn.stream.as_raw_fd(), &slices)
            };
            match outcome {
                Ok(0) => return,
                Ok(n) => {
                    let Some(conn) = self.conns.get_mut(&id) else { return };
                    Reactor::advance_out(conn, n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    if let Some(conn) = self.conns.get(&id) {
                        log::debug!("broker: writing to {}: {e}", conn.peer);
                    }
                    self.close_conn(id);
                    return;
                }
            }
        }
    }

    /// Account `n` written bytes against the front of the queue,
    /// recycling fully-written owned chunks into the scratch buffer.
    fn advance_out(conn: &mut Conn, mut n: usize) {
        while n > 0 {
            let Some(front) = conn.out.front() else { return };
            let avail = front.len() - conn.front_written;
            if n < avail {
                conn.front_written += n;
                return;
            }
            n -= avail;
            conn.front_written = 0;
            if let Some(Chunk::Owned(mut v)) = conn.out.pop_front() {
                if v.capacity() > conn.spare.capacity() {
                    v.clear();
                    conn.spare = v;
                }
            }
        }
    }

    /// Post-I/O bookkeeping: release oversized buffers, close drained
    /// EOF connections, sync poller interest.
    fn finish_io(&mut self, id: u64) {
        let close = {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            if conn.rbuf.is_empty() && conn.rbuf.capacity() > RBUF_KEEP_BYTES {
                conn.rbuf = Vec::new();
            }
            // A hung-up peer abandons its parked polls outright (their
            // guards deregister); requests still executing finish their
            // cycle first so the worker's Respond lands on a vanished
            // conn as a no-op.
            conn.eof
                && (!conn.parks.is_empty() || (conn.inflight == 0 && conn.out.is_empty()))
        };
        if close {
            self.close_conn(id);
            return;
        }
        self.update_interest(id);
    }

    fn update_interest(&mut self, id: u64) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        let want_read = !conn.eof && conn.inflight < MAX_INFLIGHT_PER_CONN;
        let want_write = !conn.out.is_empty();
        if want_read != conn.reg_read || want_write != conn.reg_write {
            if let Err(e) = self
                .poller
                .modify(conn.stream.as_raw_fd(), id, want_read, want_write)
            {
                log::debug!("broker: poller modify for {}: {e}", conn.peer);
            } else {
                conn.reg_read = want_read;
                conn.reg_write = want_write;
            }
        }
    }

    fn close_conn(&mut self, id: u64) {
        if let Some(conn) = self.conns.remove(&id) {
            self.poller.deregister(conn.stream.as_raw_fd()).ok();
            self.shared.conn_counts[self.shard].fetch_sub(1, Ordering::Relaxed);
            log::debug!("broker: {} disconnected", conn.peer);
            // Dropping `conn` closes the socket; every parked poll's
            // guard deregisters its waiter from every wait-set.
        }
    }

    fn drain_inbox(&mut self) {
        loop {
            let batch: Vec<Event> = std::mem::take(&mut *self.mailbox.inbox.lock().unwrap());
            if batch.is_empty() {
                return;
            }
            for ev in batch {
                self.handle_event(ev);
            }
        }
    }

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::Accept { stream, peer } => self.adopt_conn(stream, peer),
            Event::Respond { conn: id, chunks, serial } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if serial {
                    conn.busy = false;
                }
                conn.inflight = conn.inflight.saturating_sub(1);
                for c in chunks {
                    if c.is_empty() {
                        // Degenerate chunk: recycle its buffer.
                        if let Chunk::Owned(v) = c {
                            if v.capacity() > conn.spare.capacity() {
                                conn.spare = v;
                            }
                        }
                    } else {
                        conn.out.push_back(c);
                    }
                }
                self.maybe_dispatch(id);
                self.flush_conn(id);
                self.parse_frames(id); // the cap may have re-opened
                self.finish_io(id);
            }
            Event::Park { conn: id, parked } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.eof {
                    // Client already gone: abandon the long-poll.
                    self.close_conn(id);
                    return;
                }
                if self.shared.cancel.is_cancelled()
                    || parked.waiter.generation() != parked.seen
                    || conn.parks.contains_key(&parked.corr)
                {
                    // A wake raced the park decision (the hook's
                    // PollWake may even sit earlier in this inbox, a
                    // no-op until the park registers) — or the client
                    // reused a parked correlation id, which would make
                    // the demux ambiguous: complete now instead.
                    self.complete_wait_async(id, parked);
                } else {
                    self.timers.push(Reverse((parked.deadline, id, parked.corr)));
                    conn.parks.insert(parked.corr, parked);
                    self.update_interest(id);
                }
            }
            Event::PollWake { conn: id, corr } => {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if let Some(parked) = conn.parks.remove(&corr) {
                    self.complete_wait_async(id, parked);
                }
                // Absent: a stale wake for a park that already
                // completed — ignore.
            }
            Event::Close { conn: id } => self.close_conn(id),
        }
    }

    /// Answer a (completed or expired) park on the worker pool — the
    /// readiness re-check takes cluster locks, which stay off the
    /// reactor thread.
    fn complete_wait_async(&self, id: u64, parked: Box<Parked>) {
        let shared = self.shared.clone();
        let mailbox = self.mailbox.clone();
        self.workers
            .execute(move || complete_wait(&shared, &mailbox, id, parked));
    }

    fn fire_timers(&mut self) {
        let now = Instant::now();
        while let Some(&Reverse((t, id, corr))) = self.timers.peek() {
            if t > now {
                return;
            }
            self.timers.pop();
            let Some(conn) = self.conns.get_mut(&id) else { continue };
            let Some(deadline) = conn.parks.get(&corr).map(|p| p.deadline) else {
                continue; // park already completed — stale entry
            };
            if deadline <= now {
                let parked = conn.parks.remove(&corr).unwrap();
                self.complete_wait_async(id, parked);
            } else {
                // Stale entry from an earlier park that reused this
                // corr; re-arm for the current deadline.
                self.timers.push(Reverse((deadline, id, corr)));
            }
        }
    }

    fn sweep_idle(&mut self, now: Instant) {
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.inflight == 0
                    && !c.metrics_channel
                    && c.out.is_empty()
                    && now.duration_since(c.last_activity) >= IDLE_TIMEOUT
            })
            .map(|(id, _)| *id)
            .collect();
        for id in stale {
            self.close_conn(id);
        }
    }

    /// Shutdown path: answer every parked long-poll (`woken = true` —
    /// the client re-checks and observes the shutdown), flush
    /// best-effort, close everything.
    fn shutdown_conns(&mut self) {
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let Some(conn) = self.conns.get_mut(&id) else { continue };
            let parked: Vec<Box<Parked>> =
                conn.parks.drain().map(|(_, p)| p).collect();
            for p in parked {
                let Parked { corr, guard, mut scratch, .. } = *p;
                drop(guard);
                codec::begin_response(&mut scratch, corr);
                codec::put_bool(&mut scratch, true);
                codec::finish_frame(&mut scratch);
                conn.out.push_back(Chunk::Owned(scratch));
            }
            // A parked response is a handful of bytes into an empty
            // socket buffer: this all but always completes. A socket
            // mid-backpressure just loses its tail — the client sees
            // EOF and reports the disconnect.
            self.flush_conn(id);
        }
        self.shared.conn_counts[self.shard].store(0, Ordering::Relaxed);
        self.conns.clear();
    }
}

// ---- request handling (worker pool) ----------------------------------------

/// One-way `Metric` frame: validate, decode, bump the counter. No
/// response; a CRC failure still drops the connection like any other
/// corrupt frame.
fn handle_metric(shared: &Arc<Shared>, mailbox: &Arc<ShardMailbox>, conn: u64, body: Bytes, crc: u32) {
    if format::crc32(body.as_slice()) != crc {
        mailbox.post(Event::Close { conn });
        return;
    }
    let mut r = Reader::new(body);
    let (Ok(_corr), Ok(_op)) = (r.u64(), r.u8()) else {
        mailbox.post(Event::Close { conn });
        return;
    };
    if let Err(e) = metric_payload(shared, &mut r) {
        log::debug!("broker: bad metric frame: {e:#}");
    }
}

fn metric_payload(shared: &Arc<Shared>, r: &mut Reader) -> Result<()> {
    let delta = r.u64()?;
    let name = r.str()?;
    shared.cluster.metrics.counter(&name).add(delta);
    Ok(())
}

/// Handle one request frame end-to-end on a worker thread: CRC check,
/// envelope decode, dispatch, response encode (into the connection's
/// recycled scratch buffer), and a `Respond`/`Park`/`Close` post back
/// to the owning shard. `serial` echoes through to the `Respond` so the
/// shard knows whether to release the connection's serial slot.
fn handle_request(
    shared: &Arc<Shared>,
    mailbox: &Arc<ShardMailbox>,
    conn: u64,
    body: Bytes,
    crc: u32,
    mut scratch: Vec<u8>,
    serial: bool,
    identity: Option<Identity>,
) {
    if format::crc32(body.as_slice()) != crc {
        mailbox.post(Event::Close { conn });
        return;
    }
    let mut r = Reader::new(body);
    // If even the envelope is unreadable there is no correlation id to
    // answer on — drop the connection.
    let (Ok(corr), Ok(op_byte)) = (r.u64(), r.u8()) else {
        mailbox.post(Event::Close { conn });
        return;
    };
    let Some(op) = OpCode::from_u8(op_byte) else {
        codec::encode_response_into(&mut scratch, corr, Err(&format!("unknown opcode {op_byte}")));
        mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial });
        return;
    };
    match op {
        OpCode::FetchBatch => {
            let chunks = fetch_batch_chunks(shared, &mut r, corr, scratch, identity.as_ref());
            mailbox.post(Event::Respond { conn, chunks, serial });
        }
        OpCode::FetchWait => {
            fetch_wait(shared, mailbox, conn, &mut r, corr, scratch, serial, identity.as_ref())
        }
        OpCode::Metric => {
            // Normally dispatched one-way straight from the reactor;
            // reaching here (a short body defeated the opcode peek)
            // still completes the request cycle, without a response.
            if let Err(e) = metric_payload(shared, &mut r) {
                log::debug!("broker: bad metric frame: {e:#}");
            }
            scratch.clear();
            mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial });
        }
        _ => {
            codec::begin_response(&mut scratch, corr);
            match dispatch_simple(op, &mut r, shared, identity.as_ref(), &mut scratch) {
                Ok(()) => codec::finish_frame(&mut scratch),
                Err(e) => codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}"))),
            }
            mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial });
        }
    }
}

/// `FetchBatch`: bound the response to the frame limit, then encode it
/// as gather-write chunks — header bytes in the scratch buffer, large
/// record values as zero-copy slices of the broker log.
fn fetch_batch_chunks(
    shared: &Arc<Shared>,
    r: &mut Reader,
    corr: u64,
    mut scratch: Vec<u8>,
    identity: Option<&Identity>,
) -> Vec<Chunk> {
    let fetched = (|| -> Result<_> {
        let partition = r.u32()?;
        let from = r.u64()?;
        let max = r.u32()? as usize;
        let topic = scoped_topic(shared, identity, &r.str()?);
        // Optional trailing routing epoch (cluster-aware clients);
        // absent on legacy payloads, where the read simply runs out of
        // bytes.
        let epoch = r.opt(|r| r.u64()).unwrap_or(None);
        if let Some(ctl) = shared.cluster.clusterctl() {
            ctl.check_leader(&topic, partition, epoch)?;
        }
        let batch =
            shared
                .cluster
                .fetch_batch(&topic, partition, from, max, ClientLocality::Remote)?;
        // Bound the RESPONSE to the frame limit too: the client
        // hard-rejects oversized frames, so an unbounded batch of
        // large records would wedge the consumer forever. Return a
        // prefix instead — fetch's contract is "up to max", and the
        // consumer advances through the rest in later fetches.
        let budget = codec::MAX_FRAME_BYTES as usize - 1024; // envelope headroom
        let mut bytes = 4usize; // record-count prefix
        let mut take = 0usize;
        for (offset, rec) in &batch.records {
            let frame = format::frame_size(rec);
            if bytes + frame > budget {
                if take == 0 {
                    anyhow::bail!(
                        "record at {topic}:{partition}@{offset} ({frame} bytes) \
                         exceeds the wire frame limit"
                    );
                }
                break;
            }
            bytes += frame;
            take += 1;
        }
        Ok((batch, take))
    })();
    match fetched {
        Ok((batch, take)) => codec::encode_fetch_response_chunks(
            scratch,
            corr,
            batch.records.iter().take(take).map(|(o, rec)| (*o, rec)),
        ),
        Err(e) => {
            codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}")));
            vec![Chunk::Owned(scratch)]
        }
    }
}

/// `FetchWait`: register with the cluster's wait-sets (plus the server
/// shutdown set), bridge wakes to the owning shard through the waiter
/// hook, and either answer immediately (data already there, or a wake
/// raced registration) or hand the shard a [`Parked`] to hold. The
/// connection costs a registration and a timer entry while parked —
/// no thread, and no serial slot: requests behind it keep flowing.
fn fetch_wait(
    shared: &Arc<Shared>,
    mailbox: &Arc<ShardMailbox>,
    conn: u64,
    r: &mut Reader,
    corr: u64,
    mut scratch: Vec<u8>,
    serial: bool,
    identity: Option<&Identity>,
) {
    let parsed = (|| -> Result<_> {
        let timeout_ms = r.u64()?;
        let group = r.opt(|r| Ok((r.str()?, r.u64()?)))?;
        let n = r.u32()? as usize;
        let mut assignments: Vec<(TopicPartition, u64)> = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let topic = scoped_topic(shared, identity, &r.str()?);
            let p = r.u32()?;
            let pos = r.u64()?;
            assignments.push(((topic, p), pos));
        }
        Ok((timeout_ms, group, assignments))
    })();
    let (timeout_ms, group, assignments) = match parsed {
        Ok(t) => t,
        Err(e) => {
            codec::encode_response_into(&mut scratch, corr, Err(&format!("{e:#}")));
            mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial });
            return;
        }
    };
    let wait = Duration::from_millis(timeout_ms).min(MAX_WAIT_SLICE);
    let waiter = Waiter::new();
    // Install the hook BEFORE registering: every wake after this point
    // posts a shard wakeup for this (connection, corr) park.
    let hook_mailbox = mailbox.clone();
    waiter.set_hook(move || hook_mailbox.post(Event::PollWake { conn, corr }));
    let (guard, deadline) = shared.cluster.register_data_wait(
        &waiter,
        &assignments,
        group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)),
        Instant::now() + wait,
        Some(&shared.shutdown),
    );
    let seen = waiter.generation();
    // Register → snapshot → check: data (or cancellation) that landed
    // before the snapshot is answered without parking; anything after
    // it has already fired the hook.
    if shared.cancel.is_cancelled()
        || shared
            .cluster
            .data_wait_ready(&assignments, group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)))
    {
        drop(guard);
        codec::begin_response(&mut scratch, corr);
        codec::put_bool(&mut scratch, true);
        codec::finish_frame(&mut scratch);
        mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial });
        return;
    }
    mailbox.post(Event::Park {
        conn,
        parked: Box::new(Parked {
            corr,
            assignments,
            group,
            deadline,
            waiter,
            seen,
            guard,
            scratch,
        }),
    });
}

/// Answer a park that completed (wake, timeout, or shutdown): re-check
/// readiness, deregister, encode `woken` into the carried scratch.
fn complete_wait(shared: &Arc<Shared>, mailbox: &Arc<ShardMailbox>, conn: u64, parked: Box<Parked>) {
    let Parked { corr, assignments, group, waiter, seen, guard, mut scratch, .. } = *parked;
    let woken = shared.cancel.is_cancelled()
        || waiter.generation() != seen
        || shared
            .cluster
            .data_wait_ready(&assignments, group.as_ref().map(|(gid, gen)| (gid.as_str(), *gen)));
    drop(guard);
    codec::begin_response(&mut scratch, corr);
    codec::put_bool(&mut scratch, woken);
    codec::finish_frame(&mut scratch);
    mailbox.post(Event::Respond { conn, chunks: vec![Chunk::Owned(scratch)], serial: false });
}

/// Is tenant namespacing in force for this caller? Only when auth is
/// enforced AND the identity is a non-admin tenant key — admins and
/// unauthenticated deployments see the flat internal namespace.
fn tenant_scope<'a>(shared: &Shared, identity: Option<&'a Identity>) -> Option<&'a Identity> {
    let auth_on = shared.auth.as_ref().is_some_and(|a| a.require_auth());
    identity.filter(|ident| auth_on && !ident.admin)
}

/// The broker-internal name for a client-visible topic: prefixed
/// `{tenant}::` under tenant namespacing, unchanged otherwise. Two
/// tenants can each own an `mnist-train` without colliding. Placement
/// ([`crate::broker::clusterctl`]) hashes the bare suffix, so the
/// scoped name lands on the same leader the client routed to.
fn scoped_topic(shared: &Shared, identity: Option<&Identity>, topic: &str) -> String {
    match tenant_scope(shared, identity) {
        Some(ident) => format!("{}::{topic}", ident.tenant),
        None => topic.to_string(),
    }
}

/// Egress inverse of [`scoped_topic`]: the name the caller may see —
/// the bare suffix of their own topics, `None` for anyone else's.
fn visible_topic<'a>(
    shared: &Shared,
    identity: Option<&Identity>,
    topic: &'a str,
) -> Option<&'a str> {
    match tenant_scope(shared, identity) {
        Some(ident) => topic
            .strip_prefix(&ident.tenant)
            .and_then(|rest| rest.strip_prefix("::")),
        None => Some(topic),
    }
}

/// Strip the caller's tenant prefix from group-assignment egress (a
/// scoped join only ever assigns the caller's own topics).
fn strip_assigned(shared: &Shared, identity: Option<&Identity>, assigned: &mut [TopicPartition]) {
    for tp in assigned.iter_mut() {
        let stripped = match visible_topic(shared, identity, &tp.0) {
            Some(bare) if bare.len() != tp.0.len() => Some(bare.to_string()),
            _ => None,
        };
        if let Some(bare) = stripped {
            tp.0 = bare;
        }
    }
}

/// Cluster-management opcodes are broker-to-broker surface: with auth
/// enforced they require an admin key (peers dial each other with the
/// operator's key), so a tenant key can never rewrite membership or
/// siphon raw partition frames.
fn require_admin_op(shared: &Shared, identity: Option<&Identity>, what: &str) -> Result<()> {
    if shared.auth.as_ref().is_some_and(|a| a.require_auth())
        && !identity.is_some_and(|ident| ident.admin)
    {
        anyhow::bail!("{what} requires an admin key");
    }
    Ok(())
}

/// Decode one request payload and run it against the cluster, writing
/// the response payload straight into the (envelope-prefixed) scratch
/// buffer. Decoding happens *entirely* before the cluster call, so a
/// malformed payload can never leave a partition lock poisoned or a
/// group half-updated. On error the caller re-encodes the buffer as an
/// error response — partial payload bytes are simply discarded.
fn dispatch_simple(
    op: OpCode,
    r: &mut Reader,
    shared: &Arc<Shared>,
    identity: Option<&Identity>,
    out: &mut Vec<u8>,
) -> Result<()> {
    let cluster = &shared.cluster;
    match op {
        OpCode::CreateTopic => {
            let partitions = r.u32()?;
            let topic = scoped_topic(shared, identity, &r.str()?);
            // A tenant at its stored-bytes ceiling can't create more
            // storage-bearing resources.
            if let (Some(auth), Some(ident)) = (&shared.auth, identity) {
                if auth.storage_exhausted(ident) {
                    anyhow::bail!("quota: stored-bytes ceiling reached");
                }
            }
            // Apply LOCALLY only (0 = broker default partitions). The
            // cluster-aware *client* fans CreateTopic out to every
            // broker — as does the in-process transport's trait impl —
            // so a server-side fan-out here would ping-pong the create
            // between brokers forever.
            let t = if partitions == 0 {
                cluster.topic_or_create(&topic)
            } else {
                cluster.create_topic(&topic, partitions)
            };
            codec::put_u32(out, t.num_partitions());
        }
        OpCode::Metadata => {
            let topic = scoped_topic(shared, identity, &r.str()?);
            let parts = cluster.topic(&topic).map(|t| t.num_partitions());
            codec::put_opt(out, parts.as_ref(), |o, n| codec::put_u32(o, *n));
        }
        OpCode::ListTopics => {
            let names: Vec<String> = cluster
                .topic_names()
                .into_iter()
                .filter_map(|t| visible_topic(shared, identity, &t).map(str::to_string))
                .collect();
            codec::put_strings(out, &names);
        }
        OpCode::Produce => {
            let partition = r.u32()?;
            let seq = r.opt(|r| Ok((r.u64()?, r.u64()?)))?;
            let topic = scoped_topic(shared, identity, &r.str()?);
            // Zero-copy: each decoded record's payloads are slices of
            // the request buffer; the append below shares them.
            let records: Vec<Record> = r.records()?.into_iter().map(|(_, rec)| rec).collect();
            // Optional trailing routing epoch (cluster-aware clients);
            // absent on legacy payloads.
            let epoch = r.opt(|r| r.u64()).unwrap_or(None);
            // Fence BEFORE charging quota: a produce refused for
            // routing reasons must not spend the tenant's rate budget.
            if let Some(ctl) = cluster.clusterctl() {
                ctl.check_leader(&topic, partition, epoch)?;
            }
            // Quota: charge rate + stored bytes against the tenant
            // BEFORE appending — a rejected produce stores nothing.
            if let (Some(auth), Some(ident)) = (&shared.auth, identity) {
                let bytes: u64 = records.iter().map(|rec| format::frame_size(rec) as u64).sum();
                auth.charge_produce(ident, records.len() as u64, bytes)
                    .map_err(|_| anyhow::anyhow!("quota: tenant produce quota exceeded"))?;
            }
            let base = cluster.produce(&topic, partition, &records, ClientLocality::Remote, seq)?;
            codec::put_u64(out, base);
        }
        OpCode::Offsets => {
            let partition = r.u32()?;
            let topic = scoped_topic(shared, identity, &r.str()?);
            let (earliest, latest) = cluster.offsets(&topic, partition)?;
            codec::put_u64(out, earliest);
            codec::put_u64(out, latest);
        }
        OpCode::AllocProducerId => {
            codec::put_u64(out, cluster.alloc_producer_id());
        }
        OpCode::JoinGroup => {
            let assignor = codec::assignor_from_u8(r.u8()?)?;
            let gid = r.str()?;
            let member = r.str()?;
            // Subscriptions resolve against internal names; the
            // assignments echo back bare. Group ids stay unscoped —
            // they carry no data and scoping them would break
            // cross-tenant ops dashboards.
            let topics: Vec<String> = r
                .strings()?
                .iter()
                .map(|t| scoped_topic(shared, identity, t))
                .collect();
            let mut m = cluster.join_group(&gid, &member, &topics, assignor);
            strip_assigned(shared, identity, &mut m.assigned);
            codec::put_membership(out, &m);
        }
        OpCode::LeaveGroup => {
            let gid = r.str()?;
            let member = r.str()?;
            cluster.leave_group(&gid, &member);
        }
        OpCode::Heartbeat => {
            let gid = r.str()?;
            let member = r.str()?;
            let mut m = cluster.heartbeat(&gid, &member);
            if let Some(m) = &mut m {
                strip_assigned(shared, identity, &mut m.assigned);
            }
            codec::put_opt(out, m.as_ref(), codec::put_membership);
        }
        OpCode::CommitOffsets => {
            let gid = r.str()?;
            let n = r.u32()? as usize;
            let mut offsets: Vec<(TopicPartition, u64)> = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let topic = scoped_topic(shared, identity, &r.str()?);
                let p = r.u32()?;
                let off = r.u64()?;
                offsets.push(((topic, p), off));
            }
            // Same trait impl as the in-process transport — no drift.
            BrokerTransport::commit_offsets(&**cluster, &gid, &offsets)?;
        }
        OpCode::CommittedOffset => {
            let gid = r.str()?;
            let topic = scoped_topic(shared, identity, &r.str()?);
            let p = r.u32()?;
            let committed = cluster.committed_offset(&gid, &(topic, p));
            codec::put_opt(out, committed.as_ref(), |o, v| codec::put_u64(o, *v));
        }
        OpCode::ClusterMeta => {
            // Readable by any authenticated caller: clients need the
            // roster + epoch to route; it names brokers, not data.
            codec::put_cluster_view(out, &cluster.cluster_view());
        }
        OpCode::ClusterUpdate => {
            require_admin_op(shared, identity, "ClusterUpdate")?;
            let view = r.cluster_view()?;
            cluster.install_cluster_view(view)?;
        }
        OpCode::ReplicaFetch => {
            require_admin_op(shared, identity, "ReplicaFetch")?;
            let partition = r.u32()?;
            let from = r.u64()?;
            let max = r.u32()? as usize;
            let ack = r.u64()?;
            // Internal (possibly tenant-scoped) name verbatim: the
            // follower mirrors the leader's namespace exactly.
            let topic = r.str()?;
            let (hwm, batch) = cluster.replica_fetch(&topic, partition, from, max, ack)?;
            codec::put_u64(out, hwm);
            // Bound the response to the frame limit like FetchBatch:
            // replication advances through the rest next round.
            let budget = codec::MAX_FRAME_BYTES as usize - 1024;
            let mut bytes = 4usize;
            let mut take = 0usize;
            for (offset, rec) in &batch.records {
                let frame = format::frame_size(rec);
                if bytes + frame > budget {
                    if take == 0 {
                        anyhow::bail!(
                            "record at {topic}:{partition}@{offset} ({frame} bytes) \
                             exceeds the wire frame limit"
                        );
                    }
                    break;
                }
                bytes += frame;
                take += 1;
            }
            codec::put_records(out, batch.records.iter().take(take).map(|(o, rec)| (*o, rec)));
        }
        // The reactor answers Authenticate inline; a frame whose short
        // body defeated the opcode peek still lands here — answer it
        // as an error rather than asserting.
        OpCode::Authenticate => anyhow::bail!("malformed Authenticate frame"),
        // Handled before dispatch_simple is reached.
        OpCode::FetchBatch | OpCode::FetchWait | OpCode::Metric => unreachable!(),
    }
    Ok(())
}
