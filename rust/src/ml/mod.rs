//! ML data plumbing on the Rust side: batch assembly from decoded stream
//! samples, train/validation splitting (`validation_rate`), metric
//! aggregation, and the synthetic datasets used by examples/tests/benches
//! (the HCOPD generator substitutes the paper's non-redistributable
//! dataset — see DESIGN.md §Substitutions).

mod batch;
mod data;

pub use batch::{epoch_batches, split_validation, Batcher, MetricAverager};
pub use data::{hcopd_dataset, mnist_like_dataset, separable_dataset, Dataset};
