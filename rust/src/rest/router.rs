//! Path router with `:param` segments.

use super::http::{Method, Request, Response, Status};
use std::collections::BTreeMap;
use std::sync::Arc;

type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

struct Route {
    method: Method,
    segments: Vec<Segment>,
    handler: Handler,
}

enum Segment {
    Literal(String),
    Param(String),
}

#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn route<F>(mut self, method: Method, pattern: &str, f: F) -> Router
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let segments = pattern
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if let Some(name) = s.strip_prefix(':') {
                    Segment::Param(name.to_string())
                } else {
                    Segment::Literal(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route { method, segments, handler: Arc::new(f) });
        self
    }

    pub fn dispatch(&self, mut req: Request) -> Response {
        let path: Vec<&str> = req
            .path
            .split('?')
            .next()
            .unwrap_or("")
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .collect();
        for route in &self.routes {
            if route.method != req.method || route.segments.len() != path.len() {
                continue;
            }
            let mut params = BTreeMap::new();
            let matched = route.segments.iter().zip(&path).all(|(seg, part)| match seg {
                Segment::Literal(l) => l == part,
                Segment::Param(name) => {
                    params.insert(name.clone(), (*part).to_string());
                    true
                }
            });
            if matched {
                req.params = params;
                return (route.handler)(req);
            }
        }
        Response::error(Status::NotFound, &format!("no route for {}", req.path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new()
            .route(Method::Get, "/models", |_| {
                Response::json(Status::Ok, &crate::json::Json::str("list"))
            })
            .route(Method::Get, "/models/:id", |req| {
                Response::json(
                    Status::Ok,
                    &crate::json::Json::str(format!("model {}", req.param("id").unwrap())),
                )
            })
            .route(Method::Post, "/models", |_| Response::status(Status::Created))
            .route(Method::Get, "/models/:id/download", |req| {
                Response::binary(Status::Ok, req.param("id").unwrap().as_bytes().to_vec())
            })
    }

    #[test]
    fn literal_and_param_routes() {
        let r = router();
        let resp = r.dispatch(Request::new(Method::Get, "/models"));
        assert_eq!(resp.status, Status::Ok);
        let resp = r.dispatch(Request::new(Method::Get, "/models/42"));
        assert!(String::from_utf8_lossy(&resp.body).contains("model 42"));
        let resp = r.dispatch(Request::new(Method::Get, "/models/42/download"));
        assert_eq!(resp.body, b"42");
    }

    #[test]
    fn method_mismatch_is_404() {
        let r = router();
        let resp = r.dispatch(Request::new(Method::Delete, "/models"));
        assert_eq!(resp.status, Status::NotFound);
    }

    #[test]
    fn unknown_path_is_404() {
        let r = router();
        assert_eq!(
            r.dispatch(Request::new(Method::Get, "/nope")).status,
            Status::NotFound
        );
        assert_eq!(
            r.dispatch(Request::new(Method::Get, "/models/1/2/3")).status,
            Status::NotFound
        );
    }

    #[test]
    fn query_string_ignored_for_matching() {
        let r = router();
        let resp = r.dispatch(Request::new(Method::Get, "/models?limit=10"));
        assert_eq!(resp.status, Status::Ok);
    }

    #[test]
    fn trailing_slash_tolerated() {
        let r = router();
        assert_eq!(
            r.dispatch(Request::new(Method::Get, "/models/")).status,
            Status::Ok
        );
    }
}
