//! Host-side model parameters + the binary wire format used to move
//! trained models between training Jobs and the back-end registry
//! (the paper's "submit the trained model to the back-end" /
//! "download the trained model" steps).
//!
//! Wire format (little-endian):
//! ```text
//! magic "KMLP" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name | u8 ndim | u32 dims[ndim] | f32 data[numel]
//! ```

use super::meta::ParamMeta;
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ParamTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModelParams {
    pub tensors: Vec<ParamTensor>,
}

const MAGIC: &[u8; 4] = b"KMLP";
const VERSION: u32 = 1;

impl ModelParams {
    pub fn total_weights(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Validate against the artifact contract (names, order, shapes).
    pub fn check_against(&self, metas: &[ParamMeta]) -> Result<()> {
        if self.tensors.len() != metas.len() {
            bail!(
                "param count mismatch: {} vs meta {}",
                self.tensors.len(),
                metas.len()
            );
        }
        for (t, m) in self.tensors.iter().zip(metas) {
            if t.name != m.name || t.shape != m.shape {
                bail!(
                    "param mismatch: got {}{:?}, meta says {}{:?}",
                    t.name,
                    t.shape,
                    m.name,
                    m.shape
                );
            }
            if t.data.len() != t.numel() {
                bail!("tensor {}: data len {} != numel {}", t.name, t.data.len(), t.numel());
            }
        }
        Ok(())
    }

    // ---- wire format ---------------------------------------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.total_weights() * 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.name.len() as u16).to_le_bytes());
            out.extend_from_slice(t.name.as_bytes());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<ModelParams> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != MAGIC {
            bail!("bad magic (not a KMLP model blob)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported model blob version {version}");
        }
        let n = r.u32()? as usize;
        if n > 10_000 {
            bail!("implausible tensor count {n}");
        }
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())?;
            let ndim = r.take(1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let numel: usize = shape.iter().product();
            if numel > 100_000_000 {
                bail!("implausible tensor size {numel}");
            }
            let raw = r.take(numel * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(ParamTensor { name, shape, data });
        }
        if r.pos != bytes.len() {
            bail!("trailing bytes in model blob");
        }
        Ok(ModelParams { tensors })
    }
}

/// Bounds-checked little-endian byte cursor, shared by the `KMLP`
/// params decoder above and the `KMLN` native-checkpoint decoder
/// (`runtime/native/model.rs`).
pub(crate) struct Reader<'a> {
    bytes: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { bytes, pos: 0 }
    }

    pub(crate) fn len(&self) -> usize {
        self.bytes.len()
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated blob at byte {}", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelParams {
        ModelParams {
            tensors: vec![
                ParamTensor {
                    name: "w1".into(),
                    shape: vec![2, 3],
                    data: vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25],
                },
                ParamTensor { name: "b1".into(), shape: vec![3], data: vec![0.1, 0.2, 0.3] },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let p = sample();
        let bytes = p.to_bytes();
        let back = ModelParams::from_bytes(&bytes).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn rejects_corruption() {
        let p = sample();
        let mut bytes = p.to_bytes();
        bytes[0] = b'X'; // magic
        assert!(ModelParams::from_bytes(&bytes).is_err());
        let mut short = p.to_bytes();
        short.truncate(short.len() - 3);
        assert!(ModelParams::from_bytes(&short).is_err());
        let mut long = p.to_bytes();
        long.push(0);
        assert!(ModelParams::from_bytes(&long).is_err());
    }

    #[test]
    fn check_against_meta() {
        let p = sample();
        let metas = vec![
            ParamMeta { name: "w1".into(), shape: vec![2, 3] },
            ParamMeta { name: "b1".into(), shape: vec![3] },
        ];
        p.check_against(&metas).unwrap();
        let wrong = vec![
            ParamMeta { name: "w1".into(), shape: vec![3, 2] },
            ParamMeta { name: "b1".into(), shape: vec![3] },
        ];
        assert!(p.check_against(&wrong).is_err());
        assert!(p.check_against(&metas[..1]).is_err());
    }

    #[test]
    fn total_weights() {
        assert_eq!(sample().total_weights(), 9);
    }
}
