//! Producer with message-set batching and delivery semantics.
//!
//! §II of the paper credits Kafka's dispatch rate to *message set
//! abstractions* (batching amortizes the network round trip) and the
//! broker's QoS policies ("at most once", "at least once", "exactly
//! one"). This producer implements all of it:
//!
//! * records accumulate per partition until `batch_size` (or an explicit
//!   `flush`), then travel as one batch → one simulated network
//!   traversal;
//! * `Acks::AtMostOnce` fires and forgets (send errors are swallowed);
//! * `Acks::AtLeastOnce` retries the whole batch on failure (duplicates
//!   possible);
//! * `Acks::ExactlyOnce` retries with an idempotent `(producer_id, seq)`
//!   so broker-side dedup keeps the log duplicate-free.

use super::net::ClientLocality;
use super::record::Record;
use super::transport::BrokerTransport;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Acks {
    AtMostOnce,
    AtLeastOnce,
    ExactlyOnce,
}

#[derive(Debug, Clone)]
pub struct ProducerConfig {
    /// Flush a partition's buffer at this many records.
    pub batch_size: usize,
    pub acks: Acks,
    pub locality: ClientLocality,
    /// Retries for (at-least/exactly)-once on send failure.
    pub max_retries: usize,
}

impl Default for ProducerConfig {
    fn default() -> Self {
        ProducerConfig {
            batch_size: 64,
            acks: Acks::AtLeastOnce,
            locality: ClientLocality::External,
            max_retries: 3,
        }
    }
}

pub struct Producer {
    broker: Arc<dyn BrokerTransport>,
    config: ProducerConfig,
    /// 0 = not yet allocated (the broker was unreachable at
    /// construction); re-fetched lazily before the first exactly-once
    /// flush. Broker-issued ids start at 1.
    producer_id: u64,
    /// Per-partition sequence counter for idempotence.
    seqs: HashMap<(String, u32), u64>,
    buffers: HashMap<(String, u32), Vec<Record>>,
    round_robin: u64,
    /// Partition counts learned from topic metadata (get-or-create),
    /// so routing costs no metadata round trip per send. Topics never
    /// re-partition, so the cache cannot go stale.
    partition_counts: HashMap<String, u32>,
}

impl Producer {
    pub fn new(broker: Arc<dyn BrokerTransport>, config: ProducerConfig) -> Producer {
        let producer_id = broker.alloc_producer_id().unwrap_or(0);
        Producer {
            broker,
            config,
            producer_id,
            seqs: HashMap::new(),
            buffers: HashMap::new(),
            round_robin: 0,
            partition_counts: HashMap::new(),
        }
    }

    pub fn with_defaults(broker: Arc<dyn BrokerTransport>) -> Producer {
        Producer::new(broker, ProducerConfig::default())
    }

    pub fn id(&self) -> u64 {
        self.producer_id
    }

    /// Partition count of `topic`, creating it with the broker default
    /// when missing (Kafka auto-create); cached after the first lookup.
    fn partitions_of(&mut self, topic: &str) -> Result<u32> {
        if let Some(&n) = self.partition_counts.get(topic) {
            return Ok(n);
        }
        let n = self.broker.create_topic(topic, 0)?;
        self.partition_counts.insert(topic.to_string(), n);
        Ok(n)
    }

    /// Buffer a record; flushes its partition when the batch fills.
    /// Returns the partition it was routed to.
    pub fn send(&mut self, topic: &str, record: Record) -> Result<u32> {
        let n = self.partitions_of(topic)?;
        let partition = super::topic::route_to(
            record.key.as_ref().map(|k| k.as_slice()),
            self.round_robin,
            n,
        );
        self.round_robin += 1;
        let key = (topic.to_string(), partition);
        let buf = self.buffers.entry(key.clone()).or_default();
        buf.push(record);
        if buf.len() >= self.config.batch_size {
            self.flush_partition(&key)?;
        }
        Ok(partition)
    }

    /// Send straight to a specific partition (bypasses routing).
    pub fn send_to(&mut self, topic: &str, partition: u32, record: Record) -> Result<()> {
        self.partitions_of(topic)?;
        let key = (topic.to_string(), partition);
        let buf = self.buffers.entry(key.clone()).or_default();
        buf.push(record);
        if buf.len() >= self.config.batch_size {
            self.flush_partition(&key)?;
        }
        Ok(())
    }

    /// Flush all buffered partitions.
    pub fn flush(&mut self) -> Result<()> {
        let keys: Vec<(String, u32)> = self
            .buffers
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(k, _)| k.clone())
            .collect();
        for k in keys {
            self.flush_partition(&k)?;
        }
        Ok(())
    }

    pub fn buffered(&self) -> usize {
        self.buffers.values().map(|v| v.len()).sum()
    }

    fn flush_partition(&mut self, key: &(String, u32)) -> Result<()> {
        let batch = match self.buffers.get_mut(key) {
            Some(b) if !b.is_empty() => std::mem::take(b),
            _ => return Ok(()),
        };
        let n = batch.len() as u64;
        let seq = match self.config.acks {
            Acks::ExactlyOnce => {
                if self.producer_id == 0 {
                    // Construction could not reach the broker; dedup
                    // needs a real id, so this flush must.
                    self.producer_id = self.broker.alloc_producer_id()?;
                }
                let s = self.seqs.entry(key.clone()).or_insert(0);
                let base = *s + 1;
                *s += n;
                Some((self.producer_id, base))
            }
            _ => None,
        };
        // The batch travels by reference: the happy path (and the
        // at-most-once path) never copies it, and the at-least-once /
        // exactly-once retry just re-sends the same slice — payloads are
        // shared `Bytes`, so even the broker-side append copies nothing.
        let mut attempt = 0;
        loop {
            let res = self.broker.produce(
                &key.0,
                key.1,
                &batch,
                self.config.locality,
                seq,
            );
            match res {
                Ok(_) => return Ok(()),
                Err(e) if e.to_string().contains("duplicate") => {
                    // Exactly-once retry hit broker-side dedup: success.
                    return Ok(());
                }
                Err(e) => {
                    attempt += 1;
                    match self.config.acks {
                        Acks::AtMostOnce => return Ok(()), // fire and forget
                        _ if attempt > self.config.max_retries => return Err(e),
                        _ => continue,
                    }
                }
            }
        }
    }
}

impl Drop for Producer {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, Cluster};

    fn cluster() -> ClusterHandle {
        Cluster::new(BrokerConfig { default_partitions: 2, ..Default::default() })
    }

    #[test]
    fn batches_flush_at_batch_size() {
        let c = cluster();
        c.create_topic("t", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 4, ..Default::default() },
        );
        for i in 0..3u8 {
            p.send_to("t", 0, Record::new(vec![i])).unwrap();
        }
        assert_eq!(p.buffered(), 3);
        assert_eq!(c.offsets("t", 0).unwrap().1, 0); // nothing sent yet
        p.send_to("t", 0, Record::new(vec![3])).unwrap();
        assert_eq!(p.buffered(), 0);
        assert_eq!(c.offsets("t", 0).unwrap().1, 4);
        // One batch => one produce call.
        assert_eq!(c.metrics.counter("broker.produce.batches").get(), 1);
    }

    #[test]
    fn explicit_flush_drains() {
        let c = cluster();
        let mut p = Producer::with_defaults(c.clone());
        p.send("t", Record::new(vec![1])).unwrap();
        p.flush().unwrap();
        assert_eq!(p.buffered(), 0);
        let t = c.topic("t").unwrap();
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn drop_flushes() {
        let c = cluster();
        {
            let mut p = Producer::with_defaults(c.clone());
            p.send("t", Record::new(vec![1])).unwrap();
        }
        assert_eq!(c.topic("t").unwrap().len(), 1);
    }

    #[test]
    fn keyed_records_land_in_one_partition() {
        let c = cluster();
        c.create_topic("t", 4);
        let mut p = Producer::with_defaults(c.clone());
        for i in 0..20u8 {
            p.send("t", Record::with_key(b"device-7".to_vec(), vec![i])).unwrap();
        }
        p.flush().unwrap();
        let t = c.topic("t").unwrap();
        let nonempty: Vec<u32> = (0..4)
            .filter(|&pi| !t.partition(pi).unwrap().lock().unwrap().is_empty())
            .collect();
        assert_eq!(nonempty.len(), 1);
    }

    #[test]
    fn unkeyed_records_spread_round_robin() {
        let c = cluster();
        c.create_topic("t", 4);
        let mut p = Producer::with_defaults(c.clone());
        for i in 0..16u8 {
            p.send("t", Record::new(vec![i])).unwrap();
        }
        p.flush().unwrap();
        let t = c.topic("t").unwrap();
        for pi in 0..4 {
            assert_eq!(t.partition(pi).unwrap().lock().unwrap().len(), 4);
        }
    }

    #[test]
    fn delivery_shares_payload_with_sender() {
        let c = cluster();
        c.create_topic("t", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 1, ..Default::default() },
        );
        let rec = Record::new(vec![42u8; 512]);
        let payload = rec.value.clone();
        p.send_to("t", 0, rec).unwrap();
        // End-to-end zero-copy: the consumed payload IS the produced one.
        let got = c.fetch("t", 0, 0, 1, ClientLocality::InCluster).unwrap();
        assert!(crate::util::Bytes::ptr_eq(&got[0].record.value, &payload));
    }

    #[test]
    fn exactly_once_retry_does_not_duplicate() {
        let c = cluster();
        c.create_topic("t", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig {
                batch_size: 100,
                acks: Acks::ExactlyOnce,
                ..Default::default()
            },
        );
        for i in 0..5u8 {
            p.send_to("t", 0, Record::new(vec![i])).unwrap();
        }
        p.flush().unwrap();
        // Simulate a client-side retry of an already-acked batch by
        // replaying the same seq range through the cluster directly.
        let replay: Vec<Record> = (0..5u8).map(|i| Record::new(vec![i])).collect();
        let err = c.produce("t", 0, &replay, ClientLocality::External, Some((p.id(), 1)));
        assert!(err.is_err());
        assert_eq!(c.offsets("t", 0).unwrap().1, 5);
    }
}
