//! The training Job — Algorithm 1 of the paper (§IV-C).
//!
//! ```text
//! model <- downloadModelFromBackend(model_url)
//! while not trained:
//!   msg <- readControlStreams()
//!   if deployment_id == msg.deployment_id:
//!     training_stream <- readStream(msg.topic)
//!     if msg.validation_rate > 0: take/split
//!     training_res <- trainModel(...)
//!     if msg.validation_rate > 0: evaluation_res <- evaluateModel(...)
//!     uploadTrainedModelAndMetrics(...)
//! ```
//!
//! `run_training_job` is the algorithm itself, callable inline (the
//! Tables I/II "data streams" column trains without containers) or
//! wrapped as an orchestrator entrypoint by
//! [`crate::coordinator::pipeline`] (the "& containerization" column).
//! Each invocation loads its own [`Engine`] — exactly as each of the
//! paper's containers loads its own TensorFlow model (and required
//! here because PJRT handles are not `Send`). Which execution backend
//! the engine uses (PJRT artifacts vs the pure-Rust native MLP) is the
//! job's `backend` knob, `Auto` by default.

use super::control::{ControlMessage, CONTROL_TOPIC};
use crate::broker::{BrokerHandle, BrokerTransport, ClientLocality, Consumer};
use crate::exec::CancelToken;
use crate::formats::{registry, Sample};
use crate::ml::{epoch_batches, split_validation, MetricAverager};
use crate::registry::{BackendClient, TrainingMetrics};
use crate::runtime::{BackendSelect, Engine};
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use std::time::{Duration, Instant};

/// Everything a training job needs (the paper passes these as container
/// env vars; the entrypoint wrapper in `pipeline.rs` does the same).
#[derive(Debug, Clone)]
pub struct TrainingJobConfig {
    pub deployment_id: u64,
    pub result_id: u64,
    pub artifact_dir: String,
    pub backend_url: String,
    pub epochs: usize,
    pub shuffle: bool,
    /// Seed for shuffling (deterministic runs).
    pub seed: u64,
    /// How long to wait for the control message.
    pub control_timeout: Duration,
    /// Where this job's broker clients sit (InCluster when containerized).
    pub locality: ClientLocality,
    /// Execution backend for the model (`--backend` knob).
    pub backend: BackendSelect,
    /// API key for the back-end (`--require-auth` platforms).
    pub api_key: Option<String>,
}

impl TrainingJobConfig {
    pub fn new(deployment_id: u64, result_id: u64, artifact_dir: &str, backend_url: &str) -> Self {
        TrainingJobConfig {
            deployment_id,
            result_id,
            artifact_dir: artifact_dir.to_string(),
            backend_url: backend_url.to_string(),
            epochs: 1,
            shuffle: true,
            seed: 42,
            control_timeout: Duration::from_secs(60),
            locality: ClientLocality::InCluster,
            backend: BackendSelect::Auto,
            api_key: None,
        }
    }
}

/// Outcome of a training job (also uploaded to the back-end).
#[derive(Debug, Clone)]
pub struct TrainingOutcome {
    pub metrics: TrainingMetrics,
    pub steps: u64,
    pub samples_train: usize,
    pub samples_val: usize,
}

/// Block until the control message for `deployment_id` arrives
/// (Algorithm 1's `readControlStreams` loop). Ignores messages for other
/// deployments — several jobs share the control topic.
///
/// The job **parks** on the control partition's wait-set between polls
/// (no sleep-poll loop); waits run in short slices so cancellation is
/// still observed promptly while idle.
pub fn await_control_message(
    broker: &BrokerHandle,
    deployment_id: u64,
    locality: ClientLocality,
    timeout: Duration,
    cancel: &CancelToken,
) -> Result<ControlMessage> {
    const CANCEL_SLICE: Duration = Duration::from_millis(25);
    broker.create_topic(CONTROL_TOPIC, 1)?;
    let mut consumer = Consumer::new(broker.clone(), locality);
    consumer.assign(vec![(CONTROL_TOPIC.to_string(), 0)]);
    let deadline = Instant::now() + timeout;
    loop {
        if cancel.is_cancelled() {
            bail!("cancelled while waiting for control message");
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        for rec in consumer.poll_wait(64, remaining.min(CANCEL_SLICE))? {
            match ControlMessage::decode(&rec.record.value) {
                Ok(msg) if msg.deployment_id == deployment_id => return Ok(msg),
                Ok(_) => {} // someone else's stream
                Err(e) => log::warn!("skipping bad control message: {e}"),
            }
        }
        if Instant::now() >= deadline {
            bail!("timed out waiting for control message for deployment {deployment_id}");
        }
    }
}

/// Read the exact log window a control message names and decode it.
pub fn read_stream_window(
    broker: &BrokerHandle,
    msg: &ControlMessage,
    locality: ClientLocality,
) -> Result<Vec<Sample>> {
    let format = registry(&msg.input_format, &msg.input_config)?;
    let mut consumer = Consumer::new(broker.clone(), locality);
    let tp = (msg.stream.topic.clone(), msg.stream.partition);
    // The window must still be in the log (retention!) — §V.
    let (earliest, latest) = broker.offsets(&msg.stream.topic, msg.stream.partition)?;
    if msg.stream.offset < earliest {
        bail!(
            "stream {} expired: starts at {} but log begins at {earliest}",
            msg.stream.format(),
            msg.stream.offset
        );
    }
    if msg.stream.end_offset() > latest {
        bail!(
            "stream {} incomplete: ends at {} but log has only {latest}",
            msg.stream.format(),
            msg.stream.end_offset()
        );
    }
    consumer.assign(vec![tp.clone()]);
    consumer.seek(tp, msg.stream.offset);
    let mut samples = Vec::with_capacity(msg.stream.length as usize);
    while (samples.len() as u64) < msg.stream.length {
        let max = (msg.stream.length as usize - samples.len()).min(512);
        // Batched fetch: one lock round trip per batch, and decoding
        // reads `&[u8]` views of the log's shared buffers — the window
        // is never deep-copied between the log and the samples.
        // (poll_batches omits empty batches, so empty == drained.)
        let batches = consumer.poll_batches(max)?;
        if batches.is_empty() {
            bail!("stream window drained early at {} records", samples.len());
        }
        for batch in &batches {
            // The consumer is assigned exactly the window's partition,
            // so offsets are monotonic across the whole poll; records
            // at/after the window end are filtered, not decoded.
            for (offset, record) in &batch.records {
                if *offset >= msg.stream.end_offset() {
                    continue;
                }
                samples.push(format.decode(record)?);
            }
        }
    }
    Ok(samples)
}

/// Algorithm 1, minus the control-message wait (already done by the
/// caller): train on the window, optionally evaluate, return metrics.
pub fn train_on_samples(
    engine: &Engine,
    samples: Vec<Sample>,
    validation_rate: f64,
    epochs: usize,
    shuffle: bool,
    seed: u64,
    cancel: &CancelToken,
) -> Result<(crate::runtime::ModelParams, TrainingOutcome)> {
    let meta = engine.meta();
    let (train, val) = split_validation(samples, validation_rate);
    if train.len() < meta.batch {
        bail!(
            "not enough training samples ({}) for one batch of {}",
            train.len(),
            meta.batch
        );
    }
    let init = engine.init_params()?;
    let mut state = engine.train_state(&init)?;
    let mut rng = Rng::new(seed);
    let mut loss_curve = Vec::with_capacity(epochs);
    let mut last_epoch = MetricAverager::new();
    let mut steps = 0u64;
    for _epoch in 0..epochs {
        if cancel.is_cancelled() {
            bail!("training cancelled");
        }
        let batches = epoch_batches(
            &train,
            meta.batch,
            meta.input_dim,
            if shuffle { Some(&mut rng) } else { None },
        )?;
        let mut epoch_avg = MetricAverager::new();
        for (x, y) in &batches {
            let (loss, acc) = engine.train_step(&mut state, x, y)?;
            epoch_avg.push(loss, acc);
            steps += 1;
        }
        loss_curve.push(epoch_avg.loss());
        last_epoch = epoch_avg;
    }

    // Evaluation (if validation_rate > 0) on full batches of the tail.
    let (val_loss, val_acc) = if !val.is_empty() && val.len() >= meta.batch {
        let mut avg = MetricAverager::new();
        for (x, y) in epoch_batches(&val, meta.batch, meta.input_dim, None)? {
            let (l, a) = engine.eval_step(&state.params, &x, &y)?;
            avg.push(l, a);
        }
        (Some(avg.loss()), Some(avg.accuracy()))
    } else {
        (None, None)
    };

    let params = engine.params_of(&state)?;
    let outcome = TrainingOutcome {
        metrics: TrainingMetrics {
            loss: last_epoch.loss(),
            accuracy: last_epoch.accuracy(),
            val_loss,
            val_accuracy: val_acc,
            loss_curve,
        },
        steps,
        samples_train: train.len(),
        samples_val: val.len(),
    };
    Ok((params, outcome))
}

/// The full training Job (Algorithm 1). Returns the outcome after
/// uploading model + metrics to the back-end.
///
/// `broker` is a transport handle: the job runs identically against an
/// in-process cluster (the inline "data streams" column of Tables I/II)
/// and a remote broker over the wire (`kafka-ml train --broker`), just
/// as the paper's containerized jobs reach Kafka over the network.
pub fn run_training_job(
    broker: &BrokerHandle,
    config: &TrainingJobConfig,
    cancel: &CancelToken,
) -> Result<TrainingOutcome> {
    let backend = BackendClient::new_with_key(&config.backend_url, config.api_key.as_deref());
    backend
        .set_result_status(config.result_id, "training")
        .ok(); // best-effort status update

    // "downloadModelFromBackend": load the model (compiled PJRT
    // artifacts or the artifact-less native engine, per the knob).
    let engine = Engine::load_with(&config.artifact_dir, config.backend)
        .map_err(|e| anyhow!("loading model artifacts: {e}"))?;
    log::info!(
        "training job {} running on the '{}' backend",
        config.result_id,
        engine.backend_name()
    );

    let msg = await_control_message(
        broker,
        config.deployment_id,
        config.locality,
        config.control_timeout,
        cancel,
    )?;
    let samples = read_stream_window(broker, &msg, config.locality)?;
    let (params, outcome) = train_on_samples(
        &engine,
        samples,
        msg.validation_rate,
        config.epochs,
        config.shuffle,
        config.seed,
        cancel,
    )?;
    backend.upload_trained_model(config.result_id, &params, &outcome.metrics)?;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, Cluster, ClusterHandle, Producer, ProducerConfig, Record};
    use crate::json::Json;

    fn cluster() -> ClusterHandle {
        Cluster::new(BrokerConfig::default())
    }

    /// The in-process transport view of a test cluster.
    fn handle(c: &ClusterHandle) -> BrokerHandle {
        c.clone()
    }

    fn raw_config() -> Json {
        crate::json::parse(r#"{"dtype": "f32", "shape": [2]}"#).unwrap()
    }

    fn produce_samples(c: &ClusterHandle, topic: &str, n: usize) -> ControlMessage {
        let fmt = registry("RAW", &raw_config()).unwrap();
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 32, ..Default::default() },
        );
        c.create_topic(topic, 1);
        let (_, base) = c.offsets(topic, 0).unwrap();
        for i in 0..n {
            let rec = fmt
                .encode(&[i as f32, -(i as f32)], Some((i % 4) as i32))
                .unwrap();
            p.send_to(topic, 0, rec).unwrap();
        }
        p.flush().unwrap();
        ControlMessage {
            deployment_id: 1,
            stream: super::super::control::StreamRef::new(topic, 0, base, n as u64),
            input_format: "RAW".into(),
            input_config: raw_config(),
            validation_rate: 0.0,
            total_msg: n as u64,
        }
    }

    #[test]
    fn await_matches_only_own_deployment() {
        let c = cluster();
        c.create_topic(CONTROL_TOPIC, 1);
        let other = ControlMessage {
            deployment_id: 99,
            stream: super::super::control::StreamRef::new("t", 0, 0, 1),
            input_format: "RAW".into(),
            input_config: raw_config(),
            validation_rate: 0.0,
            total_msg: 1,
        };
        let mine = ControlMessage { deployment_id: 1, ..other.clone() };
        c.produce(
            CONTROL_TOPIC,
            0,
            &[Record::new(other.encode()), Record::new(mine.encode())],
            ClientLocality::InCluster,
            None,
        )
        .unwrap();
        let got = await_control_message(
            &handle(&c),
            1,
            ClientLocality::InCluster,
            Duration::from_secs(2),
            &CancelToken::new(),
        )
        .unwrap();
        assert_eq!(got.deployment_id, 1);
    }

    #[test]
    fn await_times_out_without_message() {
        let c = cluster();
        let err = await_control_message(
            &handle(&c),
            1,
            ClientLocality::InCluster,
            Duration::from_millis(50),
            &CancelToken::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("timed out"));
    }

    #[test]
    fn await_respects_cancel() {
        let c = cluster();
        let cancel = CancelToken::new();
        cancel.cancel();
        let err = await_control_message(
            &handle(&c),
            1,
            ClientLocality::InCluster,
            Duration::from_secs(5),
            &cancel,
        )
        .unwrap_err();
        assert!(err.to_string().contains("cancelled"));
    }

    #[test]
    fn read_window_exact_range() {
        let c = cluster();
        let mut msg = produce_samples(&c, "data", 50);
        // Restrict to a sub-window [10, 30).
        msg.stream.offset = 10;
        msg.stream.length = 20;
        let samples = read_stream_window(&handle(&c), &msg, ClientLocality::InCluster).unwrap();
        assert_eq!(samples.len(), 20);
        assert_eq!(samples[0].features[0], 10.0);
        assert_eq!(samples[19].features[0], 29.0);
        assert_eq!(samples[0].label, Some(2));
    }

    #[test]
    fn read_window_detects_expired_stream() {
        use crate::broker::{CleanupPolicy, LogConfig};
        use crate::util::clock::ManualClock;
        use std::sync::Arc;
        let clock = ManualClock::new(1_000);
        let c = Cluster::with_clock(
            BrokerConfig {
                log: LogConfig {
                    segment_bytes: 256,
                    retention_ms: Some(500),
                    retention_bytes: None,
                    cleanup_policy: CleanupPolicy::Delete,
                    ..LogConfig::default()
                },
                ..Default::default()
            },
            Arc::new(clock.clone()),
        );
        let msg = produce_samples(&c, "data", 100);
        clock.advance_ms(10_000);
        // Append fresh data so old segments can be deleted.
        produce_samples(&c, "data", 10);
        c.run_retention();
        let err = read_stream_window(&handle(&c), &msg, ClientLocality::InCluster).unwrap_err();
        assert!(err.to_string().contains("expired"), "{err}");
    }

    #[test]
    fn read_window_detects_incomplete_stream() {
        let c = cluster();
        let mut msg = produce_samples(&c, "data", 10);
        msg.stream.length = 50; // claims more than the log has
        let err = read_stream_window(&handle(&c), &msg, ClientLocality::InCluster).unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
    }

    // Engine-backed tests (real artifacts) live in
    // rust/tests/pipeline_integration.rs.
}
