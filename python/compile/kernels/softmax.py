"""Row-wise numerically-stable softmax as a Pallas kernel.

Used by the inference (``predict``) artifact to turn logits into class
probabilities. One grid step owns a ``(bm, N)`` row-block held in VMEM;
column padding is filled with ``-inf`` so padded lanes contribute exactly
zero probability mass.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    shifted = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(shifted)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax(x, block_m=BLOCK_M):
    """Stable softmax over the last axis of a 2-D array."""
    m, n = x.shape
    bm = min(_round_up(m, 8), block_m)
    mp, np_ = _round_up(m, bm), _round_up(n, 8)

    # -inf column padding => exp(pad) == 0 => padded lanes get no mass.
    # Row padding can stay -inf too: those rows are sliced away.
    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)), constant_values=-jnp.inf)

    out = pl.pallas_call(
        _softmax_kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, np_), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, np_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp)
    return out[:m, :n]
