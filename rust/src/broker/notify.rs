//! The wakeup subsystem: event-driven waits replacing every sleep-poll
//! loop on the consume path.
//!
//! Built from two pieces, both plain `std::sync` (no async runtime — the
//! vendored build is hermetic):
//!
//! * [`Waiter`] — one parked thread. A `Mutex<u64>` generation counter
//!   plus a `Condvar`. The counter closes the lost-wakeup race: a
//!   consumer snapshots the generation *before* checking for data, so a
//!   produce that lands between the check and the park has already
//!   bumped the generation and [`Waiter::wait_until`] returns
//!   immediately instead of sleeping through the notification.
//! * [`WaitSet`] — one event source (a partition's appends, a consumer
//!   group's rebalances, the back-end control log). Waiters register,
//!   the source calls [`WaitSet::notify_all`] when its state changes,
//!   every registered waiter is woken. A single waiter can be registered
//!   with many wait-sets at once — that is how a consumer parks across
//!   *all* of its assigned partitions under one condvar.
//!
//! The notify fast path is an atomic waiter-count check, so sources pay
//! ~one atomic load per event while nobody is parked — appends on a
//! busy partition with no idle consumers stay as cheap as before the
//! wakeup system existed.
//!
//! The condvar discipline itself (absolute-deadline timed wait,
//! spurious-wakeup safe) is the crate-wide [`wait_deadline`] primitive
//! in [`crate::util::sync`], shared with [`crate::exec`]'s channels
//! (`recv_deadline`/`recv_timeout`) and re-exported here.

pub use crate::util::sync::wait_deadline;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// The one-shot wait protocol every blocking consume path uses:
/// **register** one fresh waiter with every `set`, **snapshot** its
/// generation, **check** `changed`, then **park** until woken or
/// `deadline`. An event landing between the check and the park has
/// already bumped the generation, so the park returns immediately —
/// no lost wakeup. Returns `true` when `changed` held or a wakeup
/// arrived, `false` on a quiet timeout; registrations are always
/// removed before returning.
pub fn wait_any(sets: &[&WaitSet], changed: impl Fn() -> bool, deadline: Instant) -> bool {
    let waiter = Waiter::new();
    for s in sets {
        s.register(&waiter);
    }
    let seen = waiter.generation();
    let ready = changed() || waiter.wait_until(seen, deadline) || changed();
    for s in sets {
        s.deregister(&waiter);
    }
    ready
}

#[derive(Default)]
struct WaiterInner {
    generation: Mutex<u64>,
    cv: Condvar,
    /// Optional side-channel run on every [`Waiter::wake`], *after* the
    /// generation bump: how a non-thread waiter (the wire server's
    /// reactor parks connections, not threads) turns a condvar-world
    /// notification into its own wakeup (an eventfd write). Must be
    /// cheap and non-blocking — it runs on the notifier's thread, e.g.
    /// inside a produce call.
    hook: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
}

impl std::fmt::Debug for WaiterInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WaiterInner")
            .field("generation", &self.generation)
            .finish_non_exhaustive()
    }
}

/// One parkable thread. Clones share the same generation/condvar, so a
/// waiter can be handed to any number of [`WaitSet`]s.
#[derive(Debug, Clone, Default)]
pub struct Waiter {
    inner: Arc<WaiterInner>,
}

impl Waiter {
    pub fn new() -> Waiter {
        Waiter::default()
    }

    /// Snapshot the generation. Take it *before* checking whatever
    /// condition you are about to park on.
    pub fn generation(&self) -> u64 {
        *self.inner.generation.lock().unwrap()
    }

    /// Wake the parked thread (bumps the generation so an about-to-park
    /// thread does not sleep through this wakeup). Runs the wake hook,
    /// if one is set, after the bump — so the hook's observer always
    /// sees `generation() != seen` for a wake that already fired.
    pub fn wake(&self) {
        let mut g = self.inner.generation.lock().unwrap();
        *g = g.wrapping_add(1);
        drop(g);
        self.inner.cv.notify_all();
        let hook = self.inner.hook.lock().unwrap();
        if let Some(f) = hook.as_ref() {
            f();
        }
    }

    /// Install a side-channel called on every [`Waiter::wake`] — the
    /// bridge from condvar-world notifications to an event loop (the
    /// reactor's eventfd). Install *before* registering the waiter with
    /// any [`WaitSet`], or a wake can slip by unhooked. The hook fires
    /// once per wake (which may be more than once per park) and must be
    /// cheap and non-blocking.
    pub fn set_hook(&self, f: impl Fn() + Send + Sync + 'static) {
        *self.inner.hook.lock().unwrap() = Some(Box::new(f));
    }

    /// Park until the generation moves past `seen` or `deadline` passes.
    /// Returns `true` when woken by [`Waiter::wake`], `false` on timeout.
    pub fn wait_until(&self, seen: u64, deadline: Instant) -> bool {
        let mut g = self.inner.generation.lock().unwrap();
        while *g == seen {
            let (guard, timed_out) = wait_deadline(&self.inner.cv, g, deadline);
            g = guard;
            if timed_out {
                return *g != seen;
            }
        }
        true
    }

    /// Two handles to the same underlying waiter?
    pub fn ptr_eq(a: &Waiter, b: &Waiter) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }
}

/// A set of registered [`Waiter`]s attached to one event source.
#[derive(Debug, Default)]
pub struct WaitSet {
    waiters: Mutex<Vec<Waiter>>,
    /// Mirror of `waiters.len()` so `notify_all` can skip the mutex
    /// entirely when nobody is parked (the common case on a hot path).
    count: AtomicUsize,
}

impl WaitSet {
    pub fn new() -> WaitSet {
        WaitSet::default()
    }

    /// Register a waiter for future notifications. Register *before*
    /// checking the condition you intend to park on.
    pub fn register(&self, waiter: &Waiter) {
        let mut ws = self.waiters.lock().unwrap();
        ws.push(waiter.clone());
        self.count.store(ws.len(), Ordering::SeqCst);
    }

    /// Remove every registration of `waiter` (by identity).
    pub fn deregister(&self, waiter: &Waiter) {
        let mut ws = self.waiters.lock().unwrap();
        ws.retain(|w| !Waiter::ptr_eq(w, waiter));
        self.count.store(ws.len(), Ordering::SeqCst);
    }

    /// Wake every registered waiter. Near-free when none are parked.
    pub fn notify_all(&self) {
        if self.count.load(Ordering::SeqCst) == 0 {
            return;
        }
        let ws = self.waiters.lock().unwrap();
        for w in ws.iter() {
            w.wake();
        }
    }

    /// Number of currently registered waiters.
    pub fn len(&self) -> usize {
        self.count.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Test-only delay built on the waiter itself (a fresh waiter nobody
/// wakes parks until its deadline): broker/coordinator code — tests
/// included — never blocks on anything but these waiters.
#[cfg(test)]
pub(crate) fn pause(d: std::time::Duration) {
    let w = Waiter::new();
    let seen = w.generation();
    w.wait_until(seen, Instant::now() + d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn wake_before_wait_returns_immediately() {
        // The lost-wakeup guard: a wake that lands after the generation
        // snapshot but before the park must not be slept through.
        let w = Waiter::new();
        let seen = w.generation();
        w.wake();
        let t0 = Instant::now();
        assert!(w.wait_until(seen, Instant::now() + Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn wait_times_out_without_wake() {
        let w = Waiter::new();
        let seen = w.generation();
        let t0 = Instant::now();
        assert!(!w.wait_until(seen, Instant::now() + Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn cross_thread_wake_is_fast() {
        let w = Waiter::new();
        let w2 = w.clone();
        let seen = w.generation();
        let h = std::thread::spawn(move || {
            pause(Duration::from_millis(20));
            w2.wake();
        });
        let t0 = Instant::now();
        assert!(w.wait_until(seen, Instant::now() + Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
    }

    #[test]
    fn wake_hook_fires_on_every_wake_including_via_waitset() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let w = Waiter::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = fired.clone();
        w.set_hook(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        let seen = w.generation();
        w.wake();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // The bump precedes the hook: an observer the hook triggers
        // always sees the moved generation.
        assert_ne!(w.generation(), seen);
        let set = WaitSet::new();
        set.register(&w);
        set.notify_all();
        assert_eq!(fired.load(Ordering::SeqCst), 2);
        set.deregister(&w);
        set.notify_all();
        assert_eq!(fired.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn waitset_notifies_all_registered() {
        let set = WaitSet::new();
        let a = Waiter::new();
        let b = Waiter::new();
        set.register(&a);
        set.register(&b);
        assert_eq!(set.len(), 2);
        let (ga, gb) = (a.generation(), b.generation());
        set.notify_all();
        assert!(a.wait_until(ga, Instant::now()));
        assert!(b.wait_until(gb, Instant::now()));
    }

    #[test]
    fn deregistered_waiter_not_notified() {
        let set = WaitSet::new();
        let a = Waiter::new();
        set.register(&a);
        set.deregister(&a);
        assert!(set.is_empty());
        let seen = a.generation();
        set.notify_all();
        assert_eq!(a.generation(), seen);
    }

    #[test]
    fn one_waiter_across_many_sets() {
        let sets: Vec<WaitSet> = (0..4).map(|_| WaitSet::new()).collect();
        let w = Waiter::new();
        for s in &sets {
            s.register(&w);
        }
        let seen = w.generation();
        sets[3].notify_all(); // any one source wakes the waiter
        assert!(w.wait_until(seen, Instant::now()));
        for s in &sets {
            s.deregister(&w);
            assert!(s.is_empty());
        }
    }

    #[test]
    fn wait_any_observes_event_raced_with_registration() {
        // `changed` already true at park time: no wait happens at all.
        let set = WaitSet::new();
        let t0 = Instant::now();
        assert!(wait_any(&[&set], || true, t0 + Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert!(set.is_empty());
    }

    #[test]
    fn wait_any_wakes_on_notify_and_times_out_quiet() {
        let set = Arc::new(WaitSet::new());
        let s2 = set.clone();
        let h = std::thread::spawn(move || {
            pause(Duration::from_millis(20));
            s2.notify_all();
        });
        let t0 = Instant::now();
        assert!(wait_any(&[&set], || false, t0 + Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1));
        h.join().unwrap();
        let t0 = Instant::now();
        assert!(!wait_any(&[&set], || false, t0 + Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }
}
