//! The native pure-Rust execution backend: the whole train/eval/predict
//! surface of the model with **zero external artifacts** — no Python, no
//! HLO files, no PJRT link. This is what keeps the end-to-end pipeline
//! (and its integration suites) runnable on every clean checkout, the
//! way ML.NET ships a self-contained native pipeline backend.
//!
//! * [`mlp`] — the compute core: cache-blocked, 4-wide-unrolled dense
//!   kernels (ReLU hidden, linear output; transposed-weight tiles for
//!   the backward `dz·Wᵀ` pass; fused bias+ReLU epilogue),
//!   numerically-stable softmax-cross-entropy, the full backward pass
//!   and Glorot init, all over flat row-major `f32` buffers in a
//!   preallocated scratch arena ([`MlpScratch`]) — steady-state steps
//!   perform zero heap allocation in the kernel path (debug-asserted);
//! * [`adam`] — fused Adam update with folded bias correction,
//!   mirroring the Pallas kernel in `python/compile/kernels/adam.py`
//!   bit-for-formula;
//! * [`model`] — the self-describing `.kmln` checkpoint format
//!   (spec + embedded `KMLP` params blob), so train → checkpoint →
//!   restore → predict needs nothing but the one file.
//!
//! # Data flow: one training step
//!
//! ```text
//!  Engine::train_step(state, x, y)        (state: host ModelParams + m/v/t)
//!        │ shape/label validation
//!        ▼
//!  NativeBackend::train_step
//!        │
//!        ├─► NativeMlp::loss_grad ── forward_all: a₀=x ─ dense+ReLU ─► logits
//!        │                           loss/acc (f64-accumulated NLL)
//!        │                           backward: dz=softmax−onehot → dW,db → daᵀ
//!        │
//!        └─► per tensor: adam::adam_step(p, g, m, v, t)
//!                        lr_t = lr·√(1−β₂ᵗ)/(1−β₁ᵗ)   (bias correction)
//!        ▼
//!  (loss, acc) — state.params/m/v updated in place
//! ```
//!
//! The backend is selected by [`crate::runtime::Engine::load_with`]:
//! `Auto` prefers PJRT when HLO artifacts exist and the real client
//! links, and falls back here otherwise; `--backend native` forces it.

pub mod adam;
pub mod mlp;
pub mod model;

pub use adam::{adam_step, AdamHyper};
pub use mlp::{MlpScratch, NativeMlp};
pub use model::{NativeModel, NativeSpec};

use super::backend::{Backend, TrainState};
use super::meta::ArtifactMeta;
use super::params::ModelParams;
use anyhow::Result;
use std::sync::Mutex;

/// The pure-Rust MLP engine behind [`crate::runtime::Engine`].
///
/// Owns one [`MlpScratch`] arena behind a lock: train/eval/predict all
/// run their kernels over it, so a training loop allocates during its
/// first step and then never again (`Backend` methods take `&self`; the
/// lock serializes kernel calls without changing the trait).
pub struct NativeBackend {
    mlp: NativeMlp,
    hyper: AdamHyper,
    scratch: Mutex<MlpScratch>,
}

impl NativeBackend {
    pub fn new(meta: &ArtifactMeta) -> Result<NativeBackend> {
        Ok(NativeBackend {
            mlp: NativeMlp::from_meta(meta)?,
            hyper: AdamHyper {
                lr: meta.lr,
                beta1: meta.beta1,
                beta2: meta.beta2,
                eps: meta.eps,
            },
            scratch: Mutex::new(MlpScratch::new()),
        })
    }

    pub fn mlp(&self) -> &NativeMlp {
        &self.mlp
    }

    pub fn hyper(&self) -> &AdamHyper {
        &self.hyper
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native-cpu (pure Rust)".to_string()
    }

    fn init_params(&self) -> Result<ModelParams> {
        Ok(self.mlp.init())
    }

    fn train_step(&self, state: &mut TrainState, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let rows = y.len();
        let mut s = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        let (loss, acc) = self.mlp.loss_grad_with(&state.params, x, y, rows, &mut s);
        for (i, g) in s.grads().iter().enumerate() {
            adam_step(
                &self.hyper,
                state.t,
                &mut state.params.tensors[i].data,
                g,
                &mut state.m[i],
                &mut state.v[i],
            );
        }
        Ok((loss, acc))
    }

    fn eval_step(&self, params: &ModelParams, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let mut s = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        Ok(self.mlp.loss_acc_with(params, x, y, y.len(), &mut s))
    }

    fn predict(&self, params: &ModelParams, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let mut s = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        Ok(self.mlp.probs_with(params, x, rows, &mut s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn backend() -> NativeBackend {
        let meta = ArtifactMeta::synthesize(PathBuf::new(), 4, &[8], 3, 6, 0.05, 21);
        NativeBackend::new(&meta).unwrap()
    }

    #[test]
    fn honors_meta_hyperparameters() {
        let mut meta = ArtifactMeta::synthesize(PathBuf::new(), 4, &[8], 3, 6, 0.05, 21);
        meta.beta1 = 0.8;
        meta.eps = 1e-5;
        let b = NativeBackend::new(&meta).unwrap();
        assert_eq!(b.hyper().lr, 0.05);
        assert_eq!(b.hyper().beta1, 0.8);
        assert_eq!(b.hyper().eps, 1e-5);
        assert_eq!(b.mlp().layers, vec![(4, 8), (8, 3)]);
    }

    #[test]
    fn train_step_reduces_loss_on_a_fixed_batch() {
        let b = backend();
        let mut state = TrainState::new(b.init_params().unwrap());
        let x: Vec<f32> = (0..6 * 4).map(|i| ((i * 7 % 13) as f32 - 6.0) / 6.0).collect();
        let y = [0i32, 1, 2, 0, 1, 2];
        let mut first = 0f32;
        let mut last = 0f32;
        for step in 0..50 {
            state.t += 1;
            let (loss, _) = b.train_step(&mut state, &x, &y).unwrap();
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(
            last < first * 0.5,
            "50 steps on one batch must overfit it: {first} -> {last}"
        );
    }

    #[test]
    fn steady_state_steps_reuse_the_scratch_arena() {
        let b = backend();
        let mut state = TrainState::new(b.init_params().unwrap());
        let x: Vec<f32> = (0..6 * 4).map(|i| ((i * 5 % 11) as f32 - 5.0) / 5.0).collect();
        let y = [2i32, 0, 1, 2, 0, 1];
        for _ in 0..3 {
            state.t += 1;
            b.train_step(&mut state, &x, &y).unwrap();
        }
        assert!(
            !b.scratch.lock().unwrap().grew(),
            "a warm train_step must not grow any kernel buffer"
        );
        // Interleaved eval and predict share the arena without
        // re-allocating either (debug builds also assert this inside
        // the kernels themselves).
        b.eval_step(&state.params, &x, &y).unwrap();
        b.train_step(&mut state, &x, &y).unwrap();
        b.predict(&state.params, &x, 6).unwrap();
        assert!(!b.scratch.lock().unwrap().grew());
    }
}
