//! Broker ablations (§II's dispatch-rate claims): message-set batching,
//! partition-parallel consumption, fetch sizing and the zero-copy
//! consume path.
//!
//! * batching — §II credits Kafka's rate to "message set abstractions:
//!   messages are grouped together amortizing the overhead of the
//!   network round trip". Sweep producer batch size with a calibrated
//!   in-cluster link and watch records/s.
//! * partitions — multi-consumer parallel fetch across 1/2/4 partitions.
//! * fetch size — single-consumer poll batching.
//! * payload size — consume throughput at 64 B / 1 KiB / 16 KiB
//!   payloads. This is the zero-copy dividend: since records travel as
//!   shared `Bytes`, consume cost is near-independent of payload size.
//!
//! Results are also written machine-readably to
//! `BENCH_broker_throughput.json` (repo root) via `benchkit::Report` so
//! successive PRs can diff the perf trajectory.

use kafka_ml::benchkit::{Bench, Report, Table};
use kafka_ml::broker::{
    BrokerConfig, ClientLocality, Cluster, Consumer, NetProfile, Producer, ProducerConfig,
    Record,
};
use kafka_ml::util::Bytes;
use std::time::Instant;

const REPORT_PATH: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/../BENCH_broker_throughput.json"
);

fn main() -> anyhow::Result<()> {
    let mut report = Report::new("broker_throughput");
    let records = 20_000usize;
    let payload = Bytes::from_vec(vec![7u8; 64]);

    // ---- producer batching sweep -----------------------------------------
    let mut t = Table::new(
        "Producer message-set batching (20k x 64B records, in-cluster 250µs/leg)",
        &["batch size", "wall (s)", "records/s", "network round-trips"],
    );
    for batch in [1usize, 8, 64, 256] {
        let c = Cluster::new(BrokerConfig {
            net: NetProfile::calibrated(),
            ..Default::default()
        });
        c.create_topic("bt", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig {
                batch_size: batch,
                locality: ClientLocality::InCluster,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        for _ in 0..records {
            p.send_to("bt", 0, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let wall = t0.elapsed();
        let rps = records as f64 / wall.as_secs_f64();
        t.row(&[
            batch.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{rps:.0}"),
            c.metrics.counter("broker.produce.batches").get().to_string(),
        ]);
        report.entry(
            "producer_batching",
            &[("batch_size", batch as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", wall.as_secs_f64())],
        );
    }
    t.print();

    // ---- consumer parallelism across partitions ------------------------------
    let mut t = Table::new(
        "Partition-parallel consumption (80k x 64B records, no simulated net)",
        &["partitions/consumers", "wall (s)", "records/s"],
    );
    let total = 80_000usize;
    for parts in [1u32, 2, 4] {
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("pt", parts);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 512, ..Default::default() },
        );
        for i in 0..total {
            p.send_to("pt", i as u32 % parts, Record::new(payload.clone()))?;
        }
        p.flush()?;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..parts)
            .map(|pi| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let mut cons = Consumer::new(c, ClientLocality::InCluster);
                    cons.assign(vec![("pt".to_string(), pi)]);
                    let mut got = 0usize;
                    loop {
                        let n = cons.poll(2048).unwrap().len();
                        if n == 0 {
                            break;
                        }
                        got += n;
                    }
                    got
                })
            })
            .collect();
        let got: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(got, total);
        let wall = t0.elapsed();
        let rps = total as f64 / wall.as_secs_f64();
        t.row(&[
            parts.to_string(),
            format!("{:.3}", wall.as_secs_f64()),
            format!("{rps:.0}"),
        ]);
        report.entry(
            "partition_parallelism",
            &[("partitions", parts as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", wall.as_secs_f64())],
        );
    }
    t.print();

    // ---- fetch size sweep (batched zero-copy reads) ---------------------------
    let mut t = Table::new(
        "Fetch size sweep (80k records, single consumer)",
        &["max poll", "wall (s)", "records/s"],
    );
    let c = Cluster::new(BrokerConfig::default());
    c.create_topic("ft", 1);
    let mut p = Producer::new(
        c.clone(),
        ProducerConfig { batch_size: 512, ..Default::default() },
    );
    for _ in 0..total {
        p.send_to("ft", 0, Record::new(payload.clone()))?;
    }
    p.flush()?;
    let bench = Bench::new(1, 3);
    for max_poll in [16usize, 256, 4096] {
        let stats = bench.run(|| {
            let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
            cons.assign(vec![("ft".to_string(), 0)]);
            let mut got = 0usize;
            while got < total {
                got += cons.poll(max_poll).unwrap().len();
            }
        });
        let rps = total as f64 / stats.mean_secs();
        t.row(&[
            max_poll.to_string(),
            format!("{:.3}", stats.mean_secs()),
            format!("{rps:.0}"),
        ]);
        report.entry(
            "fetch_size",
            &[("max_poll", max_poll as f64), ("payload_bytes", 64.0)],
            &[("records_per_s", rps), ("wall_s", stats.mean_secs())],
        );
    }
    t.print();

    // ---- payload size sweep (the zero-copy dividend) --------------------------
    // Shared-`Bytes` payloads mean the consume path never copies record
    // bodies; throughput in records/s should stay near-flat from 64 B
    // to 16 KiB, and MiB/s should scale with payload size.
    let mut t = Table::new(
        "Payload size sweep (20k records, single consumer, max_poll 1024)",
        &["payload", "wall (s)", "records/s", "MiB/s"],
    );
    for size in [64usize, 1024, 16 * 1024] {
        let n = 20_000usize;
        let c = Cluster::new(BrokerConfig::default());
        c.create_topic("ps", 1);
        let mut p = Producer::new(
            c.clone(),
            ProducerConfig { batch_size: 512, ..Default::default() },
        );
        let body = Bytes::from_vec(vec![42u8; size]);
        for _ in 0..n {
            p.send_to("ps", 0, Record::new(body.clone()))?;
        }
        p.flush()?;
        let stats = bench.run(|| {
            let mut cons = Consumer::new(c.clone(), ClientLocality::InCluster);
            cons.assign(vec![("ps".to_string(), 0)]);
            let mut got = 0usize;
            while got < n {
                got += cons.poll(1024).unwrap().len();
            }
        });
        let rps = n as f64 / stats.mean_secs();
        let mibs = rps * size as f64 / (1024.0 * 1024.0);
        t.row(&[
            kafka_ml::util::human_bytes(size as u64),
            format!("{:.3}", stats.mean_secs()),
            format!("{rps:.0}"),
            format!("{mibs:.1}"),
        ]);
        report.entry(
            "payload_size",
            &[("payload_bytes", size as f64), ("max_poll", 1024.0)],
            &[
                ("records_per_s", rps),
                ("mib_per_s", mibs),
                ("wall_s", stats.mean_secs()),
            ],
        );
    }
    t.print();

    report.save(REPORT_PATH)?;
    println!("\nwrote {REPORT_PATH} ({} entries)", report.len());
    Ok(())
}
