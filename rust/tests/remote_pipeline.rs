//! The ISSUE-5 acceptance pipeline: the full produce → train → deploy →
//! infer flow with the broker served over **loopback TCP** and every
//! worker using the `Remote` transport — broker and compute in separate
//! "processes" (threads holding only a socket handle; no shared
//! in-process broker state on the worker side), exactly the paper's
//! broker-pods / job-pods topology.
//!
//! The model is the deterministic separable-dataset MLP from the PR-4
//! acceptance test (native backend, self-written meta.json), so the
//! ≥90% accuracy bar is checkout-independent.

use kafka_ml::broker::{
    BrokerHandle, BrokerServer, BrokerTransport, ClientLocality, Producer, ProducerConfig, Record,
    RemoteBroker,
};
use kafka_ml::coordinator::inference::run_inference_replica;
use kafka_ml::coordinator::training::run_training_job;
use kafka_ml::coordinator::{
    ControlMessage, InferenceClient, InferenceReplicaConfig, KafkaMl, KafkaMlConfig, StreamRef,
    TrainingJobConfig, CONTROL_TOPIC,
};
use kafka_ml::exec::CancelToken;
use kafka_ml::json::Json;
use kafka_ml::ml::separable_dataset;
use kafka_ml::registry::TrainingStatus;
use kafka_ml::runtime::BackendSelect;
use std::time::Duration;

fn raw_config() -> Json {
    kafka_ml::json::parse(r#"{"dtype": "f32", "shape": [8]}"#).unwrap()
}

fn write_native_model_spec(dir: &std::path::Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("meta.json"),
        r#"{
          "format_version": 1,
          "spec": {"input_dim": 8, "hidden": [16], "classes": 4, "batch": 10,
                   "lr": 0.01, "beta1": 0.9, "beta2": 0.999, "eps": 1e-07, "seed": 7},
          "params": [
            {"name": "w1", "shape": [8, 16], "dtype": "f32"},
            {"name": "b1", "shape": [16], "dtype": "f32"},
            {"name": "w2", "shape": [16, 4], "dtype": "f32"},
            {"name": "b2", "shape": [4], "dtype": "f32"}
          ],
          "artifacts": {}
        }"#,
    )
    .unwrap();
}

#[test]
fn full_pipeline_over_loopback_tcp_with_remote_workers() {
    let dir =
        std::env::temp_dir().join(format!("kafka-ml-remote-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    write_native_model_spec(&dir);

    // The "broker pod": platform (broker + REST back-end) plus the TCP
    // wire server in this process...
    let kml = KafkaMl::start(KafkaMlConfig {
        backend: BackendSelect::Native,
        ..Default::default()
    })
    .unwrap();
    let server = BrokerServer::start("127.0.0.1:0", kml.cluster.clone()).unwrap();
    let broker_addr = server.addr().to_string();
    let backend_url = kml.backend_url().to_string();

    // ...and the registry rows (steps A-C; the Web-UI side of Fig 1).
    let model = kml
        .create_model_from("separable-remote", &dir.to_string_lossy())
        .unwrap();
    let conf = kml.create_configuration("separable-remote", &[model]).unwrap();
    let dep = kml.store.create_deployment(conf, 10, 30, true).unwrap();
    let result_id = dep.result_ids[0];

    // The "training pod": a worker whose ONLY link to the broker is the
    // socket. It parks on the control topic over the wire (Alg. 1).
    let train_broker: BrokerHandle = RemoteBroker::connect(&broker_addr).unwrap();
    let train_cfg = TrainingJobConfig {
        epochs: 30,
        seed: 7,
        locality: ClientLocality::Remote,
        backend: BackendSelect::Native,
        ..TrainingJobConfig::new(dep.id, result_id, &dir.to_string_lossy(), &backend_url)
    };
    let trainer = std::thread::spawn(move || {
        run_training_job(&train_broker, &train_cfg, &CancelToken::new())
    });

    // The "producer-side library" (§III-D), also fully remote: stream
    // the samples, then the control message that wakes the job.
    let ingest: BrokerHandle = RemoteBroker::connect(&broker_addr).unwrap();
    let format = kafka_ml::formats::registry("RAW", &raw_config()).unwrap();
    let train_ds = separable_dataset(260, 8, 4, 1);
    ingest.create_topic("sep-data", 1).unwrap();
    let (_, start) = ingest.offsets("sep-data", 0).unwrap();
    let mut producer = Producer::new(
        ingest.clone(),
        ProducerConfig {
            batch_size: 64,
            locality: ClientLocality::Remote,
            ..Default::default()
        },
    );
    for s in &train_ds.samples {
        producer
            .send_to("sep-data", 0, format.encode(&s.features, s.label).unwrap())
            .unwrap();
    }
    producer.flush().unwrap();
    let (_, end) = ingest.offsets("sep-data", 0).unwrap();
    assert_eq!(end - start, 260);
    let msg = ControlMessage {
        deployment_id: dep.id,
        stream: StreamRef::new("sep-data", 0, start, end - start),
        input_format: "RAW".into(),
        input_config: raw_config(),
        validation_rate: 0.2,
        total_msg: end - start,
    };
    ingest
        .produce(
            CONTROL_TOPIC,
            0,
            &[Record::new(msg.encode())],
            ClientLocality::Remote,
            None,
        )
        .unwrap();

    // Step E: the remote job trains from the wire-fetched window and
    // uploads the model over HTTP.
    let outcome = trainer.join().unwrap().expect("remote training job");
    assert!(outcome.samples_train >= 200);
    assert!(outcome.samples_val > 0);
    let val_acc = outcome.metrics.val_accuracy.expect("validation_rate > 0");
    assert!(val_acc >= 0.9, "validation accuracy only {val_acc:.3}");
    let first = outcome.metrics.loss_curve[0];
    let last = *outcome.metrics.loss_curve.last().unwrap();
    assert!(last < first * 0.5, "loss did not fall: {first:.4} -> {last:.4}");
    let result = kml.store.result(result_id).unwrap();
    assert_eq!(result.status, TrainingStatus::Finished);

    // The "inference pods": two replicas, each on its own socket, in
    // one consumer group spread across the input partitions (Alg. 2).
    let ingest2 = ingest.clone();
    ingest2.create_topic("sep-in", 2).unwrap();
    ingest2.create_topic("sep-out", 1).unwrap();
    let cancel = CancelToken::new();
    let mut replicas = Vec::new();
    for i in 0..2 {
        let rb: BrokerHandle = RemoteBroker::connect(&broker_addr).unwrap();
        let cfg = InferenceReplicaConfig {
            inference_id: 1,
            result_id,
            artifact_dir: dir.to_string_lossy().to_string(),
            backend_url: backend_url.clone(),
            input_topic: "sep-in".into(),
            output_topic: "sep-out".into(),
            input_format: "RAW".into(),
            input_config: raw_config(),
            locality: ClientLocality::Remote,
            max_poll: 32,
            backend: BackendSelect::Native,
            api_key: None,
        };
        let c = cancel.clone();
        replicas.push(std::thread::spawn(move || {
            run_inference_replica(&rb, &cfg, &format!("remote-replica-{i}"), &c)
        }));
    }

    // Step F: a remote request/response client streams fresh draws.
    let client_broker: BrokerHandle = RemoteBroker::connect(&broker_addr).unwrap();
    let mut client = InferenceClient::new(
        client_broker,
        "sep-in",
        "sep-out",
        "RAW",
        &raw_config(),
        ClientLocality::Remote,
    )
    .unwrap();
    let test = separable_dataset(40, 8, 4, 2);
    let mut correct = 0usize;
    for s in &test.samples {
        let p = client.request(&s.features, Duration::from_secs(15)).unwrap();
        assert_eq!(p.probs.len(), 4);
        let sum: f32 = p.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    assert!(
        correct >= 36,
        "remote end-to-end accuracy {correct}/40 below the 90% bar"
    );
    // The prediction metric crossed the wire to the broker's registry.
    // Metric frames are one-way (fire-and-forget), so allow the server
    // a moment to drain the last ones.
    let metric_deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let n = kml
            .cluster
            .metrics
            .counter("kafka_ml.inference.predictions")
            .get();
        if n >= 40 {
            break;
        }
        assert!(
            std::time::Instant::now() < metric_deadline,
            "only {n}/40 predictions reached the broker-side metric"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    cancel.cancel();
    for r in replicas {
        r.join().unwrap().expect("remote inference replica");
    }
    server.shutdown();
    kml.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn in_process_and_remote_transports_see_the_same_log() {
    // One broker, two views: a record produced over the wire is the
    // record the in-process transport reads, and vice versa.
    let kml = KafkaMl::start(KafkaMlConfig {
        control_logger: false,
        ..Default::default()
    })
    .unwrap();
    let server = BrokerServer::start("127.0.0.1:0", kml.cluster.clone()).unwrap();
    let remote: BrokerHandle = RemoteBroker::connect(&server.addr().to_string()).unwrap();
    let local: BrokerHandle = kml.broker();

    local.create_topic("mixed", 1).unwrap();
    let local_rec = [Record::new(b"from-local".to_vec())];
    let remote_rec = [Record::new(b"from-remote".to_vec())];
    local
        .produce("mixed", 0, &local_rec, ClientLocality::InCluster, None)
        .unwrap();
    remote
        .produce("mixed", 0, &remote_rec, ClientLocality::Remote, None)
        .unwrap();

    for handle in [&local, &remote] {
        let batch = handle.fetch_batch("mixed", 0, 0, 10, ClientLocality::Remote).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.records[0].1.value.as_slice(), b"from-local");
        assert_eq!(batch.records[1].1.value.as_slice(), b"from-remote");
    }
    assert_eq!(local.offsets("mixed", 0).unwrap(), remote.offsets("mixed", 0).unwrap());
    server.shutdown();
    kml.shutdown();
}
