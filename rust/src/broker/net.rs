//! Simulated network profile.
//!
//! The paper's Tables I/II compare "data streams" against "data streams &
//! containerization" and explain the inference inversion by network
//! topology: *"For inference [latency] is lower since Kafka is deployed
//! in Kubernetes and thereby the network delay is smaller."* To reproduce
//! that effect on one machine we model two link classes:
//!
//! * **External** — a client outside the cluster (the IoT device/gateway
//!   of §III-D) talking to the broker service;
//! * **InCluster** — a pod talking to the broker over the cluster
//!   network (services resolved in-cluster).
//!
//! Each produce/fetch round-trip sleeps the one-way latency of its link
//! class. Constants are explicit and printed by every bench (DESIGN.md
//! §Table I/II latency model); with `NetProfile::zero()` the broker adds
//! no artificial delay (the default for unit tests).

use std::time::Duration;

/// Where a client sits relative to the (simulated) Kubernetes cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientLocality {
    External,
    InCluster,
    /// A client reaching the broker over the **real** TCP wire protocol
    /// ([`crate::broker::wire`]). The socket round trip *is* the
    /// network, so the simulated profile never applies — real sockets
    /// replace the `NetProfile` delay, they do not stack on top of it.
    Remote,
}

/// One-way link latencies applied per request (produce or fetch batch).
#[derive(Debug, Clone, Copy)]
pub struct NetProfile {
    pub external_one_way: Duration,
    pub in_cluster_one_way: Duration,
}

impl NetProfile {
    /// No artificial latency (unit tests, "normal" mode).
    pub fn zero() -> NetProfile {
        NetProfile {
            external_one_way: Duration::ZERO,
            in_cluster_one_way: Duration::ZERO,
        }
    }

    /// Calibrated defaults for the Tables I/II reproduction: an external
    /// hop is ~6× an in-cluster hop (LAN client → laptop cluster vs
    /// veth pair inside it).
    pub fn calibrated() -> NetProfile {
        NetProfile {
            external_one_way: Duration::from_micros(1500),
            in_cluster_one_way: Duration::from_micros(250),
        }
    }

    pub fn one_way(&self, locality: ClientLocality) -> Duration {
        match locality {
            ClientLocality::External => self.external_one_way,
            ClientLocality::InCluster => self.in_cluster_one_way,
            ClientLocality::Remote => Duration::ZERO,
        }
    }

    /// No artificial latency on either link class (the unit-test
    /// default) — every traversal is a guaranteed no-op.
    pub fn is_zero(&self) -> bool {
        self.external_one_way.is_zero() && self.in_cluster_one_way.is_zero()
    }

    /// Block for one link traversal. A zero-latency link skips the
    /// sleep syscall entirely — this (and the bench harness) is the
    /// only place the broker is allowed to sleep; everything else in
    /// the consume path parks on [`super::notify`] waiters.
    pub fn traverse(&self, locality: ClientLocality) {
        let d = self.one_way(locality);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

impl Default for NetProfile {
    fn default() -> Self {
        NetProfile::zero()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_profile_is_free() {
        let p = NetProfile::zero();
        assert!(p.is_zero());
        assert!(!NetProfile::calibrated().is_zero());
        let t0 = std::time::Instant::now();
        for _ in 0..1000 {
            p.traverse(ClientLocality::External);
        }
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn calibrated_external_slower_than_in_cluster() {
        let p = NetProfile::calibrated();
        assert!(p.one_way(ClientLocality::External) > p.one_way(ClientLocality::InCluster));
    }

    #[test]
    fn remote_locality_never_pays_simulated_latency() {
        // The wire path rides real sockets; even a calibrated profile
        // must add nothing on top.
        let p = NetProfile::calibrated();
        assert_eq!(p.one_way(ClientLocality::Remote), Duration::ZERO);
        let t0 = std::time::Instant::now();
        for _ in 0..1000 {
            p.traverse(ClientLocality::Remote);
        }
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn traverse_sleeps_roughly_one_way() {
        let p = NetProfile {
            external_one_way: Duration::from_millis(10),
            in_cluster_one_way: Duration::ZERO,
        };
        let t0 = std::time::Instant::now();
        p.traverse(ClientLocality::External);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }
}
