//! The control plane: pod lifecycle + Job / ReplicationController
//! reconciliation.
//!
//! `reconcile()` is one pass of the Kubernetes control loop: it creates
//! missing pods, schedules pending ones, starts scheduled ones (paying
//! the [`OrchestratorCosts`] startup model), replaces dead RC replicas,
//! retries failed Job pods within the backoff limit, and scales RCs. A
//! background reconciler thread (`start_reconciler`) runs it on an
//! interval, which is what gives Kafka-ML its fault-tolerance / HA
//! properties (§IV).

use super::pod::{ContainerCtx, EntrypointFn, PodPhase};
use super::resources::{JobSpec, PodSpec, RcSpec};
use super::scheduler::Scheduler;
use crate::exec::CancelToken;
use crate::metrics::Registry;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Startup-cost model for a containerized pod — the measured gap between
/// the paper's "data streams" and "& containerization" columns.
/// `zero()` for unit tests; `calibrated()` for the Tables I/II benches.
#[derive(Debug, Clone, Copy)]
pub struct OrchestratorCosts {
    /// Image pull (amortized: paid once per image per node, like a node
    /// image cache).
    pub image_pull: Duration,
    /// Scheduler + API-server latency per pod.
    pub schedule_delay: Duration,
    /// Container runtime start (create + start + readiness).
    pub container_start: Duration,
}

impl OrchestratorCosts {
    pub fn zero() -> Self {
        OrchestratorCosts {
            image_pull: Duration::ZERO,
            schedule_delay: Duration::ZERO,
            container_start: Duration::ZERO,
        }
    }

    /// Calibrated to a warm single-node cluster (images mostly cached):
    /// dominated by container start + API round-trips.
    pub fn calibrated() -> Self {
        OrchestratorCosts {
            image_pull: Duration::from_millis(350),
            schedule_delay: Duration::from_millis(50),
            container_start: Duration::from_millis(200),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Running,
    Succeeded,
    Failed,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RcStatus {
    pub desired: u32,
    pub running: u32,
    pub starting: u32,
}

struct Pod {
    spec: PodSpec,
    phase: PodPhase,
    cancel: CancelToken,
    /// Owner: ("job"|"rc", name).
    owner: Option<(String, String)>,
    node: Option<String>,
}

struct JobState {
    spec: JobSpec,
    restarts: u32,
    status: JobStatus,
    current_pod: Option<String>,
}

struct RcState {
    spec: RcSpec,
    pods: Vec<String>,
}

struct Inner {
    pods: HashMap<String, Pod>,
    jobs: HashMap<String, JobState>,
    rcs: HashMap<String, RcState>,
    scheduler: Scheduler,
    /// images already pulled (image-pull paid once per image).
    pulled_images: std::collections::HashSet<String>,
}

pub struct Orchestrator {
    inner: Mutex<Inner>,
    entrypoints: Mutex<HashMap<String, EntrypointFn>>,
    costs: OrchestratorCosts,
    next_pod_id: AtomicU64,
    pub metrics: Registry,
    reconciler_cancel: Mutex<Option<CancelToken>>,
}

impl Orchestrator {
    pub fn new(scheduler: Scheduler, costs: OrchestratorCosts) -> Arc<Orchestrator> {
        Arc::new(Orchestrator {
            inner: Mutex::new(Inner {
                pods: HashMap::new(),
                jobs: HashMap::new(),
                rcs: HashMap::new(),
                scheduler,
                pulled_images: std::collections::HashSet::new(),
            }),
            entrypoints: Mutex::new(HashMap::new()),
            costs,
            next_pod_id: AtomicU64::new(1),
            metrics: Registry::new(),
            reconciler_cancel: Mutex::new(None),
        })
    }

    pub fn single_node() -> Arc<Orchestrator> {
        Orchestrator::new(Scheduler::single_node(), OrchestratorCosts::zero())
    }

    pub fn costs(&self) -> OrchestratorCosts {
        self.costs
    }

    /// Register a container entrypoint ("push the image").
    pub fn register_entrypoint<F>(&self, name: &str, f: F)
    where
        F: Fn(ContainerCtx) -> Result<()> + Send + Sync + 'static,
    {
        self.entrypoints
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::new(f));
    }

    // ---- workload API ---------------------------------------------------------

    pub fn create_job(self: &Arc<Self>, spec: JobSpec) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.jobs.contains_key(&spec.name) {
            bail!("job {} already exists", spec.name);
        }
        inner.jobs.insert(
            spec.name.clone(),
            JobState { spec, restarts: 0, status: JobStatus::Running, current_pod: None },
        );
        drop(inner);
        self.reconcile();
        Ok(())
    }

    pub fn create_rc(self: &Arc<Self>, spec: RcSpec) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.rcs.contains_key(&spec.name) {
            bail!("rc {} already exists", spec.name);
        }
        inner
            .rcs
            .insert(spec.name.clone(), RcState { spec, pods: Vec::new() });
        drop(inner);
        self.reconcile();
        Ok(())
    }

    pub fn scale_rc(self: &Arc<Self>, name: &str, replicas: u32) -> Result<()> {
        {
            let mut inner = self.inner.lock().unwrap();
            let rc = inner
                .rcs
                .get_mut(name)
                .ok_or_else(|| anyhow!("unknown rc {name}"))?;
            rc.spec.replicas = replicas;
        }
        self.reconcile();
        Ok(())
    }

    pub fn delete_rc(self: &Arc<Self>, name: &str) -> Result<()> {
        let pods = {
            let mut inner = self.inner.lock().unwrap();
            let rc = inner
                .rcs
                .remove(name)
                .ok_or_else(|| anyhow!("unknown rc {name}"))?;
            rc.pods
        };
        for p in pods {
            self.kill_pod(&p);
        }
        Ok(())
    }

    pub fn delete_job(self: &Arc<Self>, name: &str) -> Result<()> {
        let pod = {
            let mut inner = self.inner.lock().unwrap();
            let j = inner
                .jobs
                .remove(name)
                .ok_or_else(|| anyhow!("unknown job {name}"))?;
            j.current_pod
        };
        if let Some(p) = pod {
            self.kill_pod(&p);
        }
        Ok(())
    }

    pub fn job_status(&self, name: &str) -> Option<JobStatus> {
        self.inner.lock().unwrap().jobs.get(name).map(|j| j.status)
    }

    pub fn rc_status(&self, name: &str) -> Option<RcStatus> {
        let inner = self.inner.lock().unwrap();
        let rc = inner.rcs.get(name)?;
        let mut running = 0;
        let mut starting = 0;
        for p in &rc.pods {
            match inner.pods.get(p).map(|p| p.phase) {
                Some(PodPhase::Running) => running += 1,
                Some(ph) if ph.is_active() => starting += 1,
                _ => {}
            }
        }
        Some(RcStatus { desired: rc.spec.replicas, running, starting })
    }

    pub fn pod_phase(&self, name: &str) -> Option<PodPhase> {
        self.inner.lock().unwrap().pods.get(name).map(|p| p.phase)
    }

    pub fn pods_of_rc(&self, name: &str) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .rcs
            .get(name)
            .map(|rc| rc.pods.clone())
            .unwrap_or_default()
    }

    /// Kill a pod (failure injection / scale-down / SIGTERM).
    pub fn kill_pod(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = inner.pods.get_mut(name) {
            p.cancel.cancel();
            if p.phase.is_active() {
                p.phase = PodPhase::Killed;
                let (cpu, mem) = (p.spec.container.cpu_milli, p.spec.container.memory_mb);
                inner.scheduler.release(name, cpu, mem);
                self.metrics.counter("orch.pods.killed").inc();
            }
        }
    }

    /// Block until the Job reaches a terminal status.
    pub fn wait_job(self: &Arc<Self>, name: &str, timeout: Duration) -> Result<JobStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            self.reconcile();
            match self.job_status(name) {
                Some(JobStatus::Running) => {}
                Some(s) => return Ok(s),
                None => bail!("unknown job {name}"),
            }
            if Instant::now() >= deadline {
                bail!("timeout waiting for job {name}");
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Block until an RC has all desired replicas Running.
    pub fn wait_rc_ready(self: &Arc<Self>, name: &str, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            self.reconcile();
            let st = self
                .rc_status(name)
                .ok_or_else(|| anyhow!("unknown rc {name}"))?;
            if st.running >= st.desired {
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!("timeout waiting for rc {name}: {st:?}");
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    // ---- the control loop -------------------------------------------------------

    /// One reconciliation pass. Idempotent; callable from any thread.
    pub fn reconcile(self: &Arc<Self>) {
        self.reconcile_jobs();
        self.reconcile_rcs();
        self.schedule_and_start();
    }

    fn reconcile_jobs(self: &Arc<Self>) {
        let mut to_create: Vec<(String, PodSpec, String)> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            let job_names: Vec<String> = inner.jobs.keys().cloned().collect();
            for jn in job_names {
                let (status, pod_phase, restarts, backoff, template) = {
                    let j = inner.jobs.get(&jn).unwrap();
                    let ph = j
                        .current_pod
                        .as_ref()
                        .and_then(|p| inner.pods.get(p))
                        .map(|p| p.phase);
                    (j.status, ph, j.restarts, j.spec.backoff_limit, j.spec.template.clone())
                };
                if status != JobStatus::Running {
                    continue;
                }
                match pod_phase {
                    None => {
                        // No pod yet: create one.
                        let pod_name = self.fresh_pod_name(&jn);
                        inner.jobs.get_mut(&jn).unwrap().current_pod = Some(pod_name.clone());
                        to_create.push((pod_name, template, jn));
                    }
                    Some(PodPhase::Succeeded) => {
                        inner.jobs.get_mut(&jn).unwrap().status = JobStatus::Succeeded;
                        self.metrics.counter("orch.jobs.succeeded").inc();
                    }
                    Some(PodPhase::Failed) | Some(PodPhase::Killed) => {
                        if restarts < backoff {
                            let j = inner.jobs.get_mut(&jn).unwrap();
                            j.restarts += 1;
                            let pod_name = self.fresh_pod_name(&jn);
                            j.current_pod = Some(pod_name.clone());
                            to_create.push((pod_name, template, jn));
                            self.metrics.counter("orch.jobs.restarts").inc();
                        } else {
                            inner.jobs.get_mut(&jn).unwrap().status = JobStatus::Failed;
                            self.metrics.counter("orch.jobs.failed").inc();
                        }
                    }
                    Some(_) => {} // still active
                }
            }
            for (pod_name, spec, owner) in &to_create {
                inner.pods.insert(
                    pod_name.clone(),
                    Pod {
                        spec: spec.clone(),
                        phase: PodPhase::Pending,
                        cancel: CancelToken::new(),
                        owner: Some(("job".to_string(), owner.clone())),
                        node: None,
                    },
                );
            }
        }
    }

    fn reconcile_rcs(self: &Arc<Self>) {
        let mut inner = self.inner.lock().unwrap();
        let rc_names: Vec<String> = inner.rcs.keys().cloned().collect();
        for rn in rc_names {
            // Prune dead pods from the RC's list.
            let (mut live, template, desired) = {
                let rc = inner.rcs.get(&rn).unwrap();
                let live: Vec<String> = rc
                    .pods
                    .iter()
                    .filter(|p| {
                        inner
                            .pods
                            .get(*p)
                            .map(|p| p.phase.is_active())
                            .unwrap_or(false)
                    })
                    .cloned()
                    .collect();
                (live, rc.spec.template.clone(), rc.spec.replicas)
            };
            // Scale up.
            while (live.len() as u32) < desired {
                let pod_name = self.fresh_pod_name(&rn);
                inner.pods.insert(
                    pod_name.clone(),
                    Pod {
                        spec: template.clone(),
                        phase: PodPhase::Pending,
                        cancel: CancelToken::new(),
                        owner: Some(("rc".to_string(), rn.clone())),
                        node: None,
                    },
                );
                live.push(pod_name);
                self.metrics.counter("orch.rc.scale_ups").inc();
            }
            // Scale down (newest first).
            while (live.len() as u32) > desired {
                let victim = live.pop().unwrap();
                if let Some(p) = inner.pods.get_mut(&victim) {
                    p.cancel.cancel();
                    if p.phase.is_active() {
                        p.phase = PodPhase::Killed;
                        let (cpu, mem) =
                            (p.spec.container.cpu_milli, p.spec.container.memory_mb);
                        inner.scheduler.release(&victim, cpu, mem);
                    }
                }
            }
            inner.rcs.get_mut(&rn).unwrap().pods = live;
        }
    }

    /// Schedule Pending pods and launch Scheduled ones.
    fn schedule_and_start(self: &Arc<Self>) {
        let mut to_start: Vec<(String, PodSpec, CancelToken, bool)> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            let pending: Vec<String> = inner
                .pods
                .iter()
                .filter(|(_, p)| p.phase == PodPhase::Pending)
                .map(|(n, _)| n.clone())
                .collect();
            for name in pending {
                let (cpu, mem, image) = {
                    let p = inner.pods.get(&name).unwrap();
                    (
                        p.spec.container.cpu_milli,
                        p.spec.container.memory_mb,
                        p.spec.container.image.clone(),
                    )
                };
                if let Some(node) = inner.scheduler.schedule(&name, cpu, mem) {
                    let first_pull = inner.pulled_images.insert(image);
                    let p = inner.pods.get_mut(&name).unwrap();
                    p.phase = PodPhase::Scheduled;
                    p.node = Some(node);
                    to_start.push((name, p.spec.clone(), p.cancel.clone(), first_pull));
                }
                // else: stays Pending until capacity frees up.
            }
        }
        for (name, spec, cancel, first_pull) in to_start {
            self.launch_pod(name, spec, cancel, first_pull);
        }
    }

    fn launch_pod(
        self: &Arc<Self>,
        name: String,
        spec: PodSpec,
        cancel: CancelToken,
        first_pull: bool,
    ) {
        let entry = self
            .entrypoints
            .lock()
            .unwrap()
            .get(&spec.container.entrypoint)
            .cloned();
        let this = Arc::clone(self);
        let costs = self.costs;
        std::thread::Builder::new()
            .name(format!("pod-{name}"))
            .spawn(move || {
                this.set_phase(&name, PodPhase::Starting);
                // Startup cost model: pull (first time per image) +
                // schedule + container start.
                if first_pull {
                    cancel.sleep(costs.image_pull);
                }
                cancel.sleep(costs.schedule_delay);
                cancel.sleep(costs.container_start);
                if cancel.is_cancelled() {
                    this.finish_pod(&name, PodPhase::Killed);
                    return;
                }
                let Some(entry) = entry else {
                    log::error!(
                        "pod {name}: no entrypoint '{}' registered",
                        spec.container.entrypoint
                    );
                    this.finish_pod(&name, PodPhase::Failed);
                    return;
                };
                this.set_phase(&name, PodPhase::Running);
                this.metrics.counter("orch.pods.started").inc();
                let ctx = ContainerCtx {
                    pod_name: name.clone(),
                    env: spec.container.env.clone(),
                    cancel: cancel.clone(),
                };
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    entry(ctx)
                }));
                let phase = match result {
                    Ok(Ok(())) => PodPhase::Succeeded,
                    Ok(Err(e)) => {
                        log::warn!("pod {name} exited with error: {e:#}");
                        PodPhase::Failed
                    }
                    Err(_) => {
                        log::warn!("pod {name} panicked");
                        PodPhase::Failed
                    }
                };
                // A cancelled pod reports Killed regardless of exit value.
                let phase = if cancel.is_cancelled() { PodPhase::Killed } else { phase };
                this.finish_pod(&name, phase);
            })
            .expect("spawn pod thread");
    }

    fn set_phase(&self, name: &str, phase: PodPhase) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = inner.pods.get_mut(name) {
            // Never resurrect a terminal pod (e.g. killed during startup).
            if p.phase.is_active() {
                p.phase = phase;
            }
        }
    }

    fn finish_pod(&self, name: &str, phase: PodPhase) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = inner.pods.get_mut(name) {
            if p.phase.is_active() {
                p.phase = phase;
                let (cpu, mem) = (p.spec.container.cpu_milli, p.spec.container.memory_mb);
                inner.scheduler.release(name, cpu, mem);
            }
        }
    }

    fn fresh_pod_name(&self, owner: &str) -> String {
        format!("{owner}-{}", self.next_pod_id.fetch_add(1, Ordering::SeqCst))
    }

    // ---- background reconciler ---------------------------------------------------

    /// Run `reconcile()` every `interval` until `stop_reconciler`.
    pub fn start_reconciler(self: &Arc<Self>, interval: Duration) {
        let token = CancelToken::new();
        *self.reconciler_cancel.lock().unwrap() = Some(token.clone());
        let this = Arc::clone(self);
        std::thread::Builder::new()
            .name("reconciler".to_string())
            .spawn(move || {
                while token.sleep(interval) {
                    this.reconcile();
                }
            })
            .expect("spawn reconciler");
    }

    pub fn stop_reconciler(&self) {
        if let Some(t) = self.reconciler_cancel.lock().unwrap().take() {
            t.cancel();
        }
    }

    /// Env snapshot helper for tests/examples.
    pub fn pod_env(&self, name: &str) -> Option<BTreeMap<String, String>> {
        self.inner
            .lock()
            .unwrap()
            .pods
            .get(name)
            .map(|p| p.spec.container.env.clone())
    }

    pub fn pod_owner(&self, name: &str) -> Option<(String, String)> {
        self.inner.lock().unwrap().pods.get(name).and_then(|p| p.owner.clone())
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        if let Some(t) = self.reconciler_cancel.lock().unwrap().take() {
            t.cancel();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::resources::ContainerSpec;
    use std::sync::atomic::AtomicU32;

    fn orch() -> Arc<Orchestrator> {
        Orchestrator::single_node()
    }

    #[test]
    fn job_runs_to_completion() {
        let o = orch();
        let ran = Arc::new(AtomicU32::new(0));
        let r = ran.clone();
        o.register_entrypoint("ok", move |ctx| {
            assert_eq!(ctx.env_str("X").unwrap(), "1");
            r.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        o.create_job(JobSpec::new("j", ContainerSpec::new("img", "ok").env("X", "1")))
            .unwrap();
        let st = o.wait_job("j", Duration::from_secs(5)).unwrap();
        assert_eq!(st, JobStatus::Succeeded);
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failing_job_retries_then_fails() {
        let o = orch();
        let attempts = Arc::new(AtomicU32::new(0));
        let a = attempts.clone();
        o.register_entrypoint("bad", move |_| {
            a.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("boom")
        });
        let mut spec = JobSpec::new("j", ContainerSpec::new("img", "bad"));
        spec.backoff_limit = 2;
        o.create_job(spec).unwrap();
        let st = o.wait_job("j", Duration::from_secs(5)).unwrap();
        assert_eq!(st, JobStatus::Failed);
        assert_eq!(attempts.load(Ordering::SeqCst), 3); // 1 + 2 retries
        assert_eq!(o.metrics.counter("orch.jobs.restarts").get(), 2);
    }

    #[test]
    fn job_recovers_after_transient_failure() {
        let o = orch();
        let attempts = Arc::new(AtomicU32::new(0));
        let a = attempts.clone();
        o.register_entrypoint("flaky", move |_| {
            if a.fetch_add(1, Ordering::SeqCst) == 0 {
                anyhow::bail!("first attempt dies")
            }
            Ok(())
        });
        o.create_job(JobSpec::new("j", ContainerSpec::new("img", "flaky")))
            .unwrap();
        assert_eq!(
            o.wait_job("j", Duration::from_secs(5)).unwrap(),
            JobStatus::Succeeded
        );
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn panicking_entrypoint_is_a_failure_not_a_crash() {
        let o = orch();
        o.register_entrypoint("panics", |_| panic!("kaboom"));
        let mut spec = JobSpec::new("j", ContainerSpec::new("img", "panics"));
        spec.backoff_limit = 0;
        o.create_job(spec).unwrap();
        assert_eq!(
            o.wait_job("j", Duration::from_secs(5)).unwrap(),
            JobStatus::Failed
        );
    }

    #[test]
    fn missing_entrypoint_fails_pod() {
        let o = orch();
        let mut spec = JobSpec::new("j", ContainerSpec::new("img", "ghost"));
        spec.backoff_limit = 0;
        o.create_job(spec).unwrap();
        assert_eq!(
            o.wait_job("j", Duration::from_secs(5)).unwrap(),
            JobStatus::Failed
        );
    }

    #[test]
    fn rc_maintains_replicas_and_replaces_killed() {
        let o = orch();
        o.register_entrypoint("serve", |ctx| {
            while !ctx.cancel.is_cancelled() {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(())
        });
        o.create_rc(RcSpec::new("infer", 3, ContainerSpec::new("img", "serve")))
            .unwrap();
        o.wait_rc_ready("infer", Duration::from_secs(5)).unwrap();
        let pods = o.pods_of_rc("infer");
        assert_eq!(pods.len(), 3);
        // Kill one; the reconciler must replace it.
        o.kill_pod(&pods[0]);
        o.wait_rc_ready("infer", Duration::from_secs(5)).unwrap();
        let st = o.rc_status("infer").unwrap();
        assert_eq!(st.running, 3);
        assert_eq!(o.metrics.counter("orch.pods.killed").get(), 1);
        o.delete_rc("infer").unwrap();
    }

    #[test]
    fn rc_scales_up_and_down() {
        let o = orch();
        o.register_entrypoint("serve", |ctx| {
            while !ctx.cancel.is_cancelled() {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(())
        });
        o.create_rc(RcSpec::new("infer", 1, ContainerSpec::new("img", "serve")))
            .unwrap();
        o.wait_rc_ready("infer", Duration::from_secs(5)).unwrap();
        o.scale_rc("infer", 4).unwrap();
        o.wait_rc_ready("infer", Duration::from_secs(5)).unwrap();
        assert_eq!(o.rc_status("infer").unwrap().running, 4);
        o.scale_rc("infer", 2).unwrap();
        // Wait for terminations to settle.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            o.reconcile();
            let st = o.rc_status("infer").unwrap();
            if st.running == 2 && st.starting == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "never settled: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        o.delete_rc("infer").unwrap();
    }

    #[test]
    fn pods_queue_pending_when_cluster_full() {
        let o = Orchestrator::new(
            Scheduler::new(vec![NodeSpec::new("tiny", 100, 100)]),
            OrchestratorCosts::zero(),
        );
        o.register_entrypoint("serve", |ctx| {
            while !ctx.cancel.is_cancelled() {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(())
        });
        // Each replica wants the whole node; only 1 of 3 can run.
        o.create_rc(RcSpec::new(
            "big",
            3,
            ContainerSpec::new("img", "serve").resources(100, 100),
        ))
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        o.reconcile();
        let st = o.rc_status("big").unwrap();
        assert_eq!(st.running + st.starting, 3); // 1 running + 2 pending
        assert_eq!(st.running, 1);
        o.delete_rc("big").unwrap();
    }

    use crate::orchestrator::resources::NodeSpec;

    #[test]
    fn duplicate_job_rejected() {
        let o = orch();
        o.register_entrypoint("ok", |_| Ok(()));
        o.create_job(JobSpec::new("j", ContainerSpec::new("i", "ok"))).unwrap();
        assert!(o.create_job(JobSpec::new("j", ContainerSpec::new("i", "ok"))).is_err());
    }

    #[test]
    fn background_reconciler_replaces_pods() {
        let o = orch();
        o.register_entrypoint("serve", |ctx| {
            while !ctx.cancel.is_cancelled() {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(())
        });
        o.create_rc(RcSpec::new("infer", 2, ContainerSpec::new("img", "serve")))
            .unwrap();
        o.wait_rc_ready("infer", Duration::from_secs(5)).unwrap();
        o.start_reconciler(Duration::from_millis(10));
        let pods = o.pods_of_rc("infer");
        o.kill_pod(&pods[0]);
        o.kill_pod(&pods[1]);
        // No manual reconcile: the background loop must restore both.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let st = o.rc_status("infer").unwrap();
            if st.running == 2 {
                break;
            }
            assert!(Instant::now() < deadline, "reconciler never recovered");
            std::thread::sleep(Duration::from_millis(10));
        }
        o.stop_reconciler();
        o.delete_rc("infer").unwrap();
    }
}
