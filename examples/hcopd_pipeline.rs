//! **End-to-end validation driver** (EXPERIMENTS.md §E2E): reproduces the
//! paper's §VI validation on the synthetic HCOPD workload, exercising all
//! three layers — the Pallas-kernel model compiled AOT (L1/L2) executed
//! through PJRT by containerized training Jobs and inference replicas
//! (L3) fed entirely through data streams.
//!
//! The run mirrors the paper's setup: Avro multi-input encoding, batch
//! size 10 (220 samples → 22 steps/epoch, the paper's
//! `steps_per_epoch=22`), Adam(1e-4), validation split, then inference
//! behind 2 replicas. It prints the per-epoch loss curve and the latency
//! summary recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example hcopd_pipeline [epochs]
//! ```

use kafka_ml::broker::ClientLocality;
use kafka_ml::coordinator::{KafkaMl, KafkaMlConfig, TrainParams};
use kafka_ml::metrics::Histogram;
use kafka_ml::ml::hcopd_dataset;
use kafka_ml::util::human_duration;
use std::time::{Duration, Instant};

fn avro_config() -> kafka_ml::json::Json {
    kafka_ml::json::parse(
        r#"{
      "data_scheme": {"type":"record","name":"copd_data","fields":[
        {"name":"age","type":"float"},
        {"name":"gender","type":"float"},
        {"name":"smoking","type":"float"},
        {"name":"sensors","type":{"type":"array","items":"float"}}]},
      "label_scheme": {"type":"record","name":"copd_label","fields":[
        {"name":"diagnosis","type":"int"}]}
    }"#,
    )
    .unwrap()
}

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    println!("== Kafka-ML HCOPD end-to-end validation (epochs={epochs}) ==\n");

    let t_boot = Instant::now();
    let kml = KafkaMl::start(KafkaMlConfig::default())?;
    println!(
        "[boot] platform up in {} ({})",
        human_duration(t_boot.elapsed()),
        kml.backend_url()
    );

    // A/B — the COPD Keras model of Listing 2, here as AOT artifacts.
    let model = kml.create_model("copd-mlp")?;
    let conf = kml.create_configuration("copd", &[model])?;

    // C — deploy for training.
    let t_train = Instant::now();
    let dep = kml.deploy_training(
        conf,
        &TrainParams { batch_size: 10, epochs, shuffle: true, seed: 42 },
    )?;

    // D — Avro-encoded multi-input stream: age/gender/smoking + 5 sensor
    // channels, 220 patients, 20% validation split.
    let ds = hcopd_dataset(220, 8, 42);
    println!(
        "[data] {} samples, class histogram {:?}",
        ds.len(),
        ds.class_histogram()
    );
    let msg = kml.send_stream(
        dep.id,
        &ds.samples,
        "copd-train",
        "AVRO",
        &avro_config(),
        0.2,
        ClientLocality::External,
    )?;
    println!("[data] control message sent: {}", msg.stream.format());

    // E — wait, report the loss curve.
    let results = kml.wait_training(&dep, Duration::from_secs(1800))?;
    let r = &results[0];
    let train_wall = t_train.elapsed();
    println!(
        "\n[train] finished in {} — loss curve:",
        human_duration(train_wall)
    );
    for (e, loss) in r.metrics.loss_curve.iter().enumerate() {
        if e % (epochs / 12).max(1) == 0 || e + 1 == r.metrics.loss_curve.len() {
            let bar = "#".repeat((loss * 40.0) as usize);
            println!("  epoch {e:>4}  loss {loss:.4}  {bar}");
        }
    }
    println!(
        "[train] final: loss {:.4}, accuracy {:.3}, val_loss {:.4}, val_accuracy {:.3}",
        r.metrics.loss,
        r.metrics.accuracy,
        r.metrics.val_loss.unwrap_or(f64::NAN),
        r.metrics.val_accuracy.unwrap_or(f64::NAN),
    );
    let first = *r.metrics.loss_curve.first().unwrap();
    let last = *r.metrics.loss_curve.last().unwrap();
    assert!(last < first, "loss must decrease over training");

    // E/F — inference behind 2 replicas (consumer-group load balancing),
    // input format auto-configured from the control log (§IV-E).
    let inf = kml.deploy_inference(r.id, 2, "copd-in", "copd-out")?;
    println!(
        "\n[infer] deployment {} up: 2 replicas, format {} (auto-configured)",
        inf.id, inf.input_format
    );
    let mut client = kml.inference_client(&inf, ClientLocality::External)?;
    let test = hcopd_dataset(100, 8, 999);
    let hist = Histogram::new();
    let mut correct = 0;
    for s in &test.samples {
        let t0 = Instant::now();
        let p = client.request(&s.features, Duration::from_secs(10))?;
        hist.observe(t0.elapsed());
        if p.class as i32 == s.label.unwrap() {
            correct += 1;
        }
    }
    println!(
        "[infer] 100 requests: accuracy {:.2}, latency mean {} p50 {} p99 {}",
        correct as f64 / 100.0,
        human_duration(hist.mean()),
        human_duration(hist.quantile(0.5)),
        human_duration(hist.quantile(0.99)),
    );

    println!("\n== summary (recorded in EXPERIMENTS.md §E2E) ==");
    println!("  training wall-clock : {}", human_duration(train_wall));
    println!("  epochs              : {epochs} (17 full batches/epoch after 20% split)");
    println!("  loss                : {first:.4} -> {last:.4}");
    println!(
        "  validation          : loss {:.4}, accuracy {:.3}",
        r.metrics.val_loss.unwrap_or(f64::NAN),
        r.metrics.val_accuracy.unwrap_or(f64::NAN)
    );
    println!("  inference accuracy  : {:.2}", correct as f64 / 100.0);
    println!(
        "  inference latency   : mean {} / p99 {}",
        human_duration(hist.mean()),
        human_duration(hist.quantile(0.99))
    );

    kml.stop_inference(inf.id)?;
    kml.shutdown();
    Ok(())
}
